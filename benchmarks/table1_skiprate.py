"""Table I analog — % of skipped output updates during real inference.

The paper integrates FLASH-D into HF LLMs and measures how often the
sigmoid argument falls outside [-6, 11] on PromptBench tasks (0.5–2.8%,
always-win). Offline reproduction: we TRAIN a llama2.c-scale model on the
synthetic grammar (the same model family the paper used for bit-exactness
checks), then run inference and instrument both:

  element-level  — the paper's exact counter (per key-step), via Alg. 3
  tile-level     — the TPU kernel's whole-tile predication rate at
                   B_k ∈ {16, 64}, the rate that matters for MXU-FLOP savings

over three prompt regimes (in-distribution, uniform-random, repeated-token).
An UNTRAINED model is also measured: random attention ⇒ near-zero skips,
confirming skips are a property of LEARNED attention concentration (the
paper's implicit claim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_llama
from repro.core.blockwise import MaskSpec
from repro.core.skipping import element_skip_stats, tile_skip_rate
from repro.data import DataConfig, SyntheticLM
from repro.models import get_model
from repro.models.transformer import _qkv
from repro.optim import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _train_small(cfg, steps=120):
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=10, total_steps=steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    first = last = None
    for i in range(steps):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        if i == 0:
            first = float(m["loss"])
    last = float(m["loss"])
    return state.params, first, last


def _qkv_of_layer(params, cfg, tokens):
    """Project the first layer's q/k/v for instrumentation."""
    from repro.models.layers import embed_lookup, rms_norm

    h = embed_lookup(params["embed"], tokens, cfg.compute_dtype)
    bp = jax.tree.map(lambda x: x[0], params["blocks"])["pos0"]
    x = rms_norm(h, bp["norm1"], cfg.norm_eps)
    q, k, v = _qkv(bp["mixer"], x, cfg, "attn", jnp.arange(tokens.shape[1]))
    return q, k, v


def _prompts(cfg, kind, b=4, s=64):
    rng = np.random.default_rng(7)
    if kind == "in_distribution":
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=s, global_batch=b, seed=99))
        return jnp.asarray(data.batch(0)["tokens"])
    if kind == "uniform":
        return jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return jnp.tile(jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32), (1, s))


def run(report):
    cfg = paper_llama.CONFIG
    params, loss0, loss1 = _train_small(cfg)
    report("table1_train_loss", loss1, f"first={loss0:.3f} last={loss1:.3f} (trained probe model)")

    rng_params = get_model(cfg).init(jax.random.PRNGKey(123), cfg)
    for model_name, p in (("trained", params), ("untrained", rng_params)):
        for kind in ("in_distribution", "uniform", "repeated"):
            toks = _prompts(cfg, kind)
            q, k, v = _qkv_of_layer(p, cfg, toks)
            st = element_skip_stats(q, k, v)
            lo = 100.0 * float(st.rate_low)
            hi = 100.0 * float(st.rate_high)
            t16 = 100.0 * float(tile_skip_rate(q, k, v, mask=MaskSpec("causal"), block_q=16, block_k=16))
            t64 = 100.0 * float(tile_skip_rate(q, k, v, mask=MaskSpec("causal"), block_q=16, block_k=64))
            report(
                f"table1_skip_{model_name}_{kind}", lo,
                f"elem_lo={lo:.2f}% elem_hi={hi:.2f}% tile16={t16:.2f}% "
                f"tile64={t64:.2f}% (paper: 0.5-2.8% elem, always-win)",
            )
