"""Kernel micro-benchmarks — the paper's "same performance" claim.

The paper's hardware comparison holds THROUGHPUT EQUAL (same pipelined
latency, same dataflow) and wins on area/power. The software analogues
measured here:

  1. wall-time of the tiled FLASH-D vs FA2 vs naive softmax attention
     (jit-compiled jnp on this host — same asymptotic work is the claim;
     Pallas interpret mode is a Python emulator, so TPU wall-times are
     out of scope for this container and come from the roofline instead);
  2. compiled HLO flops/bytes of each impl at equal shapes (XLA's view of
     the datapath — FLASH-D must not add work);
  3. skip-mode wall-time effect at a concentration-heavy input.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import MaskSpec, flash_attention


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(report):
    shapes = [
        ("train-ish", 2, 512, 8, 64),
        ("prefill-ish", 1, 2048, 4, 64),
    ]
    for name, b, s, h, d in shapes:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)

        results = {}
        for impl in ("flashd", "fa2", "naive"):
            f = jax.jit(
                lambda q, k, v, impl=impl: flash_attention(
                    q, k, v, mask=MaskSpec("causal"), impl=impl,
                    block_q=128, block_k=128,
                )
            )
            us = _bench(f, q, k, v)
            c = f.lower(q, k, v).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            results[impl] = (us, float(ca.get("flops", 0)))
            report(f"kernel_{name}_{impl}", us, f"hlo_flops={results[impl][1]:.3e}")
        ratio = results["flashd"][0] / results["fa2"][0]
        report(
            f"kernel_{name}_flashd_vs_fa2", ratio,
            f"wall-time ratio (paper: parity; <1 is a win) "
            f"flop_ratio={results['flashd'][1]/max(results['fa2'][1],1):.3f}",
        )

    # skip-mode effect on a concentration-heavy input (post-trained attn is
    # concentrated; emulate with scaled scores)
    b, s, h, d = 1, 1024, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32) * 4.0
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    for skip in (False, True):
        f = jax.jit(
            lambda q, k, v, skip=skip: flash_attention(
                q, k, v, mask=MaskSpec("causal"), impl="flashd",
                block_q=64, block_k=64, skip=skip,
            )
        )
        us = _bench(f, q, k, v)
        report(f"kernel_skip_{'on' if skip else 'off'}", us,
               "jnp path computes the predicate only; true FLOP skip is the "
               "Pallas @pl.when path (TPU)")
