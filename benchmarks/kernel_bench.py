"""Kernel micro-benchmarks — the paper's "same performance" claim.

The paper's hardware comparison holds THROUGHPUT EQUAL (same pipelined
latency, same dataflow) and wins on area/power. The software analogues
measured here:

  1. wall-time of the tiled FLASH-D vs FA2 vs naive softmax attention
     (jit-compiled jnp on this host — same asymptotic work is the claim;
     Pallas interpret mode is a Python emulator, so TPU wall-times are
     out of scope for this container and come from the roofline instead);
  2. compiled HLO flops/bytes of each impl at equal shapes (XLA's view of
     the datapath — FLASH-D must not add work);
  3. skip-mode wall-time effect at a concentration-heavy input;
  4. the decode fast path: fused vs unfused split-K kernel and the jitted
     scan engine vs the per-token host loop (the seed serving path).

  5. context parallelism on a simulated 8-device host mesh (subprocess —
     this process must keep its single device): ring prefill vs the
     replicated single-device baseline, and cp_decode, in tokens/sec.

Besides the CSV `report` contract, this module emits machine-readable
``BENCH_prefill.json`` / ``BENCH_decode.json`` / ``BENCH_ring.json`` (into
$BENCH_DIR, default cwd) so the perf trajectory is tracked across PRs. Set
BENCH_SMOKE=1 for CI-sized shapes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import MaskSpec, flash_attention


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _emit_json(filename: str, payload: dict) -> None:
    path = os.path.join(os.environ.get("BENCH_DIR", "."), filename)
    payload = {
        "backend": jax.devices()[0].platform,
        "smoke": bool(os.environ.get("BENCH_SMOKE")),
        **payload,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


def run(report):
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    prefill_rows = []
    shapes = [
        ("train-ish", 2, 512, 8, 64),
        ("prefill-ish", 1, 2048, 4, 64),
    ]
    if smoke:
        shapes = [("train-ish", 1, 128, 2, 32)]
    for name, b, s, h, d in shapes:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)

        results = {}
        for impl in ("flashd", "fa2", "naive"):
            f = jax.jit(
                lambda q, k, v, impl=impl: flash_attention(
                    q, k, v, mask=MaskSpec("causal"), impl=impl,
                    block_q=128, block_k=128,
                )
            )
            us = _bench(f, q, k, v)
            c = f.lower(q, k, v).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            results[impl] = (us, float(ca.get("flops", 0)))
            report(f"kernel_{name}_{impl}", us, f"hlo_flops={results[impl][1]:.3e}")
            prefill_rows.append({
                "name": name, "impl": impl, "batch": b, "seq": s,
                "heads": h, "head_dim": d, "us_per_call": us,
                "hlo_flops": results[impl][1],
            })
        ratio = results["flashd"][0] / results["fa2"][0]
        report(
            f"kernel_{name}_flashd_vs_fa2", ratio,
            f"wall-time ratio (paper: parity; <1 is a win) "
            f"flop_ratio={results['flashd'][1]/max(results['fa2'][1],1):.3f}",
        )

    # skip-mode effect on a concentration-heavy input (post-trained attn is
    # concentrated; emulate with scaled scores)
    b, s, h, d = 1, 1024, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32) * 4.0
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    for skip in (False, True):
        f = jax.jit(
            lambda q, k, v, skip=skip: flash_attention(
                q, k, v, mask=MaskSpec("causal"), impl="flashd",
                block_q=64, block_k=64, skip=skip,
            )
        )
        us = _bench(f, q, k, v)
        report(f"kernel_skip_{'on' if skip else 'off'}", us,
               "jnp path computes the predicate only; true FLOP skip is the "
               "Pallas @pl.when path (TPU)")

    _emit_json("BENCH_prefill.json", {"rows": prefill_rows})
    _emit_json("BENCH_decode.json", _bench_decode(report, smoke))
    _emit_json("BENCH_paged.json", _bench_paged(report, smoke))
    _emit_json("BENCH_serve.json", _bench_serve(report, smoke))
    _emit_json("BENCH_spec.json", _bench_spec(report, smoke))
    _emit_json("BENCH_prefix.json", _bench_prefix(report, smoke))
    _emit_json("BENCH_chaos.json", _bench_chaos(report, smoke))
    _emit_json("BENCH_train.json", _bench_train(report, smoke))
    _emit_json("BENCH_quant.json", _bench_quant(report, smoke))
    _emit_json("BENCH_ring.json", _bench_ring(report, smoke))


def _bench_train(report, smoke: bool) -> dict:
    """Training with the FLASH-D fwd+bwd pair (DESIGN.md §6).

    Two asserted bars:

    1. **throughput** — full jitted train step (value_and_grad + AdamW)
       with `attn_impl="flashd"` (the tiled custom_vjp pair, algorithmic
       mirror of the Pallas kernels) vs `"xla"` (plain softmax attention
       with no custom_vjp — XLA saves the [S,S] probs for the backward,
       the seed-era baseline). At S where the [S,S] residuals hurt, the
       recompute-from-(q,k,Λ) backward must win: flashd tokens/s ≥ xla.
       (Pallas interpret mode is a Python emulator — TPU wall-times are
       out of scope for this container, so the fused pair's own bar is
       the jnp mirror, same policy as the kernel bars above.)

    2. **goodput under chaos** — `train_resilient` at 0% / 10% train-site
       fault injection: goodput = committed steps / total step executions
       (replays after a restart are the waste). Asserted: 1.0 at rate 0,
       ≥ 0.5 at 10%, and the final loss BITWISE identical across rates —
       chaos costs throughput, never correctness.
    """
    import dataclasses as _dc
    import tempfile as _tf

    from repro.configs import paper_llama
    from repro.data import DataConfig, SyntheticLM
    from repro.runtime.resilience import FaultInjector
    from repro.train import (
        ResilienceConfig, TrainConfig, init_train_state, make_train_step,
        train_resilient,
    )

    out: dict = {"throughput": {}, "goodput": {}}

    # ---- 1. train-step throughput: flashd pair vs xla baseline ----
    S = 512 if smoke else 1024
    B = 2

    def tok_per_s(impl):
        cfg = _dc.replace(
            paper_llama.CONFIG, n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, d_ff=256, head_dim=32, vocab_size=256,
            vocab_pad_multiple=64, attn_impl=impl,
        )
        tc = TrainConfig(warmup_steps=2, total_steps=100)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                                      global_batch=B))
        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        step = jax.jit(make_train_step(cfg, tc))
        batch = jax.tree.map(jnp.asarray, data.batch(0))
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])  # compile + warm
        best = float("inf")
        for i in range(5):
            batch = jax.tree.map(jnp.asarray, data.batch(i + 1))
            t0 = time.perf_counter()
            state, m = step(state, batch)
            jax.block_until_ready(m["loss"])
            best = min(best, time.perf_counter() - t0)
        return B * S / best

    tok = {impl: tok_per_s(impl) for impl in ("flashd", "xla")}
    on_tpu = jax.devices()[0].platform == "tpu"
    if on_tpu:  # the fused pair's real wall-time bar — TPU only
        tok["flashd_pallas"] = tok_per_s("flashd_pallas")
    out["throughput"] = {
        "shape": {"batch": B, "seq_len": S, "d_model": 128, "n_layers": 2},
        "tokens_per_sec": tok,
        "flashd_over_xla": tok["flashd"] / tok["xla"],
        "pallas_measured": on_tpu,
    }
    for impl, t in tok.items():
        report(f"train_step_{impl}_tok_per_s", t, f"B={B} S={S}")
    report("train_flashd_over_xla", tok["flashd"] / tok["xla"],
           "fused-pair mirror vs [S,S]-residual baseline (≥1 target)")
    floor = 0.9 if smoke else 1.0  # smoke shape's margin is thin on CPU
    assert tok["flashd"] >= floor * tok["xla"], tok

    # ---- 2. goodput under train-site fault injection ----
    cfg = _dc.replace(
        paper_llama.CONFIG, n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, head_dim=16, vocab_size=64, vocab_pad_multiple=64,
    )
    tc = TrainConfig(warmup_steps=2, total_steps=50)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4))
    total = 12 if smoke else 24
    final_loss = {}
    for rate in (0.0, 0.10):
        inj = (FaultInjector(rate, seed=7, sites=FaultInjector.TRAIN_SITES)
               if rate > 0 else None)
        executions = [0]
        with _tf.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            state, hist, ctr = train_resilient(
                ckpt_dir=d, model_cfg=cfg, train_cfg=tc, data=data,
                total_steps=total,
                res=ResilienceConfig(ckpt_every=3, max_restarts=1000),
                injector=inj,
                on_step=lambda s, m, c: executions.__setitem__(0, executions[0] + 1),
            )
            wall = time.perf_counter() - t0
        goodput = total / max(executions[0], total)
        final_loss[rate] = hist[-1]["loss"]
        out["goodput"][f"{rate:.2f}"] = {
            "goodput": goodput,
            "committed_steps": total,
            "step_executions": executions[0],
            "restarts": ctr["restarts"],
            "faults": ctr["faults"],
            "wall_s": wall,
            "final_loss": final_loss[rate],
        }
        report(f"train_chaos_rate{int(rate * 100):02d}_goodput", goodput,
               f"{ctr['restarts']} restarts, {ctr['faults']} faults")
    assert out["goodput"]["0.00"]["goodput"] == 1.0
    assert out["goodput"]["0.10"]["goodput"] >= 0.5, out["goodput"]
    assert final_loss[0.0] == final_loss[0.10], final_loss  # bitwise
    return out


_RING_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import jax, jax.numpy as jnp, numpy as np

smoke = bool(int(sys.argv[1]))
from repro.core.attention import MaskSpec, flash_attention
from repro.distributed.context import cp_decode, ring_prefill
from repro.kernels.tuning import choose_ring_schedule

def bench(fn, iters=3):
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
b, s, h, d = (1, 256, 2, 32) if smoke else (1, 2048, 4, 64)
q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
out = {"n_devices": 8, "prefill": [], "decode": {}}
for kind, window in [("causal", 0), ("local", s // 4)]:
    mask = MaskSpec(kind, window=window)
    ring = jax.jit(lambda q, k, v, m=mask: ring_prefill(
        q, k, v, axis="data", mesh=mesh, mask=m, impl="flashd"))
    base = jax.jit(lambda q, k, v, m=mask: flash_attention(
        q, k, v, mask=m, impl="flashd"))
    t_ring = bench(lambda: ring(q, k, v))
    t_base = bench(lambda: base(q, k, v))
    sched = choose_ring_schedule(s // 8, s // 8, d, d, n_devices=8, mask=mask)
    out["prefill"].append({
        "mask": kind, "window": window, "batch": b, "seq": s, "heads": h,
        "head_dim": d, "live_hops": sched.n_hops,
        "tokens_per_sec_ring": b * s / t_ring,
        "tokens_per_sec_replicated": b * s / t_base,
    })

bd, S = (2, 256) if smoke else (2, 4096)
qd = jnp.asarray(rng.normal(size=(bd, h, d)), jnp.float32)
kc = jnp.asarray(rng.normal(size=(bd, S, h, d)), jnp.float32)
vc = jnp.asarray(rng.normal(size=(bd, S, h, d)), jnp.float32)
cl = jnp.full((bd,), S, jnp.int32)
cpd = jax.jit(lambda q, k, v, c: cp_decode(
    q, k, v, c, axis="data", mesh=mesh, use_kernel=False))
t_cp = bench(lambda: cpd(qd, kc, vc, cl))
out["decode"] = {"batch": bd, "cache_len": S, "heads": h, "head_dim": d,
                 "tokens_per_sec_cp": bd / t_cp}
print(json.dumps(out))
"""


def _bench_ring(report, smoke: bool) -> dict:
    """Ring context-parallel prefill/decode on a simulated 8-device mesh.

    Runs in a subprocess (XLA device count is fixed at first jax use, so
    this process cannot re-host 8 devices itself). Numbers are CPU-host
    relative — the tracked signal is ring-vs-replicated on equal shapes
    and the live-hop count, not absolute throughput."""
    res = subprocess.run(
        [sys.executable, "-c", _RING_PROG, "1" if smoke else "0"],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu", "XLA_FLAGS": "",
             "PYTHONPATH": os.environ.get("PYTHONPATH", "src")},
    )
    if res.returncode != 0:
        # fail the job like the in-process benches do — a silent error blob
        # in BENCH_ring.json would erase the tracked perf signal unnoticed
        raise RuntimeError(f"ring bench subprocess failed:\n{res.stderr}")
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for row in out["prefill"]:
        report(
            f"ring_prefill_{row['mask']}_tok_per_s", row["tokens_per_sec_ring"],
            f"replicated={row['tokens_per_sec_replicated']:.1f} "
            f"live_hops={row['live_hops']}/8 seq={row['seq']}",
        )
    report("cp_decode_tok_per_s", out["decode"]["tokens_per_sec_cp"],
           f"cache={out['decode']['cache_len']} b={out['decode']['batch']}")
    return out


def _bench_paged(report, smoke: bool) -> dict:
    """Paged KV cache (DESIGN.md §3.4): kernel overhead of the block-table
    indirection, and the serving-density win — peak concurrent sequences of
    the paged engine vs the contiguous engine at EQUAL KV memory budget.

    The contiguous engine commits max_len tokens per slot up front, so its
    concurrency is budget / max_len regardless of actual lengths; the paged
    engine admits by free pages, so short sequences pack the same budget
    ~(max_len / actual_len)× denser. The tracked signal is that ratio
    (≥ 1.5× is the acceptance bar; short-request workloads sit well above)."""
    from repro.kernels.flashd_decode import (
        flashd_decode_paged_pallas, flashd_decode_pallas,
    )

    out: dict = {"kernel": [], "engine": {}}
    interp = jax.devices()[0].platform != "tpu"

    # --- kernel: paged (block-table DMA gather) vs contiguous fused
    b, hq, hkv, d = (1, 2, 1, 16) if smoke else (2, 8, 2, 64)
    page, n_tbl = (16, 4) if smoke else (64, 8)
    s = page * n_tbl
    rng = np.random.default_rng(0)
    n_pool = b * n_tbl + 2
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    tbl = jnp.asarray(
        rng.permutation(np.arange(1, n_pool))[: b * n_tbl].reshape(b, n_tbl),
        jnp.int32,
    )
    cl = jnp.full((b,), s, jnp.int32)
    kc = jnp.moveaxis(kp[tbl], 3, 1).reshape(b, hkv, s, d)
    vc = jnp.moveaxis(vp[tbl], 3, 1).reshape(b, hkv, s, d)

    f_paged = jax.jit(lambda q, kp, vp, t, c: flashd_decode_paged_pallas(
        q, kp, vp, t, c, interpret=interp))
    f_cont = jax.jit(lambda q, k, v, c: flashd_decode_pallas(
        q, k, v, c, n_splits=n_tbl, fused=True, interpret=interp))
    us_paged = _bench(f_paged, q, kp, vp, tbl, cl)
    us_cont = _bench(f_cont, q, kc, vc, cl)
    report("decode_kernel_paged", us_paged, f"page={page} n_tbl={n_tbl}")
    report("decode_kernel_paged_vs_contiguous", us_paged / us_cont,
           "ratio (block-table indirection overhead; ~1 is the goal)")
    out["kernel"] = [
        {"variant": "paged", "batch": b, "heads": hq, "kv_heads": hkv,
         "cache_len": s, "head_dim": d, "page_size": page,
         "us_per_call": us_paged},
        {"variant": "contiguous_fused", "batch": b, "heads": hq,
         "kv_heads": hkv, "cache_len": s, "head_dim": d,
         "n_splits": n_tbl, "us_per_call": us_cont},
    ]

    # --- engine: concurrent sequences at equal KV memory budget
    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.serve import Engine, ServeConfig

    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    slots, max_len = (2, 64) if smoke else (4, 256)
    budget_tokens = slots * max_len  # what the contiguous engine commits
    n_req, p_len, n_new = (8, 4, 6) if smoke else (16, 8, 16)
    reqs = [np.random.default_rng(i).integers(0, cfg.vocab_size, (p_len,))
            .astype(np.int32) for i in range(n_req)]

    eng_c = Engine(params, cfg, ServeConfig(
        max_batch=slots, max_len=max_len, temperature=0.0))
    t0 = time.perf_counter()
    outs_c = eng_c.serve(reqs, n_new)
    t_cont = time.perf_counter() - t0

    eng_p = Engine(params, cfg, ServeConfig(
        max_batch=4 * slots, max_len=max_len, temperature=0.0,
        kv_layout="paged", page_size=16, kv_pool_tokens=budget_tokens))
    t0 = time.perf_counter()
    outs_p = eng_p.serve(reqs, n_new)
    t_paged = time.perf_counter() - t0
    assert all(np.array_equal(a, c) for a, c in zip(outs_c, outs_p))

    ratio = eng_p.peak_active / max(eng_c.peak_active, 1)
    report("serve_concurrency_contiguous", eng_c.peak_active,
           f"budget={budget_tokens} tokens, max_len={max_len}")
    report("serve_concurrency_paged", eng_p.peak_active,
           f"same budget, page=16, reqs of ~{p_len}+{n_new} tokens")
    report("serve_concurrency_ratio", ratio, "paged/contiguous (≥1.5 target)")
    out["engine"] = {
        "kv_budget_tokens": budget_tokens, "max_len": max_len,
        "request_prompt_len": p_len, "new_tokens": n_new,
        "n_requests": n_req,
        "concurrent_contiguous": eng_c.peak_active,
        "concurrent_paged": eng_p.peak_active,
        "concurrency_ratio": ratio,
        "wall_s_contiguous": t_cont, "wall_s_paged": t_paged,
    }
    return out


def _bench_quant(report, smoke: bool) -> dict:
    """Quantized paged KV pool (DESIGN.md §3.8): serving density at EQUAL
    KV HBM budget — the int8 pool stores ~4x the tokens per byte (pages at
    1 B/elem plus a small f32 per-page scale side-band), so the same
    memory admits proportionally more concurrent sequences. The tracked
    signals are the peak-concurrency ratio (≥ 1.5x is the acceptance bar)
    and the accuracy cost as max logprob drift on a teacher-forced paged
    decode (int8 vs native pool)."""
    import jax.numpy as jnp
    from jax import tree_util as jtu

    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.models.transformer import (
        decode_step_lm, init_decode_cache, prefill_lm,
    )
    from repro.serve import Engine, ServeConfig

    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    page = 4 if smoke else 8
    budget_tokens, max_len = (48, 32) if smoke else (192, 64)
    n_req, p_len, n_new = (16, 6, 6) if smoke else (32, 12, 16)
    reqs = [np.random.default_rng(i).integers(0, cfg.vocab_size, (p_len,))
            .astype(np.int32) for i in range(n_req)]

    def make(kv_dtype, pool_tokens):
        return Engine(params, cfg, ServeConfig(
            max_batch=n_req, max_len=max_len, temperature=0.0,
            kv_layout="paged", page_size=page, kv_pool_tokens=pool_tokens,
            kv_dtype=kv_dtype))

    eng_f = make("", budget_tokens)
    t0 = time.perf_counter()
    eng_f.serve(reqs, n_new)
    t_native = time.perf_counter() - t0
    bpt_f = eng_f.stats()["kv_bytes_per_token"]
    budget_bytes = bpt_f * budget_tokens

    # size the int8 pool to the SAME byte budget (scale side-band included)
    probe_eng = make("int8", budget_tokens)
    probe_eng._paged_state()  # pool is lazy; stats() needs it materialized
    bpt_q = probe_eng.stats()["kv_bytes_per_token"]
    pool_q = int(budget_bytes // bpt_q) // page * page
    eng_q = make("int8", pool_q)
    t0 = time.perf_counter()
    eng_q.serve(reqs, n_new)
    t_int8 = time.perf_counter() - t0

    ratio = eng_q.peak_active / max(eng_f.peak_active, 1)
    report("quant_pool_tokens_native", budget_tokens,
           f"{budget_bytes / 1024:.1f} KiB @ {bpt_f:.0f} B/token")
    report("quant_pool_tokens_int8", pool_q,
           f"same bytes @ {bpt_q:.0f} B/token (pages + scale side-band)")
    report("quant_concurrency_ratio", ratio,
           "int8/native peak sequences at equal KV HBM (≥1.5 target)")
    assert ratio >= 1.5, (
        f"int8 equal-memory concurrency {ratio:.2f}x below the 1.5x bar "
        f"({eng_q.peak_active} vs {eng_f.peak_active} peak sequences)")

    # --- accuracy: teacher-forced paged decode, int8 vs native pool
    B, plen, steps, n_per = 2, 8, 6, 8
    toks_in = jnp.asarray(
        np.random.default_rng(99).integers(1, cfg.vocab_size, (B, plen)),
        jnp.int32)
    tbl = jnp.asarray([[1 + b * n_per + i for i in range(n_per)]
                       for b in range(B)], jnp.int32)

    def probe(kv_dtype, forced):
        cache = init_decode_cache(B, 32, cfg, layout="paged", page_size=page,
                                  n_pages=1 + B * n_per, kv_dtype=kv_dtype)

        def set_tbl(path, x):
            name = next((e.key for e in reversed(path)
                         if isinstance(e, jtu.DictKey)), None)
            return jnp.broadcast_to(tbl, x.shape) if name == "tbl" else x

        cache = jtu.tree_map_with_path(set_tbl, cache)
        logits, cache = prefill_lm(params, toks_in, cache, cfg)
        lps, toks = [jax.nn.log_softmax(logits[:, :cfg.vocab_size])], []
        for t in range(steps):
            tok = (jnp.argmax(logits, -1).astype(jnp.int32)
                   if forced is None else forced[t])
            toks.append(tok)
            logits, cache = decode_step_lm(
                params, cache, tok, jnp.full((B,), plen + t), cfg)
            lps.append(jax.nn.log_softmax(logits[:, :cfg.vocab_size]))
        return jnp.stack(lps), toks

    lp_f, forced = probe("", None)
    lp_q, _ = probe("int8", forced)
    drift = float(jnp.max(jnp.abs(lp_q - lp_f)))
    report("quant_max_logprob_drift", drift,
           f"teacher-forced, {steps} decode steps")

    return {
        "kv_budget_bytes": int(budget_bytes),
        "bytes_per_token_native": float(bpt_f),
        "bytes_per_token_int8": float(bpt_q),
        "pool_tokens_native": budget_tokens, "pool_tokens_int8": pool_q,
        "page_size": page, "n_requests": n_req,
        "request_prompt_len": p_len, "new_tokens": n_new,
        "concurrent_native": eng_f.peak_active,
        "concurrent_int8": eng_q.peak_active,
        "concurrency_ratio": ratio,
        "wall_s_native": t_native, "wall_s_int8": t_int8,
        "max_logprob_drift": drift,
    }


def _bench_serve(report, smoke: bool) -> dict:
    """Mixed varlen step vs sequential prefill-then-decode (DESIGN.md §3.5).

    The tracked workload is decode-heavy with a LONG-PROMPT ARRIVAL: a
    queue of short prompts (which decode for a while) with one long prompt
    in the middle. The sequential engines run the long prompt's whole
    prefill as one blocking dispatch when a slot frees — every decoding
    sequence stalls and everything queued behind it waits; the mixed
    engine drips the prompt in `prefill_chunk`-token pieces interleaved
    with decode rows. Tracked signals: per-request time-to-first-token
    (engine.ttft, recorded by the shared Scheduler) and total tokens/s,
    for the contiguous and paged sequential engines vs the mixed engine.
    All three must be token-identical (asserted here, greedy)."""
    import dataclasses as _dc

    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.serve import Engine, ServeConfig

    cfg = _dc.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if smoke:
        short_len, long_len, n_short, n_new = 6, 256, 5, 16
        slots, max_len = 2, 288
        pchunk = 64
    else:
        short_len, long_len, n_short, n_new = 8, 512, 7, 32
        slots, max_len = 2, 576
        pchunk = 64
    rng = np.random.default_rng(0)
    shorts = [rng.integers(0, cfg.vocab_size, (short_len,)).astype(np.int32)
              for _ in range(n_short)]
    long_p = rng.integers(0, cfg.vocab_size, (long_len,)).astype(np.int32)
    mid = n_short // 2
    reqs = shorts[:mid] + [long_p] + shorts[mid:]
    long_rid = mid

    # prefix_cache off: this bench re-serves the same queue for jit
    # warm-up, and the tracked signal is chunked-prefill interleaving on
    # COLD prompts — warm-hit prefill skipping is BENCH_prefix.json's job
    common = dict(max_batch=slots, max_len=max_len, temperature=0.0,
                  prefix_cache=False)
    engines = {
        "contiguous_sequential": ServeConfig(**common),
        "paged_sequential": ServeConfig(**common, kv_layout="paged"),
        "mixed": ServeConfig(
            **common, step_mode="mixed",
            prefill_chunk=pchunk, token_budget=slots + pchunk,
        ),
    }
    out: dict = {"workload": {
        "n_short": n_short, "short_len": short_len, "long_len": long_len,
        "long_rid": long_rid, "new_tokens": n_new, "slots": slots,
        "max_len": max_len,
    }, "engines": {}}
    tokens_ref = None
    for name, sc in engines.items():
        # jit caches live on the Engine instance, so the warm-up and the
        # timed call must share one engine: serve() rebuilds its scheduler
        # state per call, making a re-serve of the same queue valid
        eng2 = Engine(params, cfg, sc)
        eng2.serve(reqs, n_new)  # warm-up: compile every bucket
        t0 = time.perf_counter()
        outs = eng2.serve(reqs, n_new)
        wall = time.perf_counter() - t0
        if tokens_ref is None:
            tokens_ref = outs
        else:  # the acceptance contract: all three token-identical
            assert all(np.array_equal(a, b) for a, b in zip(tokens_ref, outs))
        ttft = [eng2.ttft[r] for r in sorted(eng2.ttft)]
        after_long = [eng2.ttft[r] for r in range(long_rid + 1, len(reqs))]
        row = {
            "wall_s": wall,
            "tokens_per_sec": sum(map(len, outs)) / wall,
            "ttft_mean_s": float(np.mean(ttft)),
            "ttft_max_s": float(np.max(ttft)),
            "ttft_long_prompt_s": eng2.ttft[long_rid],
            "ttft_after_long_mean_s": float(np.mean(after_long)),
            "ttft_s": ttft,
        }
        out["engines"][name] = row
        report(f"serve_{name}_tok_per_s", row["tokens_per_sec"], f"T={n_new}")
        report(f"serve_{name}_ttft_mean_s", row["ttft_mean_s"],
               f"after_long={row['ttft_after_long_mean_s']:.3f}s "
               f"max={row['ttft_max_s']:.3f}s")
    ratio = (out["engines"]["mixed"]["ttft_mean_s"]
             / out["engines"]["paged_sequential"]["ttft_mean_s"])
    report("serve_mixed_vs_sequential_ttft", ratio,
           "mean-TTFT ratio under long-prompt arrival (<1 is the win)")
    return out


def _bench_spec(report, smoke: bool) -> dict:
    """Speculative decoding through the packed verify step (DESIGN.md §3.9).

    Decode-heavy workload (short prompts, long generations — the regime
    speculation targets): a non-speculative mixed engine is the baseline,
    then the same queue runs with spec_tokens=K drafts verified per round.
    `OracleDraft` dials acceptance exactly (it corrupts the known greedy
    continuation per-token with a seeded rate), so the sweep shows decode
    tokens/s as a function of acceptance — the top of the sweep is the
    tracked ≥2× signal, the bottom bounds the rejection-rollback overhead.
    Token identity vs the non-speculative output is ASSERTED at every
    acceptance point (greedy: speculation must never change the stream),
    and a self-draft row (the target as its own draft, acceptance 1.0 by
    construction) pins the end-to-end DraftModel device path."""
    import dataclasses as _dc

    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.serve import Engine, OracleDraft, ServeConfig

    cfg = _dc.replace(
        paper_llama.CONFIG, n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=512, head_dim=32, vocab_size=128,
        vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    # the TRACKED point is single-stream (max_batch=1): the latency-bound
    # regime speculation exists for — a lone sequence leaves the hardware
    # idle between sequential decode steps, and a verify round turns K+1
    # of those steps into one parallel dispatch. A batched point rides
    # along (reported, ungated): batching already fills the device, so
    # the margin there is structurally thinner.
    spec_k = 15
    if smoke:
        n_reqs, plen, n_new = 2, 8, 48
    else:
        n_reqs, plen, n_new = 3, 8, 64
    max_len = plen + n_new + spec_k + 2
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)
            for _ in range(n_reqs)]
    out: dict = {
        "workload": {"n_reqs": n_reqs, "prompt_len": plen,
                     "new_tokens": n_new, "spec_tokens": spec_k},
        "points": {},
    }

    def timed(eng):
        eng.serve(reqs, n_new)  # warm-up: compile every bucket
        t0 = time.perf_counter()
        outs = eng.serve(reqs, n_new)
        wall = time.perf_counter() - t0
        return outs, wall, sum(map(len, outs)) / wall

    top_row = None
    for slots in (1, 2):
        common = dict(max_batch=slots, max_len=max_len, temperature=0.0,
                      step_mode="mixed", prefix_cache=False)
        ref, base_wall, base_tps = timed(
            Engine(params, cfg, ServeConfig(**common))
        )
        point = {"baseline_tokens_per_sec": base_tps,
                 "baseline_wall_s": base_wall, "sweep": []}
        out["points"][f"slots_{slots}"] = point
        report(f"spec_b{slots}_baseline_tok_per_s", base_tps,
               f"T={n_new} no speculation")

        def spec_row(label, draft):
            eng = Engine(params, cfg,
                         ServeConfig(**common, spec_tokens=spec_k),
                         draft=draft)
            outs, wall, tps = timed(eng)
            for i, (a, b) in enumerate(zip(ref, outs)):  # identity contract
                assert np.array_equal(a, b), f"{label}: req {i} diverged"
            s = eng.stats()
            row = {
                "draft": label,
                "wall_s": wall,
                "tokens_per_sec": tps,
                "speedup": tps / base_tps,
                "acceptance_rate": s["spec_acceptance_rate"],
                "mean_accepted_per_round": s["spec_mean_accepted"],
                "rounds": s["spec_rounds"],
                "token_identical": True,
            }
            point["sweep"].append(row)
            report(f"spec_b{slots}_{label}_tok_per_s", tps,
                   f"acc={row['acceptance_rate']:.2f} "
                   f"speedup={row['speedup']:.2f}x")
            return row

        top = spec_row("oracle_acc_1.00",
                       OracleDraft(reqs, ref, cfg.vocab_size, accuracy=1.0))
        for acc in (0.75, 0.5):
            spec_row(f"oracle_acc_{acc:.2f}",
                     OracleDraft(reqs, ref, cfg.vocab_size,
                                 accuracy=acc, seed=1))
        if slots == 1:
            top_row = top
            spec_row("self_draft", (params, cfg))
    # the tracked acceptance bar, on the single-stream point: a
    # fully-accepted K-chain commits K+1 tokens per dispatch where the
    # baseline pays K+1 sequential steps — ≥2× decode throughput,
    # token-identical (measured margin is ~5-10×; 2 is the alarm line)
    assert top_row["speedup"] >= 2.0, (
        f"speculative decode speedup {top_row['speedup']:.2f}x < 2x at "
        f"acceptance {top_row['acceptance_rate']:.2f}"
    )
    report("spec_top_speedup", top_row["speedup"],
           ">=2x required at full acceptance, single stream, "
           "token-identical")
    return out


def _bench_chaos(report, smoke: bool) -> dict:
    """Serving under chaos injection (DESIGN.md §3.7).

    One request batch served at fault rates 0% / 5% / 20% (fresh engine
    per rate, same seed → deterministic). Tracked signals per rate:
    goodput (fraction of requests ending DONE), retries charged, wall
    time, and p99 TTFT. The lifecycle contract is ASSERTED, not just
    reported: every request terminal at every rate, survivors
    token-identical to the fault-free run, and goodput degrades
    gracefully (1.0 at rate 0, ≥ 0.5 at rate 0.2) instead of collapsing.
    """
    import dataclasses as _dc

    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.serve import Engine, FaultInjector, ServeConfig

    cfg = _dc.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if smoke:
        n_req, p_len, n_new, slots = 6, 8, 8, 2
    else:
        n_req, p_len, n_new, slots = 8, 12, 16, 2
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, (p_len,)).astype(np.int32)
            for _ in range(n_req)]
    sc = ServeConfig(max_batch=slots, max_len=p_len + n_new + 8,
                     kv_layout="paged", page_size=8, max_retries=5)

    out: dict = {"workload": {"n_requests": n_req, "prompt_len": p_len,
                              "new_tokens": n_new, "slots": slots,
                              "max_retries": sc.max_retries},
                 "rates": {}}
    baseline = None
    for rate in (0.0, 0.05, 0.20):
        inj = FaultInjector(rate=rate, seed=0) if rate > 0 else None
        eng = Engine(params, cfg, sc, fault_injector=inj)
        t0 = time.perf_counter()
        outs = eng.serve(reqs, n_new)
        wall = time.perf_counter() - t0
        st = eng.stats()
        status = st["request_status"]
        assert all(s in ("done", "failed", "expired")
                   for s in status.values()), status  # all terminal
        if baseline is None:
            baseline = outs
        for i, o in enumerate(outs):  # survivors token-identical
            if status[i] == "done":
                assert np.array_equal(baseline[i], o), (rate, i)
        eng._alloc.check()
        goodput = sum(s == "done" for s in status.values()) / n_req
        ttft = sorted(eng.ttft.values())
        p99 = float(ttft[min(len(ttft) - 1,
                             int(np.ceil(0.99 * len(ttft))) - 1)]) if ttft else 0.0
        row = {
            "goodput": goodput,
            "done": sum(s == "done" for s in status.values()),
            "failed": st["failed"], "expired": st["expired"],
            "retries": st["retried"],
            "faults_fired": st.get("injected_faults", {}),
            "wall_s": wall,
            "tokens_per_sec": sum(map(len, outs)) / wall,
            "ttft_p99_s": p99,
        }
        out["rates"][f"{rate:.2f}"] = row
        report(f"chaos_rate{int(rate * 100):02d}_goodput", goodput,
               f"{row['done']}/{n_req} done, {row['retries']} retries, "
               f"p99 TTFT {p99:.3f}s")
    assert out["rates"]["0.00"]["goodput"] == 1.0
    assert out["rates"]["0.20"]["goodput"] >= 0.5, out["rates"]["0.20"]
    return out


def _bench_prefix(report, smoke: bool) -> dict:
    """Radix prefix cache + preemptive scheduling (DESIGN.md §3.6).

    Two tracked signals on a multi-turn chat workload (every request
    replays a shared system prompt):

      1. warm-hit TTFT — the engine's radix tree persists across serve()
         calls, so the second turn's prefill starts at the first uncached
         token. Acceptance bar: warm TTFT ≤ 0.5 × cold TTFT (asserted —
         on real shapes the ratio is prompt_len / tail_len, far below).
      2. oversubscription — a pool SMALLER than the worst-case demand of
         a mixed-priority burst completes via victim preemption with
         tokens IDENTICAL to the unconstrained engine (asserted), at the
         reported tokens/s and preemption count.
    """
    import dataclasses as _dc

    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.serve import Engine, ServeConfig

    cfg = _dc.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if smoke:
        sys_len, user_len, n_new, page = 96, 8, 8, 8
    else:
        sys_len, user_len, n_new, page = 512, 16, 16, 16
    max_len = sys_len + 3 * (user_len + n_new) + 2 * page
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size, (sys_len,)).astype(np.int32)

    def user():
        return rng.integers(0, cfg.vocab_size, (user_len,)).astype(np.int32)

    sc = ServeConfig(max_batch=2, max_len=max_len, temperature=0.0,
                     kv_layout="paged", page_size=page)
    eng = Engine(params, cfg, sc)
    # compile warm-up on same-shape, different-content traffic (its pages
    # land in the cache but can never match the measured system prompt)
    wsys = rng.integers(0, cfg.vocab_size, (sys_len,)).astype(np.int32)
    wturn1 = np.concatenate([wsys, user()])
    wout = eng.serve([wturn1], n_new)
    eng.serve([np.concatenate([wsys, user()])], n_new)
    # a warm-up CONVERSATION turn, so the measured turn-2 tail bucket
    # (cached prior turn + fresh user message) is compiled too
    eng.serve([np.concatenate([wturn1, wout[0], user()])], n_new)

    turn1 = np.concatenate([system, user()])
    out1 = eng.serve([turn1], n_new)
    t_cold = eng.ttft[0]
    warm_prompt = np.concatenate([system, user()])
    out_warm = eng.serve([warm_prompt], n_new)
    t_warm = eng.ttft[0]
    # multi-turn: the follow-up replays the ENTIRE first conversation
    turn2 = np.concatenate([turn1, out1[0], user()])
    eng.serve([turn2], n_new)
    t_turn2 = eng.ttft[0]
    st = eng.stats()
    assert st["hit_tokens"] > 0, "the warm turns must hit the cache"
    assert t_warm <= 0.5 * t_cold, (
        f"warm-hit TTFT {t_warm:.4f}s not ≤ 0.5× cold {t_cold:.4f}s"
    )
    # warm tokens must equal a cold (cache-off) engine's for the same prompt
    cold_eng = Engine(params, cfg, _dc.replace(sc, prefix_cache=False))
    out_cold = cold_eng.serve([warm_prompt], n_new)
    assert np.array_equal(out_warm[0], out_cold[0]), (
        "warm-hit tokens must match the cold engine"
    )
    report("prefix_cold_ttft_s", t_cold, f"system={sys_len} user={user_len}")
    report("prefix_warm_ttft_s", t_warm,
           f"ratio={t_warm / t_cold:.3f} (≤0.5 bar) "
           f"hit_rate={st['hit_rate']:.2f}")
    report("prefix_turn2_ttft_s", t_turn2,
           "full prior conversation replayed from cache")

    # --- oversubscription: mixed priorities, pool < worst-case demand.
    # Every request is admitted at once (optimistic per-chunk allocation),
    # the shared system prompt is cached once, and the pool is sized so
    # concurrent tail GROWTH still overflows it — page pressure that only
    # victim preemption can resolve.
    n_req = slots = 6
    reqs = [np.concatenate([system, user()]) for _ in range(n_req)]
    prios = [i % 2 for i in range(n_req)]
    ample = Engine(params, cfg, _dc.replace(sc, max_batch=slots))
    t0 = time.perf_counter()
    want = ample.serve(reqs, n_new, priorities=prios)
    t_ample = time.perf_counter() - t0
    # worst case: n_req × ⌈(sys+user+new)/page⌉ pages; grant the shared
    # system prompt once plus one page of headroom per request
    shared_pages = sys_len // page
    tight_pages = shared_pages + n_req + 1
    worst_pages = n_req * (-(-(sys_len + user_len + n_new) // page))
    assert tight_pages < worst_pages
    tight = Engine(params, cfg, _dc.replace(
        sc, max_batch=slots, kv_pool_tokens=tight_pages * page))
    t0 = time.perf_counter()
    got = tight.serve(reqs, n_new, priorities=prios)
    t_tight = time.perf_counter() - t0
    assert all(np.array_equal(a, b) for a, b in zip(want, got)), (
        "oversubscribed run must stay token-identical"
    )
    stt = tight.stats()
    toks = sum(map(len, got))
    report("prefix_oversub_tok_per_s", toks / t_tight,
           f"pool={tight_pages}p vs worst-case {worst_pages}p, "
           f"preemptions={stt['preemptions']}, "
           f"ample={toks / t_ample:.1f} tok/s")
    return {
        "workload": {
            "system_len": sys_len, "user_len": user_len,
            "new_tokens": n_new, "page_size": page,
        },
        "cold_ttft_s": t_cold,
        "warm_ttft_s": t_warm,
        "warm_over_cold": t_warm / t_cold,
        "turn2_ttft_s": t_turn2,
        "hit_rate": st["hit_rate"],
        "hit_tokens": st["hit_tokens"],
        "oversubscription": {
            "n_requests": n_req, "slots": slots,
            "pool_pages": tight_pages, "worst_case_pages": worst_pages,
            "priorities": prios,
            "tokens_per_sec_tight": toks / t_tight,
            "tokens_per_sec_ample": toks / t_ample,
            "preemptions": stt["preemptions"],
            "evictions": stt["evictions"],
            "token_identical": True,
        },
    }


def _bench_decode(report, smoke: bool) -> dict:
    """Decode fast path: fused vs unfused split-K kernel, and the jitted
    scan engine vs the seed-style per-token host loop."""
    from repro.kernels.flashd_decode import flashd_decode_pallas

    out: dict = {"kernel": [], "engine": {}}

    # --- kernel: fused (in-VMEM merge) vs unfused (HBM partials + host merge)
    b, hq, hkv, s, d = (1, 2, 1, 64, 16) if smoke else (2, 8, 2, 512, 64)
    n_splits = 2 if smoke else 8
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    cl = jnp.full((b,), s, jnp.int32)
    for fused in (True, False):
        f = jax.jit(
            lambda q, k, v, c, fused=fused: flashd_decode_pallas(
                q, k, v, c, n_splits=n_splits, fused=fused,
                interpret=jax.devices()[0].platform != "tpu",
            )
        )
        us = _bench(f, q, kc, vc, cl)
        tag = "fused" if fused else "unfused"
        report(f"decode_kernel_{tag}", us, f"b={b} s={s} splits={n_splits}")
        out["kernel"].append({
            "variant": tag, "batch": b, "heads": hq, "kv_heads": hkv,
            "cache_len": s, "head_dim": d, "n_splits": n_splits,
            "us_per_call": us,
        })

    # --- engine: jitted scan loop vs per-token host loop (the seed path)
    from repro.configs import paper_llama
    from repro.models import get_model
    from repro.models.transformer import prefill_lm
    from repro.serve import Engine, ServeConfig, sample_token

    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    bsz, n_new = (2, 8) if smoke else (4, 32)
    sc = ServeConfig(max_len=64, temperature=0.0)
    eng = Engine(params, cfg, sc)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (bsz, 8)
    ).astype(np.int32)

    scan_s = _bench(lambda: eng.generate(prompts, n_new), iters=3) * 1e-6

    prefill_j = jax.jit(lambda p, t, c: prefill_lm(p, t, c, cfg))

    def legacy_generate():
        """The seed engine's loop: one dispatch + one blocking np.asarray
        host sync per token."""
        cache = api.init_cache(bsz, sc.max_len, cfg)
        logits, cache = prefill_j(params, jnp.asarray(prompts, jnp.int32), cache)
        pos = jnp.full((bsz,), prompts.shape[1], jnp.int32)
        key = jax.random.PRNGKey(0)
        tok = sample_token(logits, key, sc)
        outs = []
        for _ in range(n_new):
            outs.append(np.asarray(tok))  # per-token host sync
            logits, cache = eng._decode(params, cache, tok, pos)
            pos = pos + 1
            key, k = jax.random.split(key)
            tok = sample_token(logits, k, sc)
        return np.stack(outs, axis=1)

    loop_s = _bench(legacy_generate, iters=3) * 1e-6

    tok_scan = bsz * n_new / scan_s
    tok_loop = bsz * n_new / loop_s
    report("decode_engine_scan_tok_per_s", tok_scan, f"b={bsz} T={n_new}")
    report("decode_engine_loop_tok_per_s", tok_loop, "seed per-token path")
    report("decode_engine_speedup", tok_scan / tok_loop,
           "jitted scan vs per-token host loop (>1 is a win)")
    out["engine"] = {
        "batch": bsz, "new_tokens": n_new,
        "tokens_per_sec_scan": tok_scan,
        "tokens_per_sec_seed_loop": tok_loop,
        "speedup": tok_scan / tok_loop,
    }
    return out
