"""§Roofline table — reads dryrun_results.json and emits per-cell terms.

One row per (arch × shape × mesh): the three roofline times (seconds),
dominant term, MODEL_FLOPS/HLO ratio, memory/device. This is the benchmark
the §Perf hillclimb iterates against (EXPERIMENTS.md).
"""

from __future__ import annotations

import json
import os

DEFAULT_PATHS = ("dryrun_results.json", "/root/repo/dryrun_results.json")


def load_results(path=None):
    for p in ([path] if path else DEFAULT_PATHS):
        if p and os.path.exists(p):
            with open(p) as f:
                return json.load(f)
    return []


def run(report):
    results = load_results()
    if not results:
        report("roofline_missing", 0.0,
               "run `python -m repro.launch.dryrun` first to populate dryrun_results.json")
        return
    n_ok = n_skip = n_err = 0
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        cell = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        if r["status"] == "skipped":
            n_skip += 1
            report(f"roofline_{cell}", 0.0, f"SKIPPED: {r['reason'][:90]}")
            continue
        if r["status"] != "ok":
            n_err += 1
            report(f"roofline_{cell}", 0.0, f"ERROR: {r.get('error','?')[:90]}")
            continue
        n_ok += 1
        rl = r["roofline"]
        t_dom = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
        mem = (r.get("memory") or {}).get("total_bytes_per_device", 0) / 2 ** 30
        report(
            f"roofline_{cell}",
            t_dom * 1e6,
            f"tc={rl['t_compute']*1e3:.2f}ms tm={rl['t_memory']*1e3:.2f}ms "
            f"tx={rl['t_collective']*1e3:.2f}ms dom={rl['dominant']} "
            f"useful={rl['useful_flops_ratio']:.2f} mem={mem:.1f}GiB",
        )
    report("roofline_summary", float(n_ok), f"ok={n_ok} skipped={n_skip} errors={n_err}")
