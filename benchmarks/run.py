"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract):
  fig45_opcounts  — Figs. 4/5 analog: FA2 vs FLASH-D datapath accounting
  table1_skiprate — Table I analog: skip rates on a trained model
  kernel_bench    — wall-time / HLO parity of the attention impls
  roofline_bench  — §Roofline table from the dry-run artifacts
"""

import csv
import io
import sys


def main(argv=None) -> None:
    out = csv.writer(sys.stdout)
    out.writerow(["name", "us_per_call", "derived"])

    def report(name, value, derived=""):
        out.writerow([name, f"{value:.4f}", derived])
        sys.stdout.flush()

    from benchmarks import fig45_opcounts, kernel_bench, roofline_bench, table1_skiprate

    mods = {
        "fig45_opcounts": fig45_opcounts,
        "kernel_bench": kernel_bench,
        "table1_skiprate": table1_skiprate,
        "roofline_bench": roofline_bench,
    }
    names = (argv if argv is not None else sys.argv[1:]) or list(mods)
    for name in names:
        if name not in mods:
            raise SystemExit(f"unknown benchmark {name!r}; options: {list(mods)}")
        mods[name].run(report)


if __name__ == "__main__":
    main()
