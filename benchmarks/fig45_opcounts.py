"""Figs. 4/5 analog — FA2 vs FLASH-D datapath accounting.

The paper synthesizes both kernels at 28 nm and reports −22.8% area / −20.3%
power on average. Silicon synthesis isn't reproducible here; the underlying
driver is the per-step datapath op inventory (paper §IV-A):

  FA2      : two vector multipliers + adder, max unit, ℓ datapath
             (2 mult + FMA), two exp units, final vector divider
  FLASH-D  : ONE vector multiplier + adder + subtractor (Eq. 12 FMA form),
             sigmoid + ln PWL units, no max, no ℓ, no divider

We count per-(key,query)-step ops for hidden dims d ∈ {16, 64, 256} and
weight them with standard relative FP-op area costs (mult = 1.0/elem,
add/sub = 0.35, div = 3.0, cmp/max = 0.15, PWL nonlinearity = 1.35 —
one mult + one add + segment select, per §IV-B's 8-segment design;
weights from published FPU synthesis ratios — bf16 multiplier-relative).
The derived column is FLASH-D's reduction vs FA2, the quantity Figs. 4/5
measure post-synthesis. Also reported: the tile-level carried-state saving
(FA2 carries (m, ℓ), FLASH-D carries Λ only) that drives the TPU kernel's
VMEM/register footprint (DESIGN.md §2.2).
"""

from __future__ import annotations

W_MULT, W_ADD, W_DIV, W_CMP, W_PWL = 1.0, 0.35, 3.0, 0.15, 1.35


def _shared_dot(d: int) -> float:
    return d * W_MULT + (d - 1) * W_ADD


def fa2_step_cost(d: int, n_amortize: int = 1024) -> float:
    c = _shared_dot(d)
    c += W_CMP  # m update (max)
    c += 2 * W_PWL  # exp(m−m'), exp(s−m')
    c += 2 * W_MULT + W_ADD  # ℓ ← ℓα + p
    c += 2 * d * W_MULT + d * W_ADD  # o ← o·α + v·p
    c += (d * W_DIV) / n_amortize  # final o/ℓ, amortized over N steps
    return c


def flashd_step_cost(d: int) -> float:
    c = _shared_dot(d)
    c += 2 * W_ADD  # sigmoid argument s_i − s_{i−1} + ln w
    c += W_PWL  # sigmoid PWL (division hidden inside)
    c += W_PWL  # ln PWL for the next step's argument
    c += d * W_ADD + d * W_MULT + d * W_ADD  # Eq. 12: o + (v − o)·w
    return c


def run(report):
    for d in (16, 64, 256):
        fa2 = fa2_step_cost(d)
        fld = flashd_step_cost(d)
        red = 100.0 * (1.0 - fld / fa2)
        report(
            f"fig4_area_proxy_d{d}", fld,
            f"fa2={fa2:.1f} flashd={fld:.1f} reduction={red:.1f}% "
            f"(paper: 20-28% across formats)",
        )
    # dynamic-power proxy: ops × activity; identical activity ⇒ same ratio,
    # minus the ℓ/m register toggling FLASH-D removes (2 fewer live scalars)
    for d in (16, 64, 256):
        fa2 = fa2_step_cost(d) + 2 * W_ADD  # ℓ,m register writes/toggles
        fld = flashd_step_cost(d) + 1 * W_ADD  # ln w register
        red = 100.0 * (1.0 - fld / fa2)
        report(
            f"fig5_power_proxy_d{d}", fld,
            f"reduction={red:.1f}% (paper: 16-27%)",
        )
    # tile-level carried state (TPU kernel, per q-row, f32 scalars)
    report("tile_carry_fa2", 2.0, "m + l row-vectors in VMEM scratch")
    report("tile_carry_flashd", 1.0, "Λ only — 50% scratch-row saving, no epilogue pass")
