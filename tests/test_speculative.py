"""Speculative decoding (DESIGN.md §3.9): greedy token identity, page
rollback soundness, and draft-slot scheduling.

The one invariant everything here pins: speculative serving is
TOKEN-IDENTICAL to non-speculative greedy serving at ANY acceptance rate
— the draft only ever proposes, the target's argmax at every verify row
decides. `OracleDraft` makes acceptance a controlled dial (it corrupts
the known reference continuation per-token with a seeded probability), so
the property sweeps the whole rollback spectrum from 100 % accepted
(self-draft) to 0 % (adversarial junk) across both serving loops
(paged / mixed), both kernels (jnp / pallas varlen), and both KV dtypes
(native / int8).

Memory-soundness side: after every rejection rollback the allocator's
full invariant check must pass (refcount conservation, reservation
accounting), and the radix prefix tree must never index a page holding
unaccepted draft KV — every cached chain stays a prefix of some
request's COMMITTED token stream.

Runs on the real `hypothesis` when installed and on the deterministic
stub in `tests/conftest.py` otherwise (CI exercises both).
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import paper_llama
from repro.kernels.tuning import choose_varlen_blocks, padded_rows
from repro.models import get_model
from repro.runtime.kvcache import PagedKVAllocator, PageError
from repro.serve import (
    DONE,
    EXPIRED,
    TERMINAL,
    Engine,
    FaultInjector,
    OracleDraft,
    Scheduler,
    ServeConfig,
)

N_NEW = 8
MODES = ("paged", "mixed")


def _cfg(**kw):
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64, **kw,
    )


def _sc(mode: str, **kw) -> ServeConfig:
    base = dict(max_batch=2, max_len=64, temperature=0.0,
                kv_layout="paged", page_size=8)
    if mode == "mixed":
        base.update(step_mode="mixed")
    base.update(kw)
    return ServeConfig(**base)


@pytest.fixture(scope="module")
def spec_fixture():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9, 13, 7)]
    baselines = {
        mode: Engine(params, cfg, _sc(mode)).serve(prompts, N_NEW)
        for mode in MODES
    }
    return cfg, params, prompts, baselines


# ---------------------------------------------------------------------------
# the core property: spec == non-spec greedy, at any acceptance rate
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    accuracy=st.floats(min_value=0.0, max_value=1.0),
    mode=st.sampled_from(MODES),
    kv_dtype=st.sampled_from(["", "int8"]),
    k=st.integers(min_value=1, max_value=4),
)
def test_spec_token_identity(spec_fixture, seed, accuracy, mode, kv_dtype, k):
    """Any draft accuracy, either serving loop, either KV dtype: the
    speculative output equals the non-speculative greedy output token for
    token, and the allocator invariants hold afterwards. (int8 identity
    is vs the int8 NON-spec baseline — quantization changes tokens, the
    speculation must not change them further.)"""
    cfg, params, prompts, baselines = spec_fixture
    ref = (baselines[mode] if not kv_dtype else
           Engine(params, cfg, _sc(mode, kv_dtype=kv_dtype))
           .serve(prompts, N_NEW))
    oracle = OracleDraft(prompts, ref, cfg.vocab_size,
                         accuracy=accuracy, seed=seed)
    eng = Engine(params, cfg,
                 _sc(mode, kv_dtype=kv_dtype, spec_tokens=k), draft=oracle)
    outs = eng.serve(prompts, N_NEW)
    for i, (a, b) in enumerate(zip(ref, outs)):
        np.testing.assert_array_equal(a, b, err_msg=f"req {i}")
    eng._alloc.check(eng._paged_cache)
    s = eng.stats()
    assert s["spec_drafted"] == s["spec_accepted"] + s["spec_rejected"]
    if accuracy == 1.0:
        assert s["spec_acceptance_rate"] == 1.0
    if accuracy == 0.0 and s["spec_drafted"] > 0:
        assert s["spec_accepted"] == 0


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("attn_impl", ["flashd", "flashd_pallas"])
def test_self_draft_identity(spec_fixture, mode, attn_impl):
    """Target-as-its-own-draft accepts every token (the draft IS the
    target), so acceptance is exactly 1.0 and output is still identical —
    under both the jnp varlen mirror and the Pallas kernel."""
    cfg0, _, prompts, _ = spec_fixture
    cfg = dataclasses.replace(cfg0, attn_impl=attn_impl)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    ref = Engine(params, cfg, _sc(mode)).serve(prompts, N_NEW)
    eng = Engine(params, cfg, _sc(mode, spec_tokens=3), draft=(params, cfg))
    outs = eng.serve(prompts, N_NEW)
    for a, b in zip(ref, outs):
        np.testing.assert_array_equal(a, b)
    s = eng.stats()
    assert s["spec_acceptance_rate"] == 1.0 and s["spec_rounds"] > 0
    # the whole point: each verify round commits > 1 token on average
    assert s["spec_mean_accepted"] > 0
    eng._alloc.check(eng._paged_cache)


def test_draft_with_mismatched_vocab_is_safe(spec_fixture):
    """A draft proposing ids outside the target's vocab (a real
    vocab-mismatched draft model) must not corrupt output: OOB ids are
    clamped before they can embed (an unclamped OOB `jnp.take` fills NaN
    and would poison the whole packed step) and acceptance compares
    against the clamped id actually fed — so the stream stays
    token-identical whatever the draft proposes."""
    cfg, params, prompts, baselines = spec_fixture

    def junk_draft(rid, tokens, kk):
        return np.full((kk,), cfg.vocab_size + 1000, np.int32)

    eng = Engine(params, cfg, _sc("mixed", spec_tokens=3), draft=junk_draft)
    outs = eng.serve(prompts, N_NEW)
    for a, b in zip(baselines["mixed"], outs):
        np.testing.assert_array_equal(a, b)
    s = eng.stats()
    assert s["spec_drafted"] > 0  # proposals were made and verified


# ---------------------------------------------------------------------------
# memory soundness: rollback invariants + prefix-cache purity
# ---------------------------------------------------------------------------

def test_allocator_invariants_after_every_rollback(spec_fixture, monkeypatch):
    """Run a rejection-heavy serve with the allocator's full invariant
    check wired into EVERY rollback call — refcounts, free-list and
    reservation accounting must be consistent at each intermediate state,
    not just at the end."""
    cfg, params, prompts, baselines = spec_fixture
    calls = []
    orig = PagedKVAllocator.rollback

    def checked(self, seq, new_len):
        freed = orig(self, seq, new_len)
        self.check()
        calls.append(freed)
        return freed

    monkeypatch.setattr(PagedKVAllocator, "rollback", checked)
    for mode in MODES:
        oracle = OracleDraft(prompts, baselines[mode], cfg.vocab_size,
                             accuracy=0.2, seed=3)
        eng = Engine(params, cfg, _sc(mode, spec_tokens=4), draft=oracle)
        outs = eng.serve(prompts, N_NEW)
        for a, b in zip(baselines[mode], outs):
            np.testing.assert_array_equal(a, b)
    assert calls, "a 20%-accuracy draft must trigger rollbacks"
    assert any(f > 0 for f in calls), "some rollback must free whole pages"


def test_radix_tree_never_holds_draft_pages(spec_fixture):
    """After a rejection-heavy serve with the prefix cache on, every
    chain the radix tree indexes is a prefix of some request's COMMITTED
    stream (prompt + emitted tokens) — unaccepted draft KV is freed, never
    donated, so cached bytes stay a pure function of the token stream."""
    cfg, params, prompts, baselines = spec_fixture
    for mode in MODES:
        oracle = OracleDraft(prompts, baselines[mode], cfg.vocab_size,
                             accuracy=0.3, seed=9)
        eng = Engine(params, cfg, _sc(mode, spec_tokens=4), draft=oracle)
        outs = eng.serve(prompts, N_NEW)
        streams = [np.concatenate([p, np.asarray(o, np.int64)])
                   for p, o in zip(prompts, outs)]
        chains = eng._alloc.cached_chains()
        assert chains, "prefix cache should have indexed finished prompts"
        for chain in chains:
            ok = any(len(chain) <= len(s_)
                     and np.array_equal(chain, s_[: len(chain)])
                     for s_ in streams)
            assert ok, f"cached chain {chain} is not a committed prefix"
        # and warm reuse of those chains still serves identically
        outs2 = eng.serve(prompts, N_NEW)
        for a, b in zip(outs, outs2):
            np.testing.assert_array_equal(a, b)
        assert eng.stats()["hit_tokens"] > 0


# ---------------------------------------------------------------------------
# chaos: speculation under injected faults
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    rate=st.floats(min_value=0.05, max_value=0.20),
    mode=st.sampled_from(MODES),
)
def test_chaos_with_speculation(spec_fixture, seed, rate, mode):
    """Seeded fault injection with speculation on: every request reaches
    a terminal state, DONE requests are token-identical to the fault-free
    non-speculative baseline, and the pool invariants hold."""
    cfg, params, prompts, baselines = spec_fixture
    oracle = OracleDraft(prompts, baselines[mode], cfg.vocab_size,
                         accuracy=0.6, seed=seed)
    eng = Engine(params, cfg, _sc(mode, spec_tokens=3), draft=oracle,
                 fault_injector=FaultInjector(rate=rate, seed=seed))
    outs = eng.serve(prompts, N_NEW)
    status = eng.stats()["request_status"]
    assert set(status) == set(range(len(prompts)))
    assert all(s in TERMINAL for s in status.values()), status
    for i, base in enumerate(baselines[mode]):
        if status[i] == DONE:
            np.testing.assert_array_equal(base, outs[i])
        else:
            np.testing.assert_array_equal(base[: len(outs[i])], outs[i])
    eng._alloc.check()


# ---------------------------------------------------------------------------
# deadlines: draft budgeting must not overshoot them
# ---------------------------------------------------------------------------

def test_deadline_expiry_under_speculation(spec_fixture):
    """Deadline checks only run BETWEEN engine steps; with speculation on
    an overdue request must still expire cleanly (status EXPIRED, partial
    output a prefix of the reference) while undeadlined neighbors finish
    token-identically."""
    cfg, params, prompts, baselines = spec_fixture
    for mode in MODES:
        oracle = OracleDraft(prompts, baselines[mode], cfg.vocab_size,
                             accuracy=1.0, seed=0)
        eng = Engine(params, cfg, _sc(mode, spec_tokens=3), draft=oracle)
        outs = eng.serve(prompts, N_NEW, deadlines=[None, 0.0, None, 0.0])
        status = eng.stats()["request_status"]
        assert status[1] == EXPIRED and status[3] == EXPIRED, (mode, status)
        assert status[0] == DONE and status[2] == DONE
        for i in (0, 2):
            np.testing.assert_array_equal(baselines[mode][i], outs[i])
        for i in (1, 3):
            np.testing.assert_array_equal(
                baselines[mode][i][: len(outs[i])], outs[i])
        eng._alloc.check()


def test_draft_quota_clamps():
    """`draft_quota` never lets accepted-prefix + bonus token overshoot
    max_new_tokens, max_len, or — the bugfix — a deadline (quota shrinks
    with remaining slack / measured per-row seconds)."""
    sched = Scheduler([np.asarray([1, 2, 3])], 6, 1, eos_id=-1)
    rid, prompt = sched.take_head()
    sched.admit_prefilling(0, rid, prompt)
    assert sched.draft_quota(0, 4, max_len=32) == 0  # prefilling: no drafts
    plan = sched.plan_step(8, 8)
    sched.commit(plan, np.asarray([5], np.int32))  # prefill done, 1st token
    sl = sched.slots[0]
    assert not sl.prefilling
    # plain clamp: k_max wins when there is room
    assert sched.draft_quota(0, 2, max_len=32) == 2
    # max_new_tokens: 1 emitted, 6 allowed → at most 5 more incl. bonus → 4
    assert sched.draft_quota(0, 10, max_len=32) == 4
    # max_len: kv=3, max_len=5 → one draft row + bonus fills the cache
    assert sched.draft_quota(0, 10, max_len=5) == 1
    assert sched.draft_quota(0, 10, max_len=4) == 0  # no room at all
    # deadline clamp: 0.05 s slack at 0.01 s/row → ≤ 4 rows incl. bonus
    sl.deadline = sched.now() + 0.05
    assert sched.draft_quota(0, 10, max_len=32, per_row_s=0.01) <= 4
    sl.deadline = sched.now() - 1.0  # already overdue → no drafts at all
    assert sched.draft_quota(0, 10, max_len=32, per_row_s=0.01) == 0
    # no per-row estimate yet (first round): deadline can't clamp
    assert sched.draft_quota(0, 2, max_len=32) == 2


def test_plan_step_draft_budgeting():
    """Draft rows are funded LAST from leftover budget, round-robin
    across decode slots — prefill chunks are never starved, and the
    decode floor (one pending row per slot) is always granted."""
    reqs = [np.asarray([1, 2]), np.asarray([3, 4]), np.asarray([5, 6, 7, 8])]
    sched = Scheduler(reqs, 8, 3, eos_id=-1)
    for s in range(3):
        rid, prompt = sched.take_head()
        sched.admit_prefilling(s, rid, prompt)
    # finish slots 0 and 1's prefill so they decode; slot 2 keeps prefilling
    plan = sched.plan_step(4, 2)
    sched.commit(plan, np.asarray([9, 9, 9], np.int32))
    assert not sched.slots[0].prefilling and not sched.slots[1].prefilling
    assert sched.slots[2].prefilling
    drafts = {0: np.asarray([1, 1, 1], np.int32),
              1: np.asarray([2, 2], np.int32)}
    # budget 6 = 2 decode floor + 2 prefill chunk + 2 leftover: the chunk
    # is funded before any draft, leftovers split 1/1 round-robin
    plan = sched.plan_step(6, 2, drafts=drafts)
    by_slot = {g.slot: g for g in plan.segments}
    assert len(by_slot[2].tokens) == 2 and by_slot[2].n_draft == 0
    assert by_slot[0].n_draft == by_slot[1].n_draft == 1
    assert plan.n_tokens == 6
    # verify-segment layout: tokens[0] is the committed pending token
    assert by_slot[0].tokens[0] == sched.slots[0].pending
    assert list(by_slot[0].tokens[1:]) == [1]
    # a fat budget funds every proposed draft but never invents rows
    plan = sched.plan_step(50, 2, drafts=drafts)
    by_slot = {g.slot: g for g in plan.segments}
    assert by_slot[0].n_draft == 3 and by_slot[1].n_draft == 2
    # zero leftover: decode floor + chunk only, drafts all dropped
    plan = sched.plan_step(2, 2, drafts=drafts)
    assert all(g.n_draft == 0 for g in plan.segments)


def test_commit_accept_reject_prefix():
    """`commit` with n_acc applies the longest-accepted-prefix rule: the
    bonus token always lands, acceptance beyond n_draft is clamped, EOS
    inside the accepted prefix truncates, and kv tracks exactly the
    committed tokens so the engine can roll pages back to it."""
    sched = Scheduler([np.asarray([1, 2])] * 2, 10, 2, eos_id=7)
    for s in range(2):
        rid, prompt = sched.take_head()
        sched.admit_prefilling(s, rid, prompt)
    plan = sched.plan_step(8, 4)
    sched.commit(plan, np.asarray([5, 5], np.int32))
    drafts = {0: np.asarray([11, 12, 13], np.int32),
              1: np.asarray([21, 22, 23], np.int32)}
    plan = sched.plan_step(50, 4, drafts=drafts)
    # slot 0: accept 2 drafts + bonus; slot 1: reject at row 0 → bonus only
    g = np.asarray([[11, 12, 33, 0], [44, 0, 0, 0]], np.int32)
    sched.commit(plan, g, n_acc=np.asarray([2, 0]))
    assert sched.slots[0].out[-3:] == [11, 12, 33]
    assert sched.slots[1].out[-1] == 44 and len(sched.slots[1].out) == 2
    # kv = segment start + rows consumed (pending + accepted drafts); the
    # bonus token is the NEW pending — its KV is not in the cache yet
    assert sched.slots[0].kv == 2 + 1 + 2
    assert sched.slots[1].kv == 2 + 1
    assert sched.spec_drafted == 6 and sched.spec_accepted == 2
    # EOS inside the accepted prefix: commits up to EOS, finishes the slot
    drafts = {0: np.asarray([7, 99], np.int32)}
    plan = sched.plan_step(50, 4, drafts=drafts)
    seg = next(gg for gg in plan.segments if gg.slot == 0)
    assert seg.n_draft == 2
    g = np.asarray([[7, 55, 66, 0], [0, 0, 0, 0]], np.int32)
    finished = sched.commit(plan, g, n_acc=np.asarray([2, 0]))
    assert 0 in finished
    assert sched.slots[0].out[-1] == 7  # stopped at EOS, dropped the rest
    assert sched.slots[0].kv == 5 + 1  # only the EOS row consumed


# ---------------------------------------------------------------------------
# small-segment varlen tuning (satellite): K+1-row verify chains must not
# pad to a 128-row tile
# ---------------------------------------------------------------------------

def test_small_segment_block_q_and_row_waste():
    bl = choose_varlen_blocks(
        256, 64, 64, group=2, page=16, segment_hint=5
    )
    assert bl.block_q == 8  # pow2 bucket of 5, floored at the sublane min
    assert padded_rows(5, bl.block_q) - 5 <= 3  # ≤ 3 wasted rows per chain
    # a decode-only hint stays at the floor; a prefill-sized hint does not
    assert choose_varlen_blocks(
        256, 64, 64, group=2, page=16, segment_hint=1
    ).block_q == 8
    assert choose_varlen_blocks(
        512, 64, 64, group=2, page=16, segment_hint=128
    ).block_q >= 64
    # padded_rows: exact multiples don't pad, zero-length packs zero rows
    assert padded_rows(8, 8) == 8
    assert padded_rows(9, 8) == 16
    assert padded_rows(0, 8) == 0


# ---------------------------------------------------------------------------
# allocator rollback unit semantics
# ---------------------------------------------------------------------------

def test_allocator_rollback_unit():
    """rollback() is the inverse of extend(): wholly-past-target pages
    return to the free list AND to the sequence's reservation credit, the
    boundary page survives, and out-of-range targets raise."""
    alloc = PagedKVAllocator(n_pages=9, page_size=4)
    alloc.admit(1, prompt_len=6, reserve_tokens=24)  # 2 pages live, 4 reserved
    free0, res0 = alloc.free_pages, alloc._reserved[1]
    alloc.extend(1, 14)  # grows to 4 pages, funded by 2 reservation credits
    assert alloc._reserved[1] == res0 - 2
    assert alloc.free_pages == free0  # reservation-funded: no net change
    assert alloc.pages_in_use == 4 + 0  # (garbage page has refcount 0)
    freed = alloc.rollback(1, 7)  # back inside page 1: pages 2,3 drop
    assert freed == 2
    assert alloc.free_pages == free0
    assert alloc._reserved[1] == res0  # credits restored with the pages
    assert alloc.pages_in_use == 2
    assert alloc.seq_len(1) == 7 and len(alloc.table(1)) == 2
    alloc.check()
    assert alloc.rollback(1, 7) == 0  # no-op at the boundary
    with pytest.raises(PageError):
        alloc.rollback(1, 8)  # forward rollback is nonsense
    with pytest.raises(PageError):
        alloc.rollback(1, -1)
    with pytest.raises(PageError):
        alloc.rollback(2, 0)  # unknown sequence
    # regrow after rollback: the restored credits fund it again
    alloc.extend(1, 14)
    alloc.rollback(1, 0)  # full rollback drops every page
    assert alloc.seq_len(1) == 0 and alloc.table(1) == []
    alloc.check()


# ---------------------------------------------------------------------------
# configuration gates
# ---------------------------------------------------------------------------

def test_spec_config_validation(spec_fixture):
    cfg, params, _, _ = spec_fixture
    with pytest.raises(ValueError, match="draft"):
        Engine(params, cfg, _sc("mixed", spec_tokens=3))
    with pytest.raises(ValueError, match="greedy"):
        Engine(params, cfg, _sc("mixed", spec_tokens=3, temperature=0.8),
               draft=(params, cfg))
    with pytest.raises(ValueError, match="paged|packed"):
        Engine(params, cfg,
               ServeConfig(max_batch=2, max_len=64, temperature=0.0,
                           spec_tokens=3),
               draft=(params, cfg))
