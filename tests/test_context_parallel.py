"""Context-parallel attention on forced 8-device host meshes (subprocess —
the main test process must keep seeing exactly one device).

Covered: ring_prefill == single-device flash_attention for every mask
family (both jnp and Pallas-interpret per-shard kernels), cp_decode ==
decode_ref on ragged cache_len including shard-empty shards, the wire
contract (per-hop ppermute of one KV shard / (O, Λ)-sized butterfly
messages, no score or cache gather, structured masks prune ring hops),
and the auto-routing through flash_attention / decode_attention / the
serving engine when the active ShardingCtx seq-shards the cache.
"""

import os
import subprocess
import sys
import textwrap

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Shared recursive jaxpr walker for the wire-contract assertions (handles
# both ClosedJaxpr params and the raw Jaxpr that shard_map carries).
_WALK_HELPER = """
def walk(jx, flat):
    for e in jx.eqns:
        flat.append(e)
        for p in e.params.values():
            for pi in (p if isinstance(p, (tuple, list)) else (p,)):
                if hasattr(pi, "jaxpr"):   # ClosedJaxpr
                    walk(pi.jaxpr, flat)
                elif hasattr(pi, "eqns"):  # raw Jaxpr (shard_map param)
                    walk(pi, flat)
    return flat
"""


def _run_in_subprocess(code: str):
    """Run `code` with 8 forced host devices; raise on failure."""
    prog = "import os\nos.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" + _WALK_HELPER + textwrap.dedent(code)
    res = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": os.path.join(_REPO, "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"), "JAX_PLATFORMS": "cpu"},
        cwd=_REPO,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_ring_prefill_matches_single_device():
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.attention import MaskSpec, flash_attention
    from repro.distributed.context import ring_prefill

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    masks = [MaskSpec("causal"), MaskSpec("local", window=13),
             MaskSpec("chunked", chunk=8), MaskSpec("full")]
    for mask in masks:
        o_ref = flash_attention(q, k, v, mask=mask, impl="flashd",
                                block_q=16, block_k=16)
        for impl in ("flashd", "flashd_pallas"):
            o = ring_prefill(q, k, v, axis="data", mesh=mesh, mask=mask, impl=impl)
            assert o.dtype == q.dtype
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(o_ref), rtol=1e-4, atol=1e-5,
                err_msg=f"{mask.kind}/{impl}",
            )
    print("ring_prefill OK")
    """)


def test_ring_prefill_wire_contract():
    """jaxpr-level roofline: each hop exchanges exactly one K and one V
    shard (ppermute), nothing else crosses the wire — no all_gather, no
    [S, S] score-sized collectives — and structured masks prune hops."""
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.attention import MaskSpec
    from repro.distributed.context import ring_prefill
    from repro.kernels.tuning import choose_ring_schedule

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    b, s, hq, hkv, d = 1, 64, 4, 2, 16
    n, s_sh = 8, 64 // 8
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)

    def collectives(mask):
        jaxpr = jax.make_jaxpr(lambda *a: ring_prefill(
            *a, axis="data", mesh=mesh, mask=mask, impl="flashd"))(q, k, v)
        return walk(jaxpr.jaxpr, [])  # walk: shared helper (test harness)

    for mask, want_hops in [
        (MaskSpec("causal"), 8),
        (MaskSpec("local", window=13), 3),   # hop 2 min distance 2·8−7=9 < 13 ⇒ 3 live hops
        (MaskSpec("chunked", chunk=8), 1),   # chunk == shard ⇒ diagonal only
    ]:
        sched = choose_ring_schedule(s_sh, s_sh, d, d, n_devices=n, mask=mask)
        assert sched.n_hops == want_hops, (mask.kind, sched)
        eqns = collectives(mask)
        perms = [e for e in eqns if e.primitive.name == "ppermute"]
        gathers = [e for e in eqns if "all_gather" in e.primitive.name
                   or "all_to_all" in e.primitive.name]
        assert not gathers, gathers
        # one K + one V rotation per hop after the first; every exchanged
        # buffer is exactly one KV shard — never the full sequence
        assert len(perms) == 2 * (want_hops - 1), (mask.kind, len(perms))
        for e in perms:
            shp = e.invars[0].aval.shape
            assert s_sh in shp and s not in shp, shp
    print("wire contract OK")
    """)


def test_cp_decode_matches_ref_ragged():
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.context import cp_decode
    from repro.kernels.ref import decode_ref

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(2)
    b, hq, hkv, S, d = 4, 8, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, S, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, S, hkv, d)), jnp.float32)
    kck, vck = kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3)
    # ragged: full, shard-interior, GLOBALLY EMPTY, and mid — with 8 shards
    # of 8 the rows leave most shards empty (dead partials)
    cl = jnp.asarray([64, 5, 0, 23], jnp.int32)
    for w, c in [(0, 0), (12, 0), (0, 16)]:
        for use_kernel in (True, False):
            o = cp_decode(q, kc, vc, cl, axis="data", mesh=mesh,
                          window=w, chunk=c, use_kernel=use_kernel)
            o_ref = decode_ref(q, kck, vck, cl, window=w, chunk=c)
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(o_ref), rtol=2e-5, atol=2e-5,
                err_msg=f"w={w} c={c} kernel={use_kernel}",
            )
    # butterfly wire: log2(8)=3 rounds x (o, lam) = 6 ppermutes of
    # (O, Λ)-sized messages; no cache-sized exchange
    jaxpr = jax.make_jaxpr(lambda *a: cp_decode(
        *a, axis="data", mesh=mesh, use_kernel=False))(q, kc, vc, cl)
    flat = walk(jaxpr.jaxpr, [])  # walk: shared helper (test harness)
    perms = [e for e in flat if e.primitive.name == "ppermute"]
    assert len(perms) == 6, len(perms)
    for e in perms:
        shp = e.invars[0].aval.shape
        # (O, Λ)-sized only: ≤ B·Hq·dv elements, never a seq-sized dim
        assert int(np.prod(shp)) <= b * hq * d and S not in shp, shp
    assert not any("all_gather" in e.primitive.name for e in flat)
    print("cp_decode OK")
    """)


def test_attention_api_cp_routing():
    """flash_attention / decode_attention select the context-parallel path
    exactly when the ShardingCtx kv_cache rule seq-shards the operands."""
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.attention import MaskSpec, decode_attention, flash_attention
    from repro.distributed import sharding as shd
    from repro.kernels.ref import decode_ref

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(3)
    b, s, hq, hkv, d = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    o_ref = flash_attention(q, k, v, mask=MaskSpec("causal"), impl="flashd")

    ctx = shd.ShardingCtx(mesh, cp_prefill=True)
    with shd.activate(ctx), shd.mesh_ctx(mesh):
        assert shd.cp_axis_for_cache(k.shape) == "data"
        o = flash_attention(q, k, v, mask=MaskSpec("causal"), impl="flashd")
        jx = str(jax.make_jaxpr(lambda *a: flash_attention(
            *a, mask=MaskSpec("causal"), impl="flashd"))(q, k, v))
    assert "ppermute" in jx and "all_gather" not in jx
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-5)
    # cp_prefill defaults OFF: same ctx without the flag keeps GSPMD path
    with shd.activate(shd.ShardingCtx(mesh)), shd.mesh_ctx(mesh):
        jx_off = str(jax.make_jaxpr(lambda *a: flash_attention(
            *a, mask=MaskSpec("causal"), impl="flashd"))(q, k, v))
    assert "ppermute" not in jx_off

    # decode: B=2 doesn't divide data=8 ⇒ the kv_cache rule context-
    # parallels the sequence ⇒ decode_attention routes to cp_decode
    b2 = 2
    qd = jnp.asarray(rng.normal(size=(b2, 1, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b2, s, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b2, s, hkv, d)), jnp.float32)
    cl = jnp.asarray([40, 0], jnp.int32)
    o_ref = decode_ref(qd[:, 0], kc.transpose(0, 2, 1, 3),
                       vc.transpose(0, 2, 1, 3), cl)
    with shd.activate(shd.ShardingCtx(mesh)), shd.mesh_ctx(mesh):
        o = decode_attention(qd, kc, vc, cl)
        jx = str(jax.make_jaxpr(lambda *a: decode_attention(*a))(qd, kc, vc, cl))
    assert "ppermute" in jx
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    print("routing OK")
    """)


def test_cp_decode_batch_and_seq_sharded_mesh():
    """Heads-not-divisible CP on a (data=2, model=4) mesh: the kv_cache
    rule shards batch over 'data' AND seq over 'model'; the cp shard_map
    must keep the batch sharding (specs carry cp_batch_axes_for_cache)
    and still match the reference."""
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.attention import decode_attention
    from repro.distributed import sharding as shd
    from repro.kernels.ref import decode_ref

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(5)
    b, hq, hkv, S, d = 2, 6, 2, 64, 16  # hkv=2 % model=4 != 0 ⇒ seq CP
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, S, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, S, hkv, d)), jnp.float32)
    cl = jnp.asarray([64, 11], jnp.int32)
    o_ref = decode_ref(q[:, 0], kc.transpose(0, 2, 1, 3),
                       vc.transpose(0, 2, 1, 3), cl)
    with shd.activate(shd.ShardingCtx(mesh)), shd.mesh_ctx(mesh):
        assert shd.cp_axis_for_cache(kc.shape) == "model"
        assert shd.cp_batch_axes_for_cache(kc.shape) == ("data",)
        o = decode_attention(q, kc, vc, cl)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    print("batch+seq CP OK")
    """)


def test_paged_decode_routes_through_cp_when_seq_sharded():
    """Paged caches must keep the context-parallel interplay: when the
    active ShardingCtx seq-shards the (gathered) cache, `_paged_attn_step`
    gathers its pages and merges per-shard partials through cp_decode —
    the jaxpr carries ppermutes — and still matches the unsharded paged
    decode step."""
    _run_in_subprocess("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import paper_llama
    from repro.distributed import sharding as shd
    from repro.models import get_model
    from repro.models.transformer import init_decode_cache, prefill_lm

    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, head_dim=8, vocab_size=64, vocab_pad_multiple=32,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, max_len, page = 2, 64, 8
    cache = init_decode_cache(b, max_len, cfg, layout="paged", page_size=page)
    # distinct physical pages per row, every layer mirrors the same table
    tbl = jnp.asarray([np.arange(1, 9), np.arange(9, 17)], jnp.int32)
    cache = jax.tree_util.tree_map_with_path(
        lambda p, x: x.at[:].set(tbl[None]) if any(
            getattr(e, "key", None) == "tbl" for e in p) else x,
        cache,
    )
    prompts = np.random.default_rng(4).integers(0, 64, (b, 6)).astype(np.int32)
    logits_ref, cache_ref = prefill_lm(
        params, jnp.asarray(prompts, jnp.int32), cache, cfg)

    mesh = jax.make_mesh((8,), ("data",))
    with shd.activate(shd.ShardingCtx(mesh)), shd.mesh_ctx(mesh):
        # gathered paged cache is [B=2, 64, 2, 8]: B < data ⇒ seq CP
        assert shd.cp_axis_for_cache((b, max_len, 2, 8)) == "data"
        logits_cp, _ = prefill_lm(
            params, jnp.asarray(prompts, jnp.int32), cache, cfg)
        tok = jnp.asarray(prompts[:, 0])
        pos = jnp.zeros((b,), jnp.int32)
        jx = str(jax.make_jaxpr(lambda p, c, t, z: api.decode_step(
            p, c, t, z, cfg))(params, cache, tok, pos))
    assert "ppermute" in jx  # paged decode merged cross-device, no gather-all
    np.testing.assert_allclose(np.asarray(logits_cp), np.asarray(logits_ref),
                               rtol=1e-4, atol=1e-4)
    print("paged cp OK")
    """)


def test_engine_decode_on_cp_mesh_matches_unsharded():
    """End-to-end: Engine.generate with a sharding ctx whose kv_cache rule
    seq-shards the cache (B < data axis) emits the same tokens as the
    single-device engine — greedy decode is merge-order robust."""
    _run_in_subprocess("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import paper_llama
    from repro.distributed import sharding as shd
    from repro.models import get_model
    from repro.serve import Engine, ServeConfig

    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, head_dim=8, vocab_size=64, vocab_pad_multiple=32,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(4).integers(0, 64, (2, 6)).astype(np.int32)
    sc = ServeConfig(max_len=64, temperature=0.0)

    toks_ref = Engine(params, cfg, sc).generate(prompts, 8)

    mesh = jax.make_mesh((8,), ("data",))
    ctx = shd.ShardingCtx(mesh)  # B=2 < 8 ⇒ seq-sharded caches ⇒ cp_decode
    eng = Engine(params, cfg, sc, sharding_ctx=ctx)
    toks = eng.generate(prompts, 8)
    np.testing.assert_array_equal(toks, toks_ref)
    assert eng.host_syncs == 1  # the one-sync contract survives sharding
    print("engine cp OK")
    """)
