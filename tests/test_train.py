"""Training substrate: loss decreases, accumulation equivalence, compression,
schedules, optimizer behaviour.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_llama
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig, CompressionConfig, warmup_cosine
from repro.optim.compress import compress_gradients, init_residual
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _tiny_cfg():
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )


def _data(cfg, gb=8, s=32):
    return SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=s, global_batch=gb))


def test_loss_decreases():
    cfg = _tiny_cfg()
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=5, total_steps=60)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = _data(cfg)
    losses = []
    for i in range(40):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.3, f"loss did not decrease: {first:.3f} → {last:.3f}"


def test_grad_accum_equivalent():
    """accum_steps=2 over a 2×batch == one step at full batch (same math)."""
    cfg = _tiny_cfg()
    data = _data(cfg, gb=8)
    batch = jax.tree.map(jnp.asarray, data.batch(0))

    tc1 = TrainConfig(accum_steps=1)
    tc2 = TrainConfig(accum_steps=2)
    s1 = init_train_state(jax.random.PRNGKey(1), cfg, tc1)
    s2 = init_train_state(jax.random.PRNGKey(1), cfg, tc2)
    s1b, m1 = make_train_step(cfg, tc1)(s1, batch)
    s2b, m2 = make_train_step(cfg, tc2)(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s2b.params)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback(kind):
    """EF property: sum of compressed outputs + final residual == sum of raw
    gradients (nothing is lost, only delayed)."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)}
    res = init_residual(grads)
    cfg = CompressionConfig(kind=kind, topk_ratio=0.1, min_size=16)
    total_sent = jnp.zeros_like(grads["w"])
    for i in range(5):
        g = {"w": jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)}
        sent, res = compress_gradients(g, res, cfg)
        total_sent = total_sent + sent["w"]
        if i == 0:
            if kind == "topk":
                nz = float(jnp.mean(sent["w"] != 0))
                assert nz <= 0.15  # ~topk_ratio sparsity on first round
    # cumulative identity (error feedback conserves mass)
    # total raw == total sent + residual
    # rebuild raw total:
    rng2 = np.random.default_rng(0)
    _ = rng2.normal(size=(128, 64))
    raw = sum(
        jnp.asarray(rng2.normal(size=(128, 64)), jnp.float32) for _ in range(5)
    )
    np.testing.assert_allclose(raw, total_sent + res["w"], rtol=1e-3, atol=1e-3)


def test_training_with_compression_still_learns():
    cfg = _tiny_cfg()
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=3e-3),
        compression=CompressionConfig(kind="int8", min_size=256),
        warmup_steps=5, total_steps=60,
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    assert state.residual is not None
    step = jax.jit(make_train_step(cfg, tc))
    data = _data(cfg)
    losses = []
    for i in range(30):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_bf16_opt_state_trains():
    cfg = _tiny_cfg()
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), opt_state_dtype="bfloat16",
                     warmup_steps=5, total_steps=60)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    assert jax.tree.leaves(state.opt.m)[0].dtype == jnp.bfloat16
    step = jax.jit(make_train_step(cfg, tc))
    data = _data(cfg)
    losses = []
    for i in range(30):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10, total_steps=100))
          for s in range(100)]
    assert lr[0] == 0.0 and abs(lr[10] - 1.0) < 0.11
    assert all(a >= b - 1e-6 for a, b in zip(lr[10:], lr[11:]))  # monotone decay
    assert lr[-1] >= 0.1 - 1e-3  # final_frac floor


def test_clip_norm_applied():
    from repro.optim import apply_updates, init_opt

    params = {"w": jnp.ones((4, 4))}
    huge = {"w": jnp.full((4, 4), 1e6)}
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, weight_decay=0.0)
    new, opt, metrics = apply_updates(params, huge, init_opt(params), cfg)
    assert float(metrics["grad_norm"]) > 1e6
    assert bool(jnp.all(jnp.isfinite(new["w"])))


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=8)
    a = SyntheticLM(cfg).batch(7)
    b = SyntheticLM(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host sharding: two hosts see different slices, same structure
    c0 = SyntheticLM(dataclasses.replace(cfg, host_index=0, host_count=2)).batch(7)
    c1 = SyntheticLM(dataclasses.replace(cfg, host_index=1, host_count=2)).batch(7)
    assert c0["tokens"].shape == (4, 16)
    assert not np.array_equal(c0["tokens"], c1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
