"""Allocator invariants under randomized admit/decode/EOS/refill schedules.

The paged engine trusts `runtime.kvcache.PagedKVAllocator` for the two
properties that make page reuse safe:

  * isolation — no page is referenced by two sequences unless they share
    it read-only (prefix sharing), and no writer ever holds a shared page;
  * conservation — freed pages return to the pool, pages-in-use equals
    the sum of live sequence lengths rounded up to page size (shared
    pages counted once), and reservations guarantee a mid-flight sequence
    can always grow to its admitted worst case.

These tests drive a random schedule shaped like the engine's
(admit → chunked extends → EOS/free → refill, with occasional prefix
sharing) against an independent shadow model and call the allocator's own
`check()` after every step.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.kvcache import (
    GARBAGE_PAGE,
    CowCopy,
    PagedKVAllocator,
    PageError,
    pages_for,
)


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    page=st.sampled_from([4, 8, 16]),
    n_pages=st.integers(min_value=6, max_value=48),
    share_prob=st.floats(min_value=0.0, max_value=0.8),
)
def test_allocator_random_schedule_invariants(seed, page, n_pages, share_prob):
    """Randomized engine-shaped schedule: after every step the allocator's
    internal invariants hold, pool accounting matches an independent
    shadow model, and every admitted sequence can grow to its reservation
    without a PageError."""
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(n_pages, page)
    live: dict = {}  # seq → dict(len, reserve, prompt)
    next_seq = 0

    def shadow_pages_in_use():
        pids = set()
        for seq in live:
            pids.update(alloc.table(seq))
        return len(pids)

    for _ in range(120):
        op = rng.choice(["admit", "extend", "free"])
        if op == "admit":
            prompt_len = int(rng.integers(1, 3 * page))
            reserve = prompt_len + int(rng.integers(0, 2 * page))
            share_from, shared = None, 0
            if live and rng.random() < share_prob:
                share_from = int(rng.choice(list(live)))
                shared = int(
                    min(rng.integers(0, live[share_from]["len"] + 1), prompt_len)
                )
            if not alloc.can_admit(reserve, shared_tokens=shared):
                # blocked admissions must not mutate anything
                before = (alloc.free_pages, alloc.pages_in_use)
                with pytest.raises(PageError):
                    alloc.admit(next_seq, prompt_len, reserve,
                                share_from=share_from, shared_tokens=shared)
                assert (alloc.free_pages, alloc.pages_in_use) == before
                alloc.check()
                continue
            cows = alloc.admit(next_seq, prompt_len, reserve,
                               share_from=share_from, shared_tokens=shared)
            for cw in cows:  # CoW copies are fresh, exclusively owned pages
                assert isinstance(cw, CowCopy)
                assert cw.dst != GARBAGE_PAGE and alloc.refcount(cw.dst) == 1
            live[next_seq] = {"len": prompt_len, "reserve": reserve}
            next_seq += 1
        elif op == "extend" and live:
            seq = int(rng.choice(list(live)))
            st_ = live[seq]
            new_len = min(st_["reserve"],
                          st_["len"] + int(rng.integers(0, page + 3)))
            cows = alloc.extend(seq, new_len)
            assert cows == []  # engine schedules never write shared pages
            st_["len"] = max(st_["len"], new_len)
        elif op == "free" and live:
            seq = int(rng.choice(list(live)))
            alloc.free(seq)
            del live[seq]
        alloc.check()
        # conservation: materialized + free-list == pool minus garbage page
        assert alloc.pages_in_use == shadow_pages_in_use()
        assert (alloc.pages_in_use + alloc.free_pages + alloc.reserved_pages
                == n_pages - 1)
        # isolation: a page shared by two sequences appears at the same
        # logical index and both are fully past it (checked in .check());
        # here: live tables only reference materialized pages, never page 0
        for seq in live:
            tbl = alloc.table(seq)
            assert GARBAGE_PAGE not in tbl
            assert len(tbl) == pages_for(live[seq]["len"], page)
            assert all(alloc.refcount(p) >= 1 for p in tbl)

    # every live sequence can still reach its admitted worst case
    for seq in list(live):
        alloc.extend(seq, live[seq]["reserve"])
        alloc.check()
    # drain: all pages return to the pool
    for seq in list(live):
        alloc.free(seq)
    alloc.check()
    assert alloc.pages_in_use == 0
    assert alloc.free_pages == n_pages - 1
    assert alloc.reserved_pages == 0


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    page=st.sampled_from([4, 8]),
)
def test_no_sharing_accounting_is_exact(seed, page):
    """Without sharing, pages in use == Σ ceil(live len / page) exactly."""
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(64, page)
    live = {}
    for seq in range(12):
        n = int(rng.integers(1, 4 * page))
        alloc.admit(seq, n, n + page)
        live[seq] = n
        if rng.random() < 0.3 and live:
            victim = int(rng.choice(list(live)))
            alloc.free(victim)
            del live[victim]
        alloc.check()
        assert alloc.pages_in_use == sum(
            pages_for(n, page) for n in live.values()
        )


def test_prefix_share_counts_once_and_cow_isolates():
    """Shared full pages are counted once; the boundary page is a private
    CoW copy; freeing the parent keeps the child's pages alive."""
    page = 8
    alloc = PagedKVAllocator(32, page)
    alloc.admit(0, 20, 24)  # parent: 3 pages (20 tokens)
    base = alloc.pages_in_use
    # child shares 12 tokens: 1 full page by reference + 1 boundary CoW
    cows = alloc.admit(1, prompt_len=14, reserve_tokens=18,
                       share_from=0, shared_tokens=12)
    assert len(cows) == 1  # exactly the boundary page is copied
    assert cows[0].src == alloc.table(0)[1]
    assert cows[0].dst == alloc.table(1)[1]
    assert alloc.table(1)[0] == alloc.table(0)[0]  # full page aliased
    assert alloc.refcount(alloc.table(0)[0]) == 2
    # pool accounting: child added ⌈14/8⌉ = 2 pages minus 1 aliased
    assert alloc.pages_in_use == base + 1
    alloc.check()
    # divergence: each grows independently without touching the other
    alloc.extend(1, 18)
    alloc.extend(0, 24)
    alloc.check()
    assert alloc.table(0)[1] != alloc.table(1)[1]
    # parent EOS: the aliased page survives for the child
    shared_pid = alloc.table(0)[0]
    alloc.free(0)
    assert alloc.refcount(shared_pid) == 1
    assert alloc.table(1)[0] == shared_pid
    alloc.check()
    alloc.free(1)
    alloc.check()
    assert alloc.pages_in_use == 0


def test_reservation_guarantees_growth():
    """Admitted worst cases never collide: a second admit that would eat a
    live reservation is refused, and the live sequence can still grow."""
    page = 4
    alloc = PagedKVAllocator(9, page)  # 8 usable pages
    alloc.admit(0, 4, 24)  # 1 materialized + 5 reserved
    assert alloc.free_pages == 2
    assert not alloc.can_admit(3 * page)
    with pytest.raises(PageError):
        alloc.admit(1, 12, 12)
    alloc.admit(1, 4, 8)  # fits beside the reservation
    alloc.extend(0, 24)  # the reservation honors the worst case
    alloc.check()
    with pytest.raises(PageError):
        alloc.extend(0, 25)  # but not beyond it


def test_admit_rejects_misuse():
    alloc = PagedKVAllocator(8, 4)
    alloc.admit(0, 6, 8)
    with pytest.raises(PageError):
        alloc.admit(0, 4, 4)  # double admit
    with pytest.raises(PageError):
        alloc.admit(1, 4, 4, shared_tokens=2)  # share without parent
    with pytest.raises(PageError):
        alloc.admit(1, 4, 4, share_from=0, shared_tokens=5)  # > prompt
    with pytest.raises(PageError):
        alloc.admit(1, 8, 8, share_from=0, shared_tokens=7)  # > parent len
    with pytest.raises(PageError):
        alloc.extend(99, 4)  # unknown seq
