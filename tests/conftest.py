"""Test bootstrap: provide a minimal `hypothesis` fallback when the real
package is absent (hermetic CI containers). The stub draws deterministic
pseudo-random examples from the declared strategies — no shrinking, no
database — which keeps the property tests meaningful (N seeded examples,
with the bound edges always included) without the dependency.
"""

import sys

try:  # real hypothesis wins whenever it is installed
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import hashlib
    import inspect
    import types

    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_for(self, rng, index):
            return self._draw(rng, index)

    def _integers(min_value, max_value):
        def draw(rng, index):
            if index == 0:
                return int(min_value)
            if index == 1:
                return int(max_value)
            return int(rng.integers(min_value, max_value + 1))

        return _Strategy(draw)

    def _floats(min_value, max_value, **_kw):
        def draw(rng, index):
            if index == 0:
                return float(min_value)
            if index == 1:
                return float(max_value)
            return float(rng.uniform(min_value, max_value))

        return _Strategy(draw)

    def _booleans():
        return _Strategy(lambda rng, index: bool(rng.integers(0, 2)))

    def _sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng, index: opts[int(rng.integers(0, len(opts)))])

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from

    _DEFAULT_MAX_EXAMPLES = 20

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = int.from_bytes(
                    hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "big"
                )
                for i in range(n):
                    rng = _np.random.default_rng(seed + i)
                    drawn = {
                        name: s.example_for(rng, i) for name, s in strategies.items()
                    }
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (stub, #{i}): {drawn}"
                        ) from e

            wrapper._stub_given = True
            # hide the strategy params from pytest's fixture resolution
            params = [
                p
                for p in inspect.signature(fn).parameters.values()
                if p.name not in strategies
            ]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
