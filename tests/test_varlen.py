"""Property-based differential suite for the packed varlen path (§3.5).

The varlen kernel subsumes the prefill forward and both decode kernels on
the serving path, so its contract is checked against BOTH established
families on randomly drawn packs:

    packed varlen (jnp mirror) == packed varlen (Pallas kernel)
    packed prefill segments    == per-sequence flash_attention (naive ref)
    packed decode rows         == per-sequence decode_attention

across mask families (causal / window / chunked), GQA ratios, and
raggedness: empty sequences (zero rows in the pack), length-1 segments
(decode as the degenerate case), segments starting mid-sequence (chunked
prefill), and alignment padding rows (must come back zero).

Runs on the real `hypothesis` when installed and on the deterministic
stub in `tests/conftest.py` otherwise (CI exercises both).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.attention import (
    MaskSpec,
    decode_attention,
    flash_attention,
    gather_pages,
    varlen_attention,
)

_TOL = 1e-4  # observed agreement is a few f32 ulps


def _align(n, bq):
    return -(-n // bq) * bq


def _varlen_case(seed, n_seqs, hkv, group, d, n_tbl, page, block_q, kinds):
    """Random pool + block tables + a pack of per-sequence segments.

    Each sequence draws kv_len ∈ [0, n_tbl·page] and a segment style:
      'empty'   — no rows in the pack;
      'decode'  — one row at position kv_len−1 (needs kv_len ≥ 1);
      'prefill' — the last `q_len` positions of kv_len (a chunked-prefill
                  tail; q_len = kv_len gives the whole-prompt case).
    Segments are packed block_q-aligned (the kernel contract); padding
    rows carry seq_id = q_pos = −1.
    """
    rng = np.random.default_rng(seed)
    hq = hkv * group
    s_max = n_tbl * page
    n_pool = n_seqs * n_tbl + 2
    k_pages = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    perm = rng.permutation(np.arange(1, n_pool))[: n_seqs * n_tbl]
    tbl = jnp.asarray(perm.reshape(n_seqs, n_tbl), jnp.int32)

    kv_len = np.zeros((n_seqs,), np.int32)
    segs = []  # (seq, start, q_len)
    for s in range(n_seqs):
        kind = kinds[s % len(kinds)]
        if kind == "empty":
            kv_len[s] = rng.integers(0, s_max + 1)
            continue
        if kind == "decode":
            kv_len[s] = rng.integers(1, s_max + 1)
            segs.append((s, int(kv_len[s]) - 1, 1))
        else:  # prefill tail
            kv_len[s] = rng.integers(1, s_max + 1)
            q_len = int(rng.integers(1, kv_len[s] + 1))
            segs.append((s, int(kv_len[s]) - q_len, q_len))

    total = sum(_align(n, block_q) for _, _, n in segs) or block_q
    seq_ids = np.full((total,), -1, np.int32)
    q_pos = np.full((total,), -1, np.int32)
    off = 0
    rows = {}  # seq → (pack offset, start, q_len)
    for s, start, n in segs:
        seq_ids[off:off + n] = s
        q_pos[off:off + n] = np.arange(start, start + n)
        rows[s] = (off, start, n)
        off += _align(n, block_q)
    q = jnp.asarray(rng.normal(size=(total, hq, d)), jnp.float32)
    return q, k_pages, v_pages, tbl, seq_ids, q_pos, jnp.asarray(kv_len), rows


def _mask_kw(maskkind, maskparam, s_max):
    if maskkind == "window":
        return {"window": 1 + maskparam % s_max, "chunk": 0}
    if maskkind == "chunk":
        return {"window": 0, "chunk": 1 + maskparam % s_max}
    return {"window": 0, "chunk": 0}


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_seqs=st.integers(min_value=1, max_value=4),
    hkv=st.integers(min_value=1, max_value=2),
    group=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16]),
    n_tbl=st.integers(min_value=1, max_value=3),
    page=st.sampled_from([4, 8]),
    block_q=st.sampled_from([4, 8]),
    maskkind=st.sampled_from(["causal", "window", "chunk"]),
    maskparam=st.integers(min_value=0, max_value=63),
)
def test_varlen_pallas_vs_jnp(seed, n_seqs, hkv, group, d, n_tbl, page,
                              block_q, maskkind, maskparam):
    """Pallas varlen kernel == jnp mirror on random mixed packs."""
    q, kp, vp, tbl, sids, qpos, kvl, _ = _varlen_case(
        seed, n_seqs, hkv, group, d, n_tbl, page, block_q,
        kinds=("prefill", "decode", "empty"),
    )
    kw = _mask_kw(maskkind, maskparam, n_tbl * page)
    a = varlen_attention(q, kp, vp, tbl, sids, qpos, kvl, impl="flashd", **kw)
    b = varlen_attention(
        q, kp, vp, tbl, sids, qpos, kvl, impl="flashd_pallas",
        block_q=block_q, **kw,
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=_TOL, rtol=_TOL)
    # alignment padding rows come back exactly zero on both paths
    pad = np.asarray(sids) < 0
    if pad.any():
        assert float(jnp.max(jnp.abs(a[pad]))) == 0.0
        assert float(jnp.max(jnp.abs(b[pad]))) == 0.0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_seqs=st.integers(min_value=1, max_value=3),
    hkv=st.integers(min_value=1, max_value=2),
    group=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16]),
    n_tbl=st.integers(min_value=1, max_value=3),
    page=st.sampled_from([4, 8]),
    impl=st.sampled_from(["flashd", "flashd_pallas"]),
)
def test_varlen_prefill_rows_vs_flash_attention(seed, n_seqs, hkv, group, d,
                                                n_tbl, page, impl):
    """Prefill segments of a pack == per-sequence flash_attention over the
    gathered contiguous cache (naive ref oracle, causal at the segment's
    absolute offset)."""
    bq = 4
    q, kp, vp, tbl, sids, qpos, kvl, rows = _varlen_case(
        seed, n_seqs, hkv, group, d, n_tbl, page, bq, kinds=("prefill",),
    )
    o = varlen_attention(
        q, kp, vp, tbl, sids, qpos, kvl, impl=impl, block_q=bq,
    )
    kc = gather_pages(kp, tbl)
    vc = gather_pages(vp, tbl)
    for s, (off, start, n) in rows.items():
        kv = int(kvl[s])
        want = flash_attention(
            q[off:off + n][None], kc[s:s + 1, :kv], vc[s:s + 1, :kv],
            mask=MaskSpec("causal", q_offset=start), impl="naive",
        )[0]
        np.testing.assert_allclose(
            np.asarray(o[off:off + n]), np.asarray(want), atol=_TOL, rtol=_TOL,
        )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_seqs=st.integers(min_value=1, max_value=4),
    hkv=st.integers(min_value=1, max_value=2),
    group=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16]),
    n_tbl=st.integers(min_value=1, max_value=3),
    page=st.sampled_from([4, 8]),
    maskkind=st.sampled_from(["causal", "window", "chunk"]),
    maskparam=st.integers(min_value=0, max_value=63),
    impl=st.sampled_from(["flashd", "flashd_pallas"]),
)
def test_varlen_decode_rows_vs_decode_attention(seed, n_seqs, hkv, group, d,
                                                n_tbl, page, maskkind,
                                                maskparam, impl):
    """Decode rows of a pack (q_len == 1 segments) == decode_attention over
    the gathered contiguous cache — the degenerate-case claim."""
    bq = 4
    q, kp, vp, tbl, sids, qpos, kvl, rows = _varlen_case(
        seed, n_seqs, hkv, group, d, n_tbl, page, bq, kinds=("decode", "empty"),
    )
    kw = _mask_kw(maskkind, maskparam, n_tbl * page)
    o = varlen_attention(q, kp, vp, tbl, sids, qpos, kvl, impl=impl,
                         block_q=bq, **kw)
    kc = gather_pages(kp, tbl)
    vc = gather_pages(vp, tbl)
    for s, (off, start, n) in rows.items():
        assert n == 1
        want = decode_attention(
            q[off:off + 1][None], kc[s:s + 1], vc[s:s + 1],
            jnp.asarray([int(kvl[s])]), n_splits=1, **kw,
        )
        np.testing.assert_allclose(
            np.asarray(o[off]), np.asarray(want[0, 0]), atol=_TOL, rtol=_TOL,
        )


def test_varlen_mixed_pack_three_way():
    """One pack holding a whole prompt, a mid-sequence chunk, a decode row
    and an empty sequence — jnp == pallas == per-row oracles."""
    rng = np.random.default_rng(7)
    hkv, group, d, page, n_tbl, bq = 2, 2, 16, 8, 3, 8
    hq = hkv * group
    n_seqs = 4
    n_pool = n_seqs * n_tbl + 2
    kp = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    tbl = jnp.asarray(
        rng.permutation(np.arange(1, n_pool))[: n_seqs * n_tbl]
        .reshape(n_seqs, n_tbl), jnp.int32)
    # seq0: whole prompt len 10; seq1: chunk [6, 13) of kv 13; seq2: decode
    # at 20 (kv 21); seq3: empty
    segs = [(0, 0, 10), (1, 6, 7), (2, 20, 1)]
    kvl = jnp.asarray([10, 13, 21, 5], jnp.int32)
    total = sum(_align(n, bq) for _, _, n in segs)
    sids = np.full((total,), -1, np.int32)
    qpos = np.full((total,), -1, np.int32)
    off, offs = 0, []
    for s, start, n in segs:
        sids[off:off + n] = s
        qpos[off:off + n] = np.arange(start, start + n)
        offs.append(off)
        off += _align(n, bq)
    q = jnp.asarray(rng.normal(size=(total, hq, d)), jnp.float32)

    a = varlen_attention(q, kp, vp, tbl, sids, qpos, kvl, impl="flashd")
    b = varlen_attention(q, kp, vp, tbl, sids, qpos, kvl,
                         impl="flashd_pallas", block_q=bq)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=_TOL, rtol=_TOL)

    kc = gather_pages(kp, tbl)
    vc = gather_pages(vp, tbl)
    for (s, start, n), o0 in zip(segs, offs):
        kv = int(kvl[s])
        want = flash_attention(
            q[o0:o0 + n][None], kc[s:s + 1, :kv], vc[s:s + 1, :kv],
            mask=MaskSpec("causal", q_offset=start), impl="naive",
        )[0]
        np.testing.assert_allclose(
            np.asarray(a[o0:o0 + n]), np.asarray(want), atol=_TOL, rtol=_TOL,
        )
    # padding + empty-seq rows are zero
    pad = sids < 0
    assert float(jnp.max(jnp.abs(a[pad]))) == 0.0


def test_varlen_registry_exposes_op():
    """The varlen entry point is registered and re-exported (kernels is a
    registry, not a hand-threaded import chain)."""
    from repro import kernels

    assert "varlen" in kernels.op_names()
    assert kernels.get_op("varlen") is kernels.pallas_varlen
    for name in ("attention_fwd", "decode", "decode_paged"):
        assert callable(kernels.get_op(name))
    with pytest.raises(KeyError):
        kernels.get_op("nope")


def test_varlen_rejects_misaligned_total():
    q = jnp.zeros((6, 2, 8), jnp.float32)
    kp = jnp.zeros((3, 4, 1, 8), jnp.float32)
    with pytest.raises(ValueError):
        from repro.kernels.flashd_varlen import flashd_varlen_pallas

        flashd_varlen_pallas(
            q, kp, kp, jnp.zeros((1, 2), jnp.int32),
            jnp.zeros((6,), jnp.int32), jnp.zeros((6,), jnp.int32),
            jnp.asarray([4]), block_q=4, interpret=True,
        )
