"""Preemptive priority scheduling + prefix caching at the serve level
(DESIGN.md §3.6).

The acceptance contract of the cache-aware, preemptible serving core: all
three serve loops (contiguous, paged sequential, mixed varlen) stay
TOKEN-IDENTICAL with the radix prefix cache and preemption enabled or
disabled — including under forced preemption (pool < worst-case demand),
priority-reordered admission, and multi-turn warm-cache serving — for the
jnp and Pallas attention impls. Plus the host-side protocol pieces:
victim selection order, recompute-on-resume state, per-request-id TTFT.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import paper_llama
from repro.models import get_model
from repro.serve import Engine, Request, Scheduler, ServeConfig


def _cfg(**kw):
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64, **kw,
    )


@pytest.fixture(scope="module")
def engine_fixture():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _shared_prefix_reqs(rng, vocab, prefix_len, tails):
    prefix = rng.integers(0, vocab, (prefix_len,)).astype(np.int32)
    return [
        np.concatenate([prefix, rng.integers(0, vocab, (n,)).astype(np.int32)])
        for n in tails
    ]


# ---------------------------------------------------------------------------
# host-side protocol
# ---------------------------------------------------------------------------

def test_victim_selection_order():
    """Lowest priority first, decoding before prefilling, youngest
    admission first; `below=` restricts to strictly lower priority."""
    reqs = [np.asarray([1, 2, 3])] * 4
    sched = Scheduler(reqs, 5, 4, eos_id=-1, priorities=[2, 0, 0, 1])
    for s in range(3):
        req = sched.take_head()
        sched.admit_request(s, req, first_token=7)
    # heads came out priority-first: rids 0 (pri 2), 3 (pri 1), 1 (pri 0)
    assert [sched.slots[s].rid for s in range(3)] == [0, 3, 1]
    sched.admit_request_prefilling(3, sched.take_head())  # rid 2, pri 0
    # lowest priority and DECODING wins over the equal-priority prefilling
    assert sched.victim_slot() == 2
    assert sched.victim_slot(below=1) == 2
    assert sched.victim_slot(below=0) is None
    assert sched.victim_slot(exclude=(2, 3)) == 1
    # after the pri-0 slots are gone, pri-1 is next; pri-2 last
    sched.preempt(2)
    sched.preempt(3)
    assert sched.victim_slot() == 1
    assert sched.victim_slot(below=2) == 1
    sched.preempt(1)
    assert sched.victim_slot() == 0
    assert sched.victim_slot(below=2) is None


def test_preempt_recompute_on_resume_state():
    """A preempted slot re-queues with its generated tokens folded into
    the prefill input, and a resumed admission continues the stream."""
    sched = Scheduler([np.asarray([5, 6])], 4, 1, eos_id=-1)
    req = sched.take_head()
    sched.admit_request(0, req, first_token=9)
    sched.absorb_chunk(np.asarray([[3]], np.int32))  # out = [9, 3]
    back = sched.preempt(0)
    assert back.rid == 0 and back.out == [9, 3]
    np.testing.assert_array_equal(back.tokens, [5, 6, 9, 3])
    assert sched.preemptions == 1 and not sched.slots[0].live
    # resume: the effective prompt was prefilled, the next token sampled
    req2 = sched.take_head()
    assert req2.rid == 0
    sched.admit_request(0, req2, first_token=4)
    sl = sched.slots[0]
    assert sl.out == [9, 3, 4] and sl.resumed == 2
    np.testing.assert_array_equal(sl.prompt, [5, 6, 9, 3])
    # completion counts the WHOLE output
    finished = sched.absorb_chunk(np.asarray([[1]], np.int32))
    assert finished == [0]
    assert sched.results[0].tolist() == [9, 3, 4, 1]
    # cache_tokens excludes the not-yet-fed final sample
    assert sl.cache_tokens().tolist() == [5, 6, 9, 3, 4][: sl.kv]


def test_ttft_tracked_per_request_id_not_per_slot():
    """TTFT is armed once per request id: recorded at the FIRST token the
    request ever emits, never re-armed by preemption/resume, and recorded
    even for head-swapped (priority-reordered) admissions."""
    sched = Scheduler([np.asarray([1])] * 3, 4, 1, eos_id=-1,
                      priorities=[0, 0, 5])
    req = sched.take_head()
    assert req.rid == 2  # priority swapped the head
    sched.admit_request(0, req, first_token=7)
    assert 2 in sched.first_token_at
    t_first = sched.first_token_at[2]
    sched.absorb_chunk(np.asarray([[1]], np.int32))
    sched.preempt(0)
    resumed = sched.take_head()
    assert resumed.rid == 2
    sched.admit_request(0, resumed, first_token=9)
    assert sched.first_token_at[2] == t_first, "resume must not re-arm TTFT"
    # a request that finishes instantly still records its TTFT
    sched2 = Scheduler([np.asarray([1])], 1, 1, eos_id=-1)
    assert not sched2.admit_request(0, sched2.take_head(), first_token=3)
    assert 0 in sched2.first_token_at


def test_plan_step_orders_prefill_by_priority():
    sched = Scheduler([np.asarray([1, 2, 3, 4])] * 3, 4, 3, eos_id=-1,
                      priorities=[0, 2, 1])
    for s in range(3):
        sched.admit_request_prefilling(s, sched.take_head())
    plan = sched.plan_step(token_budget=6, prefill_chunk=4)
    # budget 6, chunks of 4: the two highest-priority prompts get chunks
    assert [sched.slots[g.slot].rid for g in plan.segments] == [1, 2]


# ---------------------------------------------------------------------------
# engine: token identity with caching / preemption on and off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attn_impl", ["flashd", "flashd_pallas"])
def test_serve_token_identity_cache_and_preemption(engine_fixture, attn_impl):
    """cache on == cache off == contiguous seed engine, for the paged and
    mixed loops, on shared-prefix traffic (jnp and Pallas impls)."""
    cfg, params = engine_fixture
    if attn_impl != "flashd":
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    rng = np.random.default_rng(0)
    reqs = _shared_prefix_reqs(rng, cfg.vocab_size, 10, (3, 2, 5, 4))
    n_new = 4
    want = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(
        reqs, n_new)
    variants = [
        ServeConfig(max_batch=2, max_len=32, kv_layout="paged", page_size=8),
        ServeConfig(max_batch=2, max_len=32, kv_layout="paged", page_size=8,
                    prefix_cache=False),
        ServeConfig(max_batch=2, max_len=32, kv_layout="paged", page_size=8,
                    preemption=False),
        ServeConfig(max_batch=2, max_len=32, step_mode="mixed", page_size=8,
                    prefill_chunk=4, token_budget=8),
        ServeConfig(max_batch=2, max_len=32, step_mode="mixed", page_size=8,
                    prefill_chunk=4, token_budget=8, prefix_cache=False,
                    preemption=False),
    ]
    for sc in variants:
        got = Engine(params, cfg, sc).serve(reqs, n_new)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("step_mode", ["sequential", "mixed"])
def test_forced_preemption_oversubscribed_pool(engine_fixture, step_mode):
    """The acceptance criterion: a pool SMALLER than the worst-case demand
    completes every request via preemption, token-identical to the
    unconstrained run, and actually preempts."""
    cfg, params = engine_fixture
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
            for _ in range(4)]
    n_new = 8
    want = Engine(params, cfg, ServeConfig(max_batch=4, max_len=32)).serve(
        reqs, n_new)
    # worst case: 4 × ⌈(10+8)/4⌉ = 20 pages; give it 12
    sc = ServeConfig(max_batch=4, max_len=32, kv_layout="paged", page_size=4,
                     kv_pool_tokens=48, step_mode=step_mode,
                     prefill_chunk=4, token_budget=8)
    eng = Engine(params, cfg, sc)
    got = eng.serve(reqs, n_new, priorities=[0, 1, 0, 1])
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    st = eng.stats()
    assert st["preemptions"] > 0, "the tight pool must have preempted"
    assert eng.peak_active == 4, "optimistic admission oversubscribes"


@pytest.mark.parametrize("step_mode", ["sequential", "mixed"])
def test_multi_turn_shared_system_prompt_warm_cache(engine_fixture, step_mode):
    """The radix cache persists across serve() calls: a second turn that
    replays the system prompt (and the first turn's whole conversation)
    hits the cache, skips the cached prefill, and stays token-identical
    to a cold engine."""
    cfg, params = engine_fixture
    rng = np.random.default_rng(2)
    system = rng.integers(0, cfg.vocab_size, (17,)).astype(np.int32)
    u1 = rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)
    u2 = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    n_new = 4
    sc = ServeConfig(max_batch=2, max_len=48, page_size=4,
                     kv_layout="paged", step_mode=step_mode,
                     prefill_chunk=4, token_budget=8)
    eng = Engine(params, cfg, sc)
    ref = Engine(params, cfg, ServeConfig(max_batch=2, max_len=48))

    turn1 = np.concatenate([system, u1])
    w1 = ref.serve([turn1], n_new)
    g1 = eng.serve([turn1], n_new)
    np.testing.assert_array_equal(w1[0], g1[0])
    cold = dict(eng.stats())
    assert cold["hit_tokens"] == 0

    # turn 2 = the whole first conversation + a new user message
    turn2 = np.concatenate([turn1, w1[0], u2])
    w2 = ref.serve([turn2], n_new)
    g2 = eng.serve([turn2], n_new)
    np.testing.assert_array_equal(w2[0], g2[0])
    warm = eng.stats()
    # the cached prefix covers ≥ the system prompt's full pages
    assert warm["hit_tokens"] >= (len(system) // 4) * 4
    assert warm["prefix_hits"] == 1
    # a sibling request sharing only the system prompt also hits
    turn1b = np.concatenate([system, u2])
    w3 = ref.serve([turn1b], n_new)
    g3 = eng.serve([turn1b], n_new)
    np.testing.assert_array_equal(w3[0], g3[0])
    assert eng.stats()["hit_tokens"] > warm["hit_tokens"]


def test_priorities_reorder_admission_not_tokens(engine_fixture):
    """Priorities change WHO WAITS, never what anyone says: outputs are
    identical to the FIFO run, and the high-priority latecomer is served
    first (smallest TTFT) despite arriving last."""
    cfg, params = engine_fixture
    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
            for _ in range(4)]
    sc = ServeConfig(max_batch=1, max_len=32, kv_layout="paged", page_size=8)
    fifo = Engine(params, cfg, sc)
    want = fifo.serve(reqs, 4)
    prio = Engine(params, cfg, sc)
    got = prio.serve(reqs, 4, priorities=[0, 0, 0, 9])
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    assert min(prio.ttft, key=prio.ttft.get) == 3
    assert max(fifo.ttft, key=fifo.ttft.get) == 3


def test_contiguous_priority_preemption_token_identity(engine_fixture):
    """The contiguous loop honors priorities (slot-array pressure is its
    preemption trigger) and keeps token identity with the FIFO run."""
    cfg, params = engine_fixture
    rng = np.random.default_rng(4)
    reqs = [rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
            for _ in range(4)]
    sc = ServeConfig(max_batch=2, max_len=32)
    want = Engine(params, cfg, sc).serve(reqs, 4)
    eng = Engine(params, cfg, sc)
    got = eng.serve(reqs, 4, priorities=[3, 0, 1, 2])
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    # priority order shows up in the TTFT ordering (0 first, then 3, 2, 1)
    order = sorted(eng.ttft, key=eng.ttft.get)
    assert order[0] == 0 and order[-1] == 1


def test_admission_preemption_bounded_by_reachable_pages(engine_fixture):
    """A high-priority arrival that could NEVER fit — even after rolling
    back every strictly-lower-priority victim — must not preempt anyone:
    running work is only discarded when it can actually buy admission."""
    from repro.serve.engine import _PoolCtx
    from repro.serve.scheduler import Request

    cfg, params = engine_fixture
    eng = Engine(params, cfg, ServeConfig(
        max_batch=3, max_len=64, kv_layout="paged", page_size=4,
        kv_pool_tokens=48))  # 12 usable pages
    alloc, cache = eng._paged_state()
    sched = Scheduler([np.asarray([1])] * 3, 4, 3, eos_id=-1,
                      priorities=[9, 0, 5])
    ctx = _PoolCtx(cache)
    # pri-9 slot holds 8 pages, pri-0 slot holds 2 → 2 free
    alloc.admit(0, 32, 32)
    sched.admit_request(0, sched.take_head(), first_token=7)
    ctx.seq_of[0] = 0
    alloc.admit(1, 8, 8)
    # heads order by priority: next head is rid 2 (pri 5); admit rid 1 last
    req_mid = sched.take_head()
    assert req_mid.rid == 2 and req_mid.priority == 5
    sched.admit_request(1, sched.take_head(), first_token=7)
    ctx.seq_of[1] = 1
    # pri-5 arrival needing 6 pages: free 2 + victim(pri<5) pages 2 = 4 <
    # 6 → preempting the pri-0 slot would be fruitless
    assert not eng._preempting_could_admit(
        sched, alloc, ctx, req_mid, reserve=24, cached=None)
    # needing 4 pages it IS reachable (2 free + the pri-0 victim's 2)
    assert eng._preempting_could_admit(
        sched, alloc, ctx, req_mid, reserve=16, cached=None)
    # a lower-priority arrival has no victims at all: bound = free pages
    req_low = Request(rid=9, prompt=np.asarray([1]), priority=0)
    assert not eng._preempting_could_admit(
        sched, alloc, ctx, req_low, reserve=16, cached=None)


def test_stats_counters_shape(engine_fixture):
    cfg, params = engine_fixture
    rng = np.random.default_rng(5)
    eng = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, kv_layout="paged", page_size=8))
    eng.serve([rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)], 3)
    st = eng.stats()
    for key in ("prefix_lookups", "prefix_hits", "hit_tokens",
                "prompt_tokens", "hit_rate", "preemptions", "evictions",
                "cached_pages", "donated_pages", "pages_in_use",
                "free_pages", "peak_active", "ttft"):
        assert key in st, key
    assert st["prefix_lookups"] == 1 and st["prompt_tokens"] == 9
    assert 0.0 <= st["hit_rate"] <= 1.0
    assert st["prefix_cache_enabled"] and st["preemption_enabled"]
    # cache-off engines report the cache as disabled and never donate
    off = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, kv_layout="paged", page_size=8,
        prefix_cache=False))
    off.serve([rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)], 3)
    st = off.stats()
    assert not st["prefix_cache_enabled"]
    assert st["cached_pages"] == 0 and st["donated_pages"] == 0


def test_failed_serve_recovers_pool_state(engine_fixture):
    """A serve() that dies (pool too small for one request) must not leak
    live sequences into the engine's persistent pool — but since PR 6 the
    recovery is PARTIAL, not scorched-earth (DESIGN.md §3.7): the
    allocator and radix tree survive with no live sequences, its
    invariants hold, and the next serve on the same engine is
    token-identical to a fresh one."""
    from repro.runtime.kvcache import PageError

    cfg, params = engine_fixture
    rng = np.random.default_rng(6)
    eng = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=64, kv_layout="paged", page_size=8,
        kv_pool_tokens=16))
    with pytest.raises(PageError):
        eng.serve([rng.integers(0, cfg.vocab_size, (30,)).astype(np.int32)], 8)
    assert eng._alloc is not None  # persistent state KEPT (warm recovery)
    assert not eng._alloc._tables  # ... but with no live sequences
    eng._alloc.check()  # refcount/table/tree invariants hold
    small = [rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)]
    want = Engine(params, cfg, ServeConfig(max_batch=2, max_len=64)).serve(
        small, 3)
    got = eng.serve(small, 3)
    np.testing.assert_array_equal(want[0], got[0])


def test_default_tuning_yields_warm_hits(engine_fixture):
    """Regression: with no explicit page_size, choose_page_size used to
    return page == max_len for max_len ≤ 64 — every page partial, so the
    radix cache could never donate a full page and repeated prompts got
    hit_tokens == 0. Default tuning must leave warm hits reachable."""
    cfg, params = engine_fixture
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32,
                                          kv_layout="paged"))
    rng = np.random.default_rng(11)
    prompts = _shared_prefix_reqs(rng, cfg.vocab_size, 16, [3, 5])
    cold = eng.serve(prompts, 4)
    warm = eng.serve(prompts, 4)
    assert eng.stats()["hit_tokens"] > 0
    for a, b in zip(cold, warm):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
