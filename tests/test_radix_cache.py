"""Radix prefix cache: shadow-model property suite (DESIGN.md §3.6).

The serving engines trust the allocator's content-addressed radix tree for
three properties:

  * longest-prefix-match correctness — `match_prefix` returns exactly the
    longest full-page prefix of the query that any inserted/donated token
    stream shares (the tree is the union of page chains, and every chain
    is a prefix of some stream);
  * isolation — no live sequence ever holds a writable shared page: radix
    matches alias only full pages strictly below the owner's length, and
    eviction never reclaims a page any table references;
  * conservation — donated pages are retained (not leaked, not freed),
    dedup donation frees duplicates, eviction returns pages to the pool,
    and `pages_in_use + free + reserved` always covers the pool exactly.

These tests drive randomized engine-shaped schedules (admit-with-lookup →
insert → extend → donate/free, under varying share pressure) against an
independent shadow model of the donated streams, calling the allocator's
own `check()` — which now also asserts the tree invariants (every node
live-or-LRU, Σ refcounts == table refs + tree refs, chain depth == table
index) — after every step.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.kvcache import (
    GARBAGE_PAGE,
    CachePolicy,
    PagedKVAllocator,
    PageError,
    pages_for,
)


def _common_full_pages(q, s, page):
    """Longest full-page common prefix (tokens) of streams q and s — the
    brute-force oracle for match_prefix."""
    m = min(len(q), len(s))
    n = 0
    while n + page <= m and np.array_equal(q[n:n + page], s[n:n + page]):
        n += page
    return n


def _stream(rng, bases, page, max_extra):
    """A token stream sharing a random-length prefix with one of `bases`
    (page-aligned overlap is common but not guaranteed) plus a fresh tail
    — the multi-turn / shared-system-prompt shape."""
    base = bases[int(rng.integers(0, len(bases)))]
    keep = int(rng.integers(0, len(base) + 1))
    extra = int(rng.integers(1, max_extra + 1))
    return np.concatenate([
        base[:keep], rng.integers(100, 100 + 7, size=(extra,))
    ]).astype(np.int64)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    page=st.sampled_from([4, 8]),
    share_depth=st.integers(min_value=1, max_value=4),
)
def test_radix_longest_prefix_match_shadow_model(seed, page, share_depth):
    """Randomized admit/insert/extend/donate/free schedule on an ample
    pool (no demand eviction): match_prefix must equal the brute-force
    longest full-page common prefix over every stream the tree has been
    given, and every invariant holds at every step."""
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(256, page)  # ample: nothing is evicted
    bases = [rng.integers(0, 9, size=(share_depth * page,)) for _ in range(3)]
    indexed: list = []  # streams whose full pages the tree has seen
    live: dict = {}  # seq → dict(stream, len)
    next_seq = 0

    def oracle(q, cap):
        best = 0
        for s in indexed:
            best = max(best, _common_full_pages(q[:cap], s, page))
        return best

    for _ in range(60):
        op = rng.choice(["admit", "extend", "retire"])
        if op == "admit":
            prompt = _stream(rng, bases, page, 2 * page)
            cap = len(prompt) - 1
            m = alloc.match_prefix(prompt, max_tokens=cap)
            want = oracle(prompt, cap)
            assert m.n_tokens == want, (
                f"match {m.n_tokens} != oracle {want} for {prompt.tolist()}"
            )
            assert m.n_tokens % page == 0
            assert len(m.pages) == m.n_tokens // page
            alloc.admit(next_seq, len(prompt), len(prompt), cached=m)
            # matched pages sit at their chain index in the new table
            assert alloc.table(next_seq)[: len(m.pages)] == list(m.pages)
            alloc.insert(next_seq, prompt)  # live indexing (prefill done)
            indexed.append(prompt)
            live[next_seq] = {"stream": prompt, "len": len(prompt)}
            next_seq += 1
        elif op == "extend" and live:
            seq = int(rng.choice(list(live)))
            grow = int(rng.integers(1, page + 2))
            st_ = live[seq]
            cows = alloc.extend(seq, st_["len"] + grow)
            # radix-matched prefixes are full pages strictly below the
            # owner's length: growth never lands on a shared page
            assert cows == []
            st_["stream"] = np.concatenate([
                st_["stream"][: st_["len"]],
                rng.integers(200, 207, size=(grow,)),
            ])
            st_["len"] += grow
        elif op == "retire" and live:
            seq = int(rng.choice(list(live)))
            st_ = live.pop(seq)
            if rng.random() < 0.7:
                alloc.donate(seq, st_["stream"][: st_["len"]])
                indexed.append(st_["stream"][: st_["len"]])
            else:
                alloc.free(seq)
        alloc.check()
        assert (alloc.pages_in_use + alloc.free_pages + alloc.reserved_pages
                == alloc.n_pages - 1)
        # every live table references only materialized, non-garbage pages
        for seq in live:
            tbl = alloc.table(seq)
            assert GARBAGE_PAGE not in tbl
            assert len(tbl) == pages_for(live[seq]["len"], page)

    # drain: cached pages stay, table pages of live seqs release
    for seq in list(live):
        alloc.free(seq)
    alloc.check()
    assert alloc.pages_in_use == alloc.cached_pages


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    page=st.sampled_from([4, 8]),
    n_pages=st.integers(min_value=10, max_value=24),
)
def test_radix_under_pressure_stays_sound(seed, page, n_pages):
    """With a tight pool (demand eviction active), completeness is off the
    table but soundness is not: every match must be a prefix of SOME
    stream ever given to the tree, eviction never touches a
    table-referenced page (check() asserts), and admissions that
    can_admit promises succeed."""
    rng = np.random.default_rng(seed)
    alloc = PagedKVAllocator(n_pages, page,
                             cache_policy=CachePolicy(min_free_pages=1))
    bases = [rng.integers(0, 5, size=(2 * page,)) for _ in range(2)]
    indexed: list = []
    live: dict = {}
    next_seq = 0
    for _ in range(50):
        if live and (rng.random() < 0.4 or len(live) > 2):
            seq = int(rng.choice(list(live)))
            stream = live.pop(seq)
            alloc.donate(seq, stream)
            indexed.append(stream)
        else:
            prompt = _stream(rng, bases, page, page)
            m = alloc.match_prefix(prompt, max_tokens=len(prompt) - 1)
            if m.n_tokens:  # soundness: the match is a known chain
                assert any(
                    _common_full_pages(prompt, s, page) >= m.n_tokens
                    for s in indexed
                )
            if not alloc.can_admit(len(prompt), cached=m):
                continue
            alloc.admit(next_seq, len(prompt), len(prompt), cached=m)
            alloc.insert(next_seq, prompt)
            indexed.append(prompt)
            live[next_seq] = prompt
            next_seq += 1
        alloc.check()


def test_radix_donation_dedupes_and_survives_donor():
    """Donating the same content twice retains it once (the duplicate's
    pages free); the cache outlives every donor and serves later matches."""
    page = 4
    alloc = PagedKVAllocator(32, page)
    stream = np.arange(11)
    alloc.admit(0, 11, 11)
    alloc.donate(0, stream)
    alloc.check()
    assert alloc.cached_pages == 2  # two full pages; the 3-token tail freed
    base = alloc.pages_in_use
    alloc.admit(1, 11, 11)  # same content, computed fresh (cold admission)
    alloc.donate(1, stream)
    alloc.check()
    assert alloc.cached_pages == 2, "duplicate donation must dedupe"
    assert alloc.pages_in_use == base, "duplicate pages must free"
    m = alloc.match_prefix(np.concatenate([stream, [9, 9]]))
    assert m.n_tokens == 8
    # the warm admission aliases the cached pages: only the tail is fresh
    before = alloc.pages_in_use
    alloc.admit(2, 11, 11, cached=m)
    assert alloc.pages_in_use == before + 1
    assert alloc.table(2)[:2] == list(m.pages)
    alloc.check()


def test_radix_eviction_is_lru_and_spares_live_pages():
    """Pressure evicts the least-recently-used unreferenced chain first;
    pages aliased by a live table are never reclaimed."""
    page = 4
    alloc = PagedKVAllocator(9, page)  # 8 usable
    old = np.arange(8)
    new = np.arange(50, 58)
    alloc.admit(0, 8, 8)
    alloc.donate(0, old)  # older chain (2 pages)
    alloc.admit(1, 8, 8)
    alloc.donate(1, new)  # newer chain (2 pages)
    # pin the NEWER chain with a live alias — eviction must take the older
    m = alloc.match_prefix(np.concatenate([new, [1]]), max_tokens=8)
    assert m.n_tokens == 8
    alloc.admit(2, 9, 9, cached=m)
    alloc.check()
    # 5 pages held (2 old + 2 new + 1 fresh); ask for the remaining 3 + 2
    alloc.admit(3, 5 * page, 5 * page)  # needs 5 → must evict the old chain
    alloc.check()
    assert alloc.evictions == 2
    assert alloc.match_prefix(old).n_tokens == 0, "old chain evicted"
    assert alloc.match_prefix(np.concatenate([new, [1]]),
                              max_tokens=8).n_tokens == 8, "live chain kept"
    assert alloc.table(2)[:2] == list(m.pages)


def test_radix_match_cap_always_leaves_a_token():
    """A fully cached prompt still prefills ≥ 1 token: the engine's cap
    (prompt_len − 1) drops the final full page, and admit() rejects a
    match that would cover the whole prompt."""
    from repro.runtime.kvcache import PrefixMatch

    page = 4
    alloc = PagedKVAllocator(16, page)
    stream = np.arange(8)
    alloc.admit(0, 8, 8)
    alloc.donate(0, stream)
    m = alloc.match_prefix(stream, max_tokens=7)
    assert m.n_tokens == 4  # second page excluded by the cap
    full = alloc.match_prefix(stream)
    assert full.n_tokens == 8
    with pytest.raises(PageError):
        alloc.admit(1, 8, 8, cached=full)  # nothing left to prefill
    alloc.admit(1, 8, 8, cached=m)
    alloc.check()


def test_radix_stale_match_rejected_after_eviction():
    """An admission holding a match whose pages were since evicted must
    fail loudly instead of aliasing freed pages."""
    page = 4
    alloc = PagedKVAllocator(6, page)  # 5 usable
    alloc.admit(0, 8, 8)
    alloc.donate(0, np.arange(8))
    m = alloc.match_prefix(np.arange(9), max_tokens=8)
    assert m.n_tokens == 8
    alloc.admit(1, 5 * page, 5 * page)  # evicts the whole cache
    assert alloc.cached_pages == 0
    with pytest.raises(PageError):
        alloc.admit(2, 9, 9, cached=m)
    alloc.check()


def test_extend_failure_is_atomic():
    """An extend the pool cannot cover fails BEFORE mutating: table, len,
    refcounts and free list are exactly as they were (the preemptible
    engines retry the same extend after victim selection)."""
    page = 4
    alloc = PagedKVAllocator(6, page)  # 5 usable
    alloc.admit(0, 2 * page, 2 * page)
    alloc.admit(1, 2 * page, 2 * page)
    before = (alloc.table(0), alloc.seq_len(0), alloc.free_pages,
              alloc.pages_in_use)
    with pytest.raises(PageError):
        alloc.extend(0, 5 * page)  # needs 3 more, 1 free
    assert (alloc.table(0), alloc.seq_len(0), alloc.free_pages,
            alloc.pages_in_use) == before
    alloc.check()
    alloc.free(1)  # victim released → the same extend now succeeds
    alloc.extend(0, 5 * page)
    alloc.check()


def test_cache_policy_watermark_and_cap():
    """min_free_pages evicts down after donations; max_cached_pages caps
    retention; 0 disables it; the tuning heuristic fills the defaults."""
    from repro.kernels.tuning import choose_cache_policy

    page = 4
    cap = PagedKVAllocator(32, page,
                           cache_policy=CachePolicy(max_cached_pages=3))
    for seq, lo in enumerate((0, 100, 200)):
        cap.admit(seq, 2 * page, 2 * page)
        cap.donate(seq, np.arange(lo, lo + 2 * page))
        cap.check()
    assert cap.cached_pages <= 3

    water = PagedKVAllocator(6, page,  # 5 usable
                             cache_policy=CachePolicy(min_free_pages=3))
    water.admit(0, 4 * page, 4 * page)
    water.donate(0, np.arange(4 * page))
    water.check()
    assert len(water._free) >= 3  # watermark enforced right after donation
    assert water.cached_pages == 2

    off = PagedKVAllocator(16, page,
                           cache_policy=CachePolicy(max_cached_pages=0))
    off.admit(0, 2 * page, 2 * page)
    off.donate(0, np.arange(2 * page))
    off.check()
    assert off.cached_pages == 0 and off.pages_in_use == 0

    pol = choose_cache_policy(64, 16)
    assert pol.min_free_pages == 4 and pol.max_cached_pages == 63
    pol = choose_cache_policy(64, 16, min_free_pages=0, max_cached_pages=7)
    assert pol.min_free_pages == 0 and pol.max_cached_pages == 7


def test_radix_live_insert_enables_concurrent_sharing():
    """A live prompt indexed via insert() is matchable while its owner
    still runs (the within-burst shared-system-prompt case), and the
    owner's retirement hands the pages over without a copy."""
    page = 4
    alloc = PagedKVAllocator(32, page)
    prompt = np.arange(10)
    alloc.admit(0, 10, 10)
    alloc.insert(0, prompt)
    alloc.check()
    m = alloc.match_prefix(np.concatenate([prompt[:8], [7, 7, 7]]))
    assert m.n_tokens == 8 and list(m.pages) == alloc.table(0)[:2]
    alloc.admit(1, 11, 11, cached=m)
    alloc.check()
    assert alloc.refcount(alloc.table(0)[0]) == 3  # seq0 + seq1 + tree
    alloc.donate(0, prompt)  # owner retires; child keeps the pages
    alloc.check()
    assert alloc.refcount(alloc.table(1)[0]) == 2  # seq1 + tree
    alloc.free(1)
    alloc.check()
    assert alloc.pages_in_use == alloc.cached_pages == 2
