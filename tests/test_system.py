"""End-to-end behaviour tests: the full public-API journey —
train → checkpoint → restore → serve — on the paper's validation-scale
model, with the FLASH-D kernel in the attention path throughout.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_llama
from repro.data import DataConfig, SyntheticLM
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import checkpoint as ckpt
from repro.serve import Engine, ServeConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _cfg():
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, head_dim=16, vocab_size=128, vocab_pad_multiple=64,
    )


def test_end_to_end_train_checkpoint_serve(tmp_path):
    cfg = _cfg()
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=5, total_steps=50)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=48, global_batch=8))

    losses = []
    for i in range(35):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3  # it learns

    ckpt.save(str(tmp_path), 35, state, extra={"data_step": 35})
    restored, extra = ckpt.restore(str(tmp_path), state)
    assert extra["data_step"] == 35

    # serve with the trained weights; greedy generation is deterministic and
    # identical from saved vs in-memory params
    eng1 = Engine(state.params, cfg, ServeConfig(max_len=64))
    eng2 = Engine(restored.params, cfg, ServeConfig(max_len=64))
    prompt = np.asarray([[1, 2, 3, 4, 5, 6]], np.int32)
    np.testing.assert_array_equal(
        eng1.generate(prompt, 8), eng2.generate(prompt, 8)
    )


def test_flashd_and_fa2_training_agree():
    """Same seed, same data: training through FLASH-D vs FA2 attention gives
    the same loss curve to float tolerance (the paper's equivalence claim at
    the full-system level)."""
    curves = {}
    for impl in ("flashd", "fa2"):
        cfg = dataclasses.replace(_cfg(), attn_impl=impl)
        tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=2, total_steps=20)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        step = jax.jit(make_train_step(cfg, tc))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        curve = []
        for i in range(12):
            state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
            curve.append(float(m["loss"]))
        curves[impl] = curve
    np.testing.assert_allclose(curves["flashd"], curves["fa2"], rtol=2e-4, atol=2e-4)
