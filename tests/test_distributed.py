"""Distributed correctness on forced 8-device host meshes (subprocess —
the main test process must keep seeing exactly one device).

Covered: GPipe pipeline == sequential reference, sharded train step ==
single-device train step, sharding-rule divisibility fallbacks, MoE under
expert parallelism.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str):
    """Run `code` with 8 forced host devices; raise on failure."""
    prog = "import os\nos.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" + textwrap.dedent(code)
    res = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": os.path.join(_REPO, "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"), "JAX_PLATFORMS": "cpu"},
        cwd=_REPO,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_pipeline_matches_sequential():
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_apply, split_stages

    S, L, M, mb, d = 4, 8, 8, 4, 16
    mesh = jax.make_mesh((S,), ("pod",))
    rng = np.random.default_rng(0)
    layer_w = jnp.asarray(rng.normal(size=(L, d, d)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

    def block_fn(stage_params, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, stage_params)
        return h

    stage_params = split_stages(layer_w, S)
    y = pipeline_apply(block_fn, stage_params, x, mesh=mesh, axis_name="pod")

    # sequential reference
    ref = x
    for i in range(L):
        ref = jnp.tanh(ref @ layer_w[i])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5, atol=2e-5)
    print("pipeline OK")
    """)


def test_pipeline_grads_flow():
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_apply, split_stages

    S, L, M, mb, d = 2, 4, 4, 2, 8
    mesh = jax.make_mesh((S,), ("pod",))
    rng = np.random.default_rng(1)
    layer_w = jnp.asarray(rng.normal(size=(L, d, d)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)

    def block_fn(stage_params, xin):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, xin, stage_params)
        return h

    def loss_pp(w):
        y = pipeline_apply(block_fn, split_stages(w, S), x, mesh=mesh, axis_name="pod")
        return jnp.sum(y ** 2)

    def loss_seq(w):
        h = x
        for i in range(L):
            h = jnp.tanh(h @ w[i])
        return jnp.sum(h ** 2)

    g1 = jax.grad(loss_pp)(layer_w)
    g2 = jax.grad(loss_seq)(layer_w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-5)
    print("pipeline grads OK")
    """)


def test_sharded_train_step_matches_single_device():
    _run_in_subprocess("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import configs
    from repro.distributed import sharding as shd
    from repro.train.train_step import TrainConfig, init_train_state, make_train_step
    from repro.data import DataConfig, SyntheticLM

    cfg = configs.get_smoke_config("qwen3-0.6b")
    tc = TrainConfig()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8))
    batch = jax.tree.map(jnp.asarray, data.batch(0))

    # single-device reference
    state0 = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    sref, mref = jax.jit(make_train_step(cfg, tc))(state0, batch)

    # 4x2 (data, model) mesh with full rules engine
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = shd.ShardingCtx(mesh)
    with shd.activate(ctx), shd.mesh_ctx(mesh):
        state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
        pspecs = shd.param_specs(state.params)
        from repro.train.train_step import TrainState
        from repro.optim import OptState
        sspec = TrainState(params=pspecs, opt=OptState(m=pspecs, v=pspecs, step=P()),
                           residual=None, step=P(),
                           loss_scale=P(), good_steps=P(), skipped=P())
        state = jax.device_put(state, shd.to_named(sspec))
        batch_sh = jax.device_put(batch, shd.to_named(shd.batch_specs(batch)))
        step = shd.sharded_jit(make_train_step(cfg, tc),
                               in_shardings=(sspec, shd.batch_specs(batch)))
        s1, m1 = step(state, batch_sh)

    np.testing.assert_allclose(float(mref["loss"]), float(m1["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(sref.params), jax.tree.leaves(s1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
    print("sharded == single OK")
    """)


def test_moe_expert_parallel_matches():
    _run_in_subprocess("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import configs
    from repro.distributed import sharding as shd
    from repro.models import moe as m

    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    params = m.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, cfg.d_model)), jnp.float32)
    y0, _ = m.apply_moe(params, x, cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = shd.ShardingCtx(mesh)
    with shd.activate(ctx), shd.mesh_ctx(mesh):
        pspecs = shd.param_specs(params)
        f = shd.sharded_jit(lambda p, xx: m.apply_moe(p, xx, cfg)[0],
                            in_shardings=(pspecs, P(("data",), None, None)))
        y1 = f(params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=3e-3, atol=3e-3)
    print("EP OK")
    """)


def test_param_rules_divisibility_fallback():
    """Rules engine never emits a spec whose axis product doesn't divide."""
    import jax.numpy as jnp
    from repro import configs
    from repro.models import get_model

    mesh_axes = {"data": 16, "model": 16, "pod": 2}

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16), dtype=object)

    ctx = shd.ShardingCtx.__new__(shd.ShardingCtx)
    ctx.mesh = None
    ctx.axis_sizes = mesh_axes
    ctx.use_sp = True
    ctx.fsdp_axis = "data"
    ctx.has_pod = True

    with shd.activate(ctx):
        for arch in ["qwen2-1.5b", "yi-34b", "qwen3-moe-235b-a22b", "seamless-m4t-medium"]:
            cfg = configs.get_config(arch)
            api = get_model(cfg)
            shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
            specs = shd.param_specs(shapes)
            flat_shapes = jax.tree.leaves(shapes)
            flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_shapes) == len(flat_specs)
            for shp, spec in zip(flat_shapes, flat_specs):
                for dim, ax in zip(shp.shape, tuple(spec) + (None,) * 8):
                    if ax is None:
                        continue
                    size = ctx.axis_size(ax)
                    assert dim % size == 0, (arch, shp.shape, spec)


def test_activation_rules_fallbacks():
    ctx = shd.ShardingCtx.__new__(shd.ShardingCtx)
    ctx.mesh = None
    ctx.axis_sizes = {"data": 16, "model": 16}
    ctx.use_sp = True
    ctx.fsdp_axis = "data"
    ctx.has_pod = False
    with shd.activate(ctx):
        # heads divide → TP over heads
        assert shd.spec_for("heads", (256, 4096, 32, 128)) == P(("data",), None, "model", None)
        # heads don't divide → full-DP attention over data×model
        s = shd.spec_for("heads", (256, 4096, 56, 128))
        assert s == P(("data", "model"), None, None, None)
        # batch=1 long context decode: KV cache context-parallel over data
        s = shd.spec_for("kv_cache", (1, 524288, 8, 128))
        assert s[1] == "data"
