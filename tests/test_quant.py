"""Quantized paged KV pool (DESIGN.md §3.8): format unit tests, the
quantized-vs-f32 differential error bounds over GQA/masks/raggedness for
both kernel paths, write-path determinism (sequential vs packed vs radix
warm hits), logprob drift on the serving decode loop, allocator scale-leaf
invariants, and terminal-cleanliness under chaos with kv_dtype=int8.

The load-bearing soundness claims pinned here:

  * a page's quantized bytes + scale are a pure function of its own token
    stream (slot-0 scale, never revised) — so the sequential step, the
    packed varlen step, and a radix-cache warm hit all produce identical
    pool state, and prefix-shared pages can alias one scale entry;
  * the jnp mirrors dequantize with arithmetic identical to the kernels'
    in-tile dequant, so they remain the differential oracle;
  * FLASH-D's stable exponentials keep the int8 K/V error a small, bounded
    output perturbation (no normalizer re-basing to amplify it).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import paper_llama
from repro.core.attention import (
    decode_attention_paged,
    gather_pages,
    varlen_attention,
)
from repro.runtime import quant
from repro.serve import DONE, TERMINAL, Engine, FaultInjector, ServeConfig

# ---------------------------------------------------------------------------
# format unit tests
# ---------------------------------------------------------------------------


def test_spec_registry():
    spec = quant.get_spec("int8")
    assert spec.name == "int8" and spec.qmax == 127.0 and spec.itemsize == 1
    assert quant.get_spec("") is None  # "" = native pool
    with pytest.raises(ValueError, match="unknown kv_dtype"):
        quant.get_spec("int4")
    assert quant.kv_itemsize("") == 4
    assert quant.kv_itemsize("int8") == 1
    assert "int8" in quant.available()
    assert quant.spec_for_dtype(jnp.int8) is spec
    assert quant.spec_for_dtype(jnp.float32) is None


def test_slot0_scale_deterministic_and_positive():
    rng = np.random.default_rng(0)
    spec = quant.get_spec("int8")
    row = jnp.asarray(rng.standard_normal((3, 2, 16)), jnp.float32)
    s1, s2 = quant.slot0_scale(row, spec), quant.slot0_scale(row, spec)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert np.all(np.asarray(s1) > 0)
    # all-zero rows still get a positive, finite scale (the _EPS floor)
    z = quant.slot0_scale(jnp.zeros((2, 16)), spec)
    assert np.all(np.isfinite(np.asarray(z))) and np.all(np.asarray(z) > 0)


def test_roundtrip_error_bound():
    """Values inside the slot-0 row's headroom round-trip within half a
    quantization step; values beyond saturate symmetrically."""
    rng = np.random.default_rng(1)
    spec = quant.get_spec("int8")
    rows = jnp.asarray(rng.standard_normal((4, 8, 2, 16)), jnp.float32)
    scales = quant.slot0_scale(rows[:, 0], spec)  # [P, Hkv]
    q = quant.quantize_rows(rows, scales[:, None, :], spec)
    assert q.dtype == jnp.int8
    deq = quant.dequantize_pages(q, scales)
    step = np.asarray(scales)[:, None, :, None]
    bound = np.abs(np.asarray(rows))  # |x| clips to qmax·scale ≤ |x|
    err = np.abs(np.asarray(deq) - np.asarray(rows))
    assert np.all(err <= np.maximum(step / 2 + 1e-6, bound - 127.0 * step))


def _quantized_pool(rng, P, page, hkv, d, dv, spec):
    kf = jnp.asarray(rng.standard_normal((P, page, hkv, d)), jnp.float32)
    vf = jnp.asarray(rng.standard_normal((P, page, hkv, dv)), jnp.float32)
    ks = quant.slot0_scale(kf[:, 0], spec)
    vs = quant.slot0_scale(vf[:, 0], spec)
    kq = quant.quantize_rows(kf, ks[:, None, :], spec)
    vq = quant.quantize_rows(vf, vs[:, None, :], spec)
    return kf, vf, kq, vq, ks, vs


# ---------------------------------------------------------------------------
# differential suites: quantized vs f32 oracle, both kernel paths
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    group=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([0, 0, 6]),
    chunk=st.sampled_from([0, 0, 8]),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_paged_decode_quantized_differential(group, window, chunk, seed):
    """Paged decode, quantized pool: kernel ≈ jnp mirror (tight — same
    arithmetic), mirror == attention over the dequantized pool (exact),
    and the int8-vs-f32 drift stays inside the error bound."""
    if window and chunk:
        chunk = 0
    rng = np.random.default_rng(seed)
    P, page, hkv, d, dv = 9, 8, 2, 16, 16
    B, N = 2, 4
    spec = quant.get_spec("int8")
    kf, vf, kq, vq, ks, vs = _quantized_pool(rng, P, page, hkv, d, dv, spec)
    tbl = jnp.asarray(rng.permutation(np.arange(1, P))[: B * N].reshape(B, N))
    clen = jnp.asarray(rng.integers(1, N * page + 1, (B,)), jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, group * hkv, d)), jnp.float32)

    from repro.kernels.ops import pallas_decode_paged

    o_kernel = pallas_decode_paged(
        q, kq, vq, tbl, clen, window=window, chunk=chunk,
        k_scale=ks, v_scale=vs,
    )
    o_mirror = decode_attention_paged(
        q, kq, vq, tbl, clen, window=window, chunk=chunk,
        k_scale=ks, v_scale=vs,
    )
    o_dequant = decode_attention_paged(
        q, quant.dequantize_pages(kq, ks), quant.dequantize_pages(vq, vs),
        tbl, clen, window=window, chunk=chunk,
    )
    o_f32 = decode_attention_paged(
        q, kf, vf, tbl, clen, window=window, chunk=chunk,
    )
    assert float(jnp.max(jnp.abs(o_kernel - o_mirror))) < 5e-5
    assert float(jnp.max(jnp.abs(o_mirror - o_dequant))) < 1e-6
    assert float(jnp.max(jnp.abs(o_mirror - o_f32))) < 0.5  # coarse sanity bound


@settings(max_examples=10, deadline=None)
@given(
    group=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([0, 0, 6]),
    ragged=st.booleans(),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_varlen_quantized_differential(group, window, ragged, seed):
    """Packed varlen, quantized pool: same oracle chain as paged decode,
    over mixed prefill/decode raggedness (per-sequence kv_len, padding
    rows) and GQA groupings."""
    rng = np.random.default_rng(seed)
    P, page, hkv, d, dv = 9, 8, 2, 16, 16
    B, N, block_q = 2, 4, 8
    spec = quant.get_spec("int8")
    kf, vf, kq, vq, ks, vs = _quantized_pool(rng, P, page, hkv, d, dv, spec)
    tbl = jnp.asarray(rng.permutation(np.arange(1, P))[: B * N].reshape(B, N))
    if ragged:
        kv_len = jnp.asarray(rng.integers(1, N * page + 1, (B,)), jnp.int32)
    else:
        kv_len = jnp.full((B,), N * page, jnp.int32)
    # one block_q-aligned segment per sequence, tail rows padded
    seq_ids, q_pos = [], []
    for b in range(B):
        n = int(rng.integers(1, block_q + 1))
        start = max(int(kv_len[b]) - n, 0)
        seq_ids += [b] * n + [-1] * (block_q - n)
        q_pos += list(range(start, start + n)) + [-1] * (block_q - n)
    seq_ids = jnp.asarray(seq_ids, jnp.int32)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    T = B * block_q
    q = jnp.asarray(rng.standard_normal((T, group * hkv, d)), jnp.float32)

    kw = dict(window=window, block_q=block_q)
    o_kernel = varlen_attention(
        q, kq, vq, tbl, seq_ids, q_pos, kv_len, impl="flashd_pallas",
        k_scale=ks, v_scale=vs, **kw,
    )
    o_mirror = varlen_attention(
        q, kq, vq, tbl, seq_ids, q_pos, kv_len, impl="flashd",
        k_scale=ks, v_scale=vs, **kw,
    )
    o_dequant = varlen_attention(
        q, quant.dequantize_pages(kq, ks), quant.dequantize_pages(vq, vs),
        tbl, seq_ids, q_pos, kv_len, impl="flashd", **kw,
    )
    o_f32 = varlen_attention(
        q, kf, vf, tbl, seq_ids, q_pos, kv_len, impl="flashd", **kw,
    )
    assert float(jnp.max(jnp.abs(o_kernel - o_mirror))) < 5e-5
    assert float(jnp.max(jnp.abs(o_mirror - o_dequant))) < 1e-6
    assert float(jnp.max(jnp.abs(o_mirror - o_f32))) < 0.5  # coarse sanity bound


def test_gather_pages_dequantizes():
    rng = np.random.default_rng(2)
    spec = quant.get_spec("int8")
    _, _, kq, _, ks, _ = _quantized_pool(rng, 5, 4, 2, 8, 8, spec)
    tbl = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    got = gather_pages(kq, tbl, scales=ks)
    want = gather_pages(quant.dequantize_pages(kq, ks), tbl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.skipif("fp8" not in quant.available(), reason="host jax lacks fp8")
def test_fp8_is_a_dtype_swap():
    """The fp8 spec rides the exact same plumbing — only (dtype, qmax)
    differ. One mirror-vs-dequantized-oracle pass is enough to pin it."""
    rng = np.random.default_rng(3)
    spec = quant.get_spec("fp8")
    _, _, kq, vq, ks, vs = _quantized_pool(rng, 5, 4, 2, 8, 8, spec)
    assert kq.dtype == jnp.float8_e4m3fn
    tbl = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    clen = jnp.asarray([6, 8], jnp.int32)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    o = decode_attention_paged(q, kq, vq, tbl, clen, k_scale=ks, v_scale=vs)
    o_ref = decode_attention_paged(
        q, quant.dequantize_pages(kq, ks), quant.dequantize_pages(vq, vs),
        tbl, clen,
    )
    assert float(jnp.max(jnp.abs(o - o_ref))) < 1e-6


# ---------------------------------------------------------------------------
# serving: write determinism, warm hits, drift, chaos
# ---------------------------------------------------------------------------


def _cfg():
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64,
    )


def _sc(mode="sequential", **kw):
    base = dict(max_batch=4, max_len=32, kv_layout="paged", page_size=4,
                kv_dtype="int8", step_mode=mode)
    base.update(kw)
    return ServeConfig(**base)


@pytest.fixture(scope="module")
def engine_fixture():
    cfg = _cfg()
    from repro.models.transformer import init_lm

    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 60, (n,)).astype(np.int32) for n in (7, 11, 5)]
    return cfg, params, prompts


def test_write_determinism_sequential_vs_packed(engine_fixture):
    """The slot-0 scale rule makes pool state write-order deterministic:
    the sequential one-token step and the packed varlen step produce
    token-identical serves from the same quantized pool format."""
    cfg, params, prompts = engine_fixture
    out_seq = Engine(params, cfg, _sc("sequential")).serve(prompts, 6)
    out_mix = Engine(params, cfg, _sc("mixed")).serve(prompts, 6)
    for a, b in zip(out_seq, out_mix):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_radix_warm_hit_token_identical_int8(engine_fixture):
    """A warm radix hit replays cached quantized pages: because a donated
    page's bytes+scale are a pure function of its token prefix, the warm
    serve is token-identical to the cold one."""
    cfg, params, prompts = engine_fixture
    eng = Engine(params, cfg, _sc("sequential"))
    cold = eng.serve(prompts, 6)
    warm = eng.serve(prompts, 6)
    assert eng.stats()["hit_tokens"] > 0
    for a, b in zip(cold, warm):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    eng._alloc.check(eng._paged_cache)


def test_stats_reports_pool_bytes(engine_fixture):
    cfg, params, prompts = engine_fixture
    eng8 = Engine(params, cfg, _sc())
    engf = Engine(params, cfg, _sc(kv_dtype=""))
    eng8.serve(prompts[:1], 2)
    engf.serve(prompts[:1], 2)
    s8, sf = eng8.stats(), engf.stats()
    assert s8["kv_dtype"] == "int8" and sf["kv_dtype"] == "native"
    # int8 pages + f32 scale side-band ≪ f32 pages
    assert s8["kv_bytes_per_token"] < sf["kv_bytes_per_token"] / 3
    assert s8["kv_pool_bytes"] > 0


def test_logprob_drift_bound(engine_fixture):
    """Teacher-forced paged decode, int8 vs native pool: max |Δ log p|
    over prefill + decode steps stays inside a small bound — the
    perplexity-style accuracy cost of the quantized cache."""
    cfg, params, _ = engine_fixture
    from jax import tree_util as jtu

    from repro.models.transformer import (
        decode_step_lm,
        init_decode_cache,
        prefill_lm,
    )

    rng = np.random.default_rng(3)
    B, plen, T, page, n_per = 2, 10, 6, 4, 8
    prompts = jnp.asarray(rng.integers(1, 60, (B, plen)), jnp.int32)
    tbl = jnp.asarray(
        [[1 + b * n_per + i for i in range(n_per)] for b in range(B)],
        jnp.int32,
    )

    def run(kv_dtype, forced):
        cache = init_decode_cache(
            B, 32, cfg, layout="paged", page_size=page,
            n_pages=1 + B * n_per, kv_dtype=kv_dtype,
        )

        def set_tbl(path, x):
            name = next(
                (e.key for e in reversed(path) if isinstance(e, jtu.DictKey)),
                None,
            )
            return jnp.broadcast_to(tbl, x.shape) if name == "tbl" else x

        cache = jtu.tree_map_with_path(set_tbl, cache)
        logits, cache = prefill_lm(params, prompts, cache, cfg)
        lps, toks = [jax.nn.log_softmax(logits[:, : cfg.vocab_size])], []
        for t in range(T):
            tok = (jnp.argmax(logits, -1).astype(jnp.int32)
                   if forced is None else forced[t])
            toks.append(tok)
            logits, cache = decode_step_lm(
                params, cache, tok, jnp.full((B,), plen + t), cfg
            )
            lps.append(jax.nn.log_softmax(logits[:, : cfg.vocab_size]))
        return jnp.stack(lps), toks

    lp_f32, toks = run("", None)
    lp_q, _ = run("int8", toks)
    assert float(jnp.max(jnp.abs(lp_q - lp_f32))) < 0.1


def test_allocator_check_validates_scales(engine_fixture):
    """`check(cache)` pins the scale side-band: leaf spans the physical
    page axis (shared pages therefore share one entry), in-use pages'
    scales finite and positive — and a corrupted scale trips it."""
    cfg, params, prompts = engine_fixture
    eng = Engine(params, cfg, _sc())
    eng.serve(prompts, 4)
    alloc, cache = eng._alloc, eng._paged_cache
    alloc.check(cache)  # healthy pool passes
    in_use = [pid for pid in range(alloc.n_pages) if alloc._ref[pid] > 0]
    assert in_use, "warm radix cache should retain pages"
    from repro.serve.engine import _map_paged

    bad = _map_paged(
        cache,
        pool=lambda x: (x.at[0, in_use[0]].set(-1.0)
                        if x.ndim == 3 else x),  # scale leaves only
    )
    with pytest.raises(AssertionError, match="non-positive"):
        alloc.check(bad)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    rate=st.floats(min_value=0.05, max_value=0.3),
    mode=st.sampled_from(["sequential", "mixed"]),
)
def test_chaos_int8_terminal_clean(engine_fixture, seed, rate, mode):
    """kv_dtype=int8 under the chaos harness: every request terminal,
    DONE survivors token-identical to the fault-free int8 run, and the
    allocator invariants — scale leaves included — hold after recovery."""
    cfg, params, prompts = engine_fixture
    baseline = Engine(params, cfg, _sc(mode)).serve(prompts, 4)
    eng = Engine(params, cfg, _sc(mode),
                 fault_injector=FaultInjector(rate=rate, seed=seed))
    outs = eng.serve(prompts, 4)
    status = eng.stats()["request_status"]
    assert set(status) == set(range(len(prompts)))
    assert all(s in TERMINAL for s in status.values()), status
    for i, base in enumerate(baseline):
        if status[i] == DONE:
            np.testing.assert_array_equal(np.asarray(base), np.asarray(outs[i]))
    eng._alloc.check(eng._paged_cache)
