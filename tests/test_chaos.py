"""Chaos differential suite for the fault-tolerant serving runtime
(DESIGN.md §3.7).

The acceptance contract, exercised across all three serve loops
(contiguous, paged sequential, mixed varlen) under seeded fault
injection at every site (page_alloc / kernel_dispatch / device_step /
host_sync):

  * every request ends TERMINAL — done, failed, or expired; never
    silently dropped (a FAILED request is reported, not vanished);
  * every request that still completes is TOKEN-IDENTICAL to the
    fault-free run (faults charge retries and reorder work, but never
    corrupt a surviving stream — recompute-on-resume over FLASH-D's
    (O, Λ) carry is exact);
  * the page pool's refcount/table/tree invariants hold after recovery
    (`PagedKVAllocator.check()`);
  * a hard mid-serve crash round-trips through `snapshot()` → fresh
    engine → `restore()` → `resume()` with full token identity and a
    re-warmed radix cache;
  * repeated kernel faults downgrade a `*_pallas` impl to its jnp twin
    and the serve still completes.
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import paper_llama
from repro.models import get_model
from repro.runtime.resilience import RetryPolicy
from repro.serve import (
    DONE,
    EXPIRED,
    TERMINAL,
    Engine,
    EngineCrash,
    FaultInjector,
    Request,
    Scheduler,
    ServeConfig,
)

MODES = ("contig", "paged", "mixed")


def _cfg(**kw):
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
        d_ff=64, head_dim=16, vocab_size=64, vocab_pad_multiple=64, **kw,
    )


def _sc(mode: str, **kw) -> ServeConfig:
    base = dict(max_batch=2, max_len=32)
    if mode == "paged":
        base.update(kv_layout="paged", page_size=4, kv_pool_tokens=96)
    elif mode == "mixed":
        base.update(kv_layout="paged", page_size=4, kv_pool_tokens=96,
                    step_mode="mixed")
    base.update(kw)
    return ServeConfig(**base)


N_NEW = 6


@pytest.fixture(scope="module")
def chaos_fixture():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9, 13, 7)]
    baselines = {
        mode: Engine(params, cfg, _sc(mode)).serve(prompts, N_NEW)
        for mode in MODES
    }
    return cfg, params, prompts, baselines


# ---------------------------------------------------------------------------
# injector / policy primitives
# ---------------------------------------------------------------------------

def test_injector_deterministic():
    """Same seed + same call sequence → the same faults fire; a schedule
    entry fires at exactly its occurrence index."""
    def trace(inj, n=40):
        out = []
        for i in range(n):
            site = FaultInjector.SITES[i % 4]
            try:
                inj.check(site, rid=i)
                out.append(0)
            except Exception:
                out.append(1)
        return out

    a = trace(FaultInjector(rate=0.3, seed=11))
    b = trace(FaultInjector(rate=0.3, seed=11))
    assert a == b and sum(a) > 0
    assert trace(FaultInjector(rate=0.3, seed=12)) != a

    inj = FaultInjector(schedule=[("device_step", 2)])
    fired = []
    for i in range(5):
        try:
            inj.check("device_step")
        except Exception:
            fired.append(i)
    assert fired == [2]
    assert inj.calls["device_step"] == 5 and inj.fired["device_step"] == 1


def test_injector_crash_after_checks():
    inj = FaultInjector(crash_after_checks=3)
    for _ in range(3):
        inj.check("host_sync")
    with pytest.raises(EngineCrash):
        inj.check("host_sync")
    inj.check("host_sync")  # crashes once, then resumes clean


def test_retry_policy():
    p = RetryPolicy(max_retries=4, backoff_base_s=0.5, backoff_max_s=3.0,
                    jitter=0.0, retryable=(ValueError, KeyError))
    assert p.is_retryable(ValueError("x")) and p.is_retryable(KeyError("y"))
    assert not p.is_retryable(RuntimeError("z"))
    delays = [p.delay_s(a) for a in range(1, 6)]
    assert delays[:3] == [0.5, 1.0, 2.0]  # exponential
    assert delays[3] == 3.0 and delays[4] == 3.0  # capped
    pj = dataclasses.replace(p, jitter=0.5)
    assert pj.delay_s(2) == pj.delay_s(2)  # jitter is seeded-deterministic
    assert pj.delay_s(2, seed=1) != pj.delay_s(2, seed=2)


def test_scheduler_retry_ordering():
    """A retried request sorts AFTER fresh requests of the same priority
    and is gated by its backoff window."""
    sched = Scheduler([np.asarray([1, 2])] * 3, 4, 1, eos_id=-1,
                      max_retries=3, retry_backoff_s=0.0)
    first = sched.take_head()
    assert first.rid == 0
    assert sched.retry_request(first)  # requeued, retries=1
    assert sched.head().rid == 1  # fresh rids 1, 2 outrank the retry
    assert sched.retried == 1 and sched.rollbacks == 1

    gated = Scheduler([np.asarray([1, 2])], 4, 1, eos_id=-1,
                      max_retries=3, retry_backoff_s=60.0)
    r = gated.take_head()
    assert gated.retry_request(r)
    assert gated.head() is None  # backoff gate: not eligible yet
    assert gated.next_ready_in() > 0
    r.not_before = 0.0  # force eligibility: the gate is the only barrier
    assert gated.head().rid == 0


def test_scheduler_retry_budget_exhaustion():
    sched = Scheduler([np.asarray([1, 2])], 4, 1, eos_id=-1, max_retries=1)
    req = sched.take_head()
    assert sched.retry_request(req)  # 1st retry: within budget
    req = sched.take_head()
    assert not sched.retry_request(req)  # 2nd: budget out → FAILED
    assert sched.status[0] == "failed" and sched.failed == 1
    assert sched.all_terminal()


# ---------------------------------------------------------------------------
# chaos differential: any seed, any rate, any loop
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    rate=st.floats(min_value=0.05, max_value=0.35),
    mode=st.sampled_from(MODES),
)
def test_chaos_differential(chaos_fixture, seed, rate, mode):
    """Under an arbitrary seeded fault schedule: no request is dropped,
    survivors are token-identical to the fault-free run, and the pool
    invariants hold afterwards."""
    cfg, params, prompts, baselines = chaos_fixture
    eng = Engine(params, cfg, _sc(mode),
                 fault_injector=FaultInjector(rate=rate, seed=seed))
    outs = eng.serve(prompts, N_NEW)
    st_ = eng.stats()
    status = st_["request_status"]
    assert set(status) == set(range(len(prompts)))
    assert all(s in TERMINAL for s in status.values()), status
    for i, base in enumerate(baselines[mode]):
        if status[i] == DONE:
            np.testing.assert_array_equal(base, outs[i])
        else:  # failed/expired: partial output is a prefix of the stream
            np.testing.assert_array_equal(base[: len(outs[i])], outs[i])
    if eng._alloc is not None:
        eng._alloc.check()
    # conservation: every fault was either absorbed (retry/failure) or
    # the serve would not have terminated
    assert st_["failed"] == sum(s == "failed" for s in status.values())


def test_chaos_every_request_fails_still_terminates(chaos_fixture):
    """rate=1.0 — every check fires. The serve must still terminate with
    every request FAILED (budgets bound the total work) and the engine
    must stay usable."""
    cfg, params, prompts, baselines = chaos_fixture
    for mode in MODES:
        eng = Engine(params, cfg, _sc(mode, max_retries=2),
                     fault_injector=FaultInjector(rate=1.0, seed=0))
        outs = eng.serve(prompts, N_NEW)
        status = eng.stats()["request_status"]
        assert all(s == "failed" for s in status.values()), (mode, status)
        assert all(len(o) == 0 for o in outs)
        if eng._alloc is not None:
            eng._alloc.check()
        # the injector dies with the chaos run, not the engine: a fresh
        # fault-free serve on the same engine works
        eng._injector = None
        got = eng.serve(prompts, N_NEW)
        for b, g in zip(baselines[mode], got):
            np.testing.assert_array_equal(b, g)


def test_targeted_fault_isolation(chaos_fixture):
    """A request whose budget is exhausted goes FAILED while its live
    neighbors finish token-identically — per-request isolation, not the
    pre-PR-6 whole-pool reset."""
    cfg, params, prompts, baselines = chaos_fixture
    for mode in MODES:
        # page_alloc occurrence 0 is the FIRST admission (rid 0: highest
        # head-of-line rank); max_retries=0 makes that one fault terminal
        site = "page_alloc" if mode != "contig" else "kernel_dispatch"
        eng = Engine(params, cfg, _sc(mode, max_retries=0),
                     fault_injector=FaultInjector(schedule=[(site, 0)]))
        outs = eng.serve(prompts, N_NEW)
        status = eng.stats()["request_status"]
        assert status[0] == "failed", (mode, status)
        assert all(status[i] == DONE for i in range(1, len(prompts)))
        for i in range(1, len(prompts)):
            np.testing.assert_array_equal(baselines[mode][i], outs[i])


def test_deadline_expiry(chaos_fixture):
    """An overdue request is cancelled exactly like EOS: status EXPIRED,
    result = whatever it generated (a prefix of the fault-free stream);
    requests without deadlines are untouched."""
    cfg, params, prompts, baselines = chaos_fixture
    for mode in MODES:
        eng = Engine(params, cfg, _sc(mode))
        outs = eng.serve(prompts, N_NEW,
                         deadlines=[None, 0.0, None, 0.0])
        status = eng.stats()["request_status"]
        assert status[1] == EXPIRED and status[3] == EXPIRED, (mode, status)
        assert status[0] == DONE and status[2] == DONE
        for i in (0, 2):
            np.testing.assert_array_equal(baselines[mode][i], outs[i])
        for i in (1, 3):
            np.testing.assert_array_equal(
                baselines[mode][i][: len(outs[i])], outs[i])


# ---------------------------------------------------------------------------
# crash → snapshot → restore → resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_snapshot_restore_roundtrip(chaos_fixture, tmp_path, mode):
    """Kill the engine mid-serve, snapshot, restore into a FRESH engine,
    resume: every request's final stream is token-identical to the
    uninterrupted run, and (paged modes) the radix cache comes back warm
    from token chains alone — no KV arrays in the checkpoint."""
    cfg, params, prompts, baselines = chaos_fixture
    eng = Engine(params, cfg, _sc(mode),
                 fault_injector=FaultInjector(crash_after_checks=8))
    with pytest.raises(EngineCrash):
        eng.serve(prompts, N_NEW)
    eng.snapshot(str(tmp_path))

    eng2 = Engine(params, cfg, _sc(mode))
    state = eng2.restore(str(tmp_path))
    assert state["pending"]  # the crash left unfinished requests
    results = eng2.resume()
    assert set(results) == set(range(len(prompts)))
    for i, base in enumerate(baselines[mode]):
        np.testing.assert_array_equal(base, results[i])
    if mode != "contig":
        # chains re-warmed the radix tree: the resumed prefills hit it
        assert eng2.stats()["hit_tokens"] > 0
        eng2._alloc.check()


def test_snapshot_between_serves(chaos_fixture, tmp_path):
    """snapshot() is also valid at rest (no crash): it carries the done
    results and the warm cache of a completed serve."""
    cfg, params, prompts, baselines = chaos_fixture
    eng = Engine(params, cfg, _sc("paged"))
    eng.serve(prompts, N_NEW)
    eng.snapshot(str(tmp_path))
    eng2 = Engine(params, cfg, _sc("paged"))
    state = eng2.restore(str(tmp_path))
    assert not state["pending"]
    results = eng2.resume()
    for i, base in enumerate(baselines["paged"]):
        np.testing.assert_array_equal(base, results[i])
    # the restored cache serves the same prompts warm
    eng2.serve(prompts, N_NEW)
    assert eng2.stats()["hit_tokens"] > 0


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------

def test_kernel_fault_downgrades_to_jnp(chaos_fixture):
    """`downgrade_after` consecutive kernel-site faults on a `*_pallas`
    impl flip the engine to the registered jnp fallback and the serve
    completes (the streak, not total faults, is what triggers it)."""
    cfg, params, prompts, _ = chaos_fixture
    pcfg = dataclasses.replace(cfg, attn_impl="flashd_pallas")
    papi = get_model(pcfg)
    pparams = papi.init(jax.random.PRNGKey(0), pcfg)
    inj = FaultInjector(schedule=[("kernel_dispatch", i) for i in range(3)])
    eng = Engine(pparams, pcfg, ServeConfig(
        max_batch=1, max_len=32, downgrade_after=3, max_retries=8),
        fault_injector=inj)
    outs = eng.serve(prompts[:1], N_NEW)
    st_ = eng.stats()
    assert st_["downgrades"] == 1 and st_["attn_impl"] == "flashd"
    assert st_["request_status"][0] == DONE and len(outs[0]) == N_NEW


def test_fallback_registry_covers_all_ops():
    from repro.kernels import ops

    for name in ops.op_names():
        assert callable(ops.get_fallback(name))
    assert ops.fallback_impl("flashd_pallas") == "flashd"
    assert ops.fallback_impl("fa2_pallas") == "fa2"
    assert ops.fallback_impl("flashd") == "flashd"  # nothing to downgrade


# ---------------------------------------------------------------------------
# request lifecycle API
# ---------------------------------------------------------------------------

def test_serve_accepts_request_objects(chaos_fixture):
    """serve() takes Request objects carrying resume state: out-tokens
    replay through recompute-on-resume (the snapshot/restore path uses
    exactly this)."""
    cfg, params, prompts, baselines = chaos_fixture
    base = baselines["contig"]
    half = [Request(rid=i, prompt=prompts[i], out=list(base[i][:2]))
            for i in range(len(prompts))]
    eng = Engine(params, cfg, _sc("contig"))
    outs = eng.serve(half, N_NEW)
    for b, g in zip(base, outs):
        np.testing.assert_array_equal(b, g)


def test_scheduler_not_before_gates_admission():
    """A request whose backoff gate is in the future is invisible to
    head() until the gate passes — priority cannot override backoff."""
    req = Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                  not_before=5.0, priority=9)
    sched = Scheduler([req], 4, 2, eos_id=-1)
    assert sched.head() is None  # gated: 5s of backoff remain
    sched.queue[0].not_before = 0.0
    assert sched.head() is not None


def test_snapshot_rebases_backoff_to_remaining(chaos_fixture, tmp_path):
    """Regression: `not_before` is an absolute reading of the scheduler's
    monotonic clock, and a restored engine's clock restarts at zero. The
    snapshot used to persist the raw value — a request 0.2s from
    admission came back gated for its full original offset (or worse,
    forever, once clocks drifted). Backoff must round-trip as REMAINING
    seconds, exactly like deadlines."""
    cfg, params, prompts, baselines = chaos_fixture
    eng = Engine(params, cfg, _sc("paged"),
                 fault_injector=FaultInjector(crash_after_checks=8))
    with pytest.raises(EngineCrash):
        eng.serve(prompts, N_NEW)
    sched = eng._sched
    assert sched.queue  # the crash folded live slots back into the queue
    # leave one survivor mid-backoff, as a device-fault retry would
    victim = sched.queue[0]
    victim.retries = 1
    victim.not_before = sched.now() + 0.2
    eng.snapshot(str(tmp_path))

    eng2 = Engine(params, cfg, _sc("paged"))
    state = eng2.restore(str(tmp_path))
    by_rid = {p["rid"]: p for p in state["pending"]}
    rebased = by_rid[victim.rid]["not_before"]
    assert 0.0 < rebased <= 0.2, rebased  # remaining seconds, not absolute
    assert all(p["not_before"] == 0.0 for r, p in by_rid.items()
               if r != victim.rid)
    results = eng2.resume()  # waits out the 0.2s gate and finishes
    assert set(results) == set(range(len(prompts)))
    for i, base in enumerate(baselines["paged"]):
        np.testing.assert_array_equal(base, results[i])
