"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels execute in interpret mode on CPU (the kernel body runs op-by-op);
on a real TPU the same tests compile the Mosaic kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.blockwise import MaskSpec
from repro.kernels.fa2_fwd import fa2_fwd_pallas
from repro.kernels.flashd_decode import flashd_decode_pallas
from repro.kernels.flashd_fwd import flashd_fwd_pallas
from repro.kernels.ref import attention_ref, decode_ref


def _inputs(seed, b, hq, hkv, sq, skv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d)).astype(dtype)
    return q, k, v


_SHAPES = [
    # b, hq, hkv, sq, skv, d
    (1, 1, 1, 16, 16, 8),
    (2, 4, 2, 48, 64, 16),
    (1, 8, 1, 33, 57, 32),   # MQA, ragged sizes (padding path)
    (2, 6, 3, 24, 24, 64),   # 2:1 GQA
]


@pytest.mark.parametrize("shape", _SHAPES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("kernel", [flashd_fwd_pallas, fa2_fwd_pallas])
def test_fwd_kernels_sweep(shape, dtype, tol, kernel):
    b, hq, hkv, sq, skv, d = shape
    q, k, v = _inputs(0, *shape, dtype)
    for mask in [MaskSpec("full"), MaskSpec("causal")]:
        o, lam = kernel(q, k, v, mask=mask, block_q=16, block_k=16, interpret=True)
        o_ref, lam_ref = attention_ref(q, k, v, mask=mask)
        np.testing.assert_allclose(
            o.astype(jnp.float32), o_ref.astype(jnp.float32), rtol=tol, atol=tol
        )
        live = lam_ref > -1e29
        np.testing.assert_allclose(
            jnp.where(live, lam, 0.0), jnp.where(live, lam_ref, 0.0),
            rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4, atol=1e-2,
        )


def _drawn_mask(maskkind, maskparam, skv):
    if maskkind == "local":
        return MaskSpec("local", window=1 + maskparam % max(skv, 1))
    if maskkind == "chunked":
        return MaskSpec("chunked", chunk=1 + maskparam % max(skv, 1))
    return MaskSpec(maskkind)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    b=st.integers(min_value=1, max_value=2),
    hkv=st.integers(min_value=1, max_value=2),
    group=st.sampled_from([1, 2, 4]),
    sq=st.integers(min_value=1, max_value=40),
    skv=st.integers(min_value=1, max_value=40),
    d=st.sampled_from([8, 16, 32]),
    maskkind=st.sampled_from(["full", "causal", "local", "chunked"]),
    maskparam=st.integers(min_value=0, max_value=63),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_fwd_kernels_property_sweep(seed, b, hkv, group, sq, skv, d,
                                    maskkind, maskparam, dtype):
    """flashd_fwd == fa2_fwd == reference across the fuzzed shape/mask grid
    in BOTH f32 and bf16 (dtype-appropriate tolerances): the two kernels
    must agree with the oracle and — more tightly — with each other, since
    they consume identical tiles and differ only in the carry algebra."""
    dt = jnp.dtype(dtype)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    mask = _drawn_mask(maskkind, maskparam, skv)
    q, k, v = _inputs(seed % 1000, b, hkv * group, hkv, sq, skv, d, dt)
    o_fd, l_fd = flashd_fwd_pallas(q, k, v, mask=mask, block_q=16, block_k=16,
                                   interpret=True)
    o_fa, l_fa = fa2_fwd_pallas(q, k, v, mask=mask, block_q=16, block_k=16,
                                interpret=True)
    o_ref, l_ref = attention_ref(q, k, v, mask=mask)
    for o in (o_fd, o_fa):
        np.testing.assert_allclose(
            o.astype(jnp.float32), o_ref.astype(jnp.float32), rtol=tol, atol=tol
        )
    # kernel-vs-kernel: same tiles, same masks — tighter than vs the oracle
    np.testing.assert_allclose(
        o_fd.astype(jnp.float32), o_fa.astype(jnp.float32),
        rtol=tol / 2, atol=tol / 2,
    )
    live = l_ref > -1e29  # fully-masked rows park Λ at NEG_INF sentinels
    lam_tol = 1e-2 if dt == jnp.bfloat16 else 1e-4
    for lam in (l_fd, l_fa):
        np.testing.assert_allclose(
            jnp.where(live, lam, 0.0), jnp.where(live, l_ref, 0.0),
            rtol=lam_tol, atol=lam_tol,
        )


@pytest.mark.parametrize("mask", [
    MaskSpec("local", window=7), MaskSpec("chunked", chunk=16),
])
def test_fwd_kernel_structured_masks(mask):
    q, k, v = _inputs(1, 2, 4, 2, 48, 48, 16, jnp.float32)
    o, _ = flashd_fwd_pallas(q, k, v, mask=mask, block_q=16, block_k=16, interpret=True)
    o_ref, _ = attention_ref(q, k, v, mask=mask)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)


def test_flashd_kernel_skip_exact():
    """Tile-skip predication must not change results beyond σ(−θ) mass."""
    q, k, v = _inputs(2, 1, 2, 1, 64, 128, 16, jnp.float32)
    q = q * 3.0
    o0, _ = flashd_fwd_pallas(q, k, v, mask=MaskSpec("causal"), block_q=16,
                              block_k=16, skip=False, interpret=True)
    o1, _ = flashd_fwd_pallas(q, k, v, mask=MaskSpec("causal"), block_q=16,
                              block_k=16, skip=True, interpret=True)
    np.testing.assert_allclose(o0, o1, atol=5e-3)


def test_flashd_kernel_matches_fa2_kernel():
    q, k, v = _inputs(3, 2, 4, 4, 32, 32, 16, jnp.float32)
    o1, l1 = flashd_fwd_pallas(q, k, v, mask=MaskSpec("causal"), block_q=8,
                               block_k=8, interpret=True)
    o2, l2 = fa2_fwd_pallas(q, k, v, mask=MaskSpec("causal"), block_q=8,
                            block_k=8, interpret=True)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_splits", [1, 2, 4, 8])
@pytest.mark.parametrize("w,c", [(0, 0), (12, 0), (0, 16)])
def test_decode_kernel_sweep(n_splits, w, c):
    rng = np.random.default_rng(4)
    b, hq, hkv, s, d = 3, 8, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    cl = jnp.asarray([64, 17, 33], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, n_splits=n_splits, window=w,
                             chunk=c, interpret=True)
    o_ref = decode_ref(q, kc, vc, cl, window=w, chunk=c)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)


def test_decode_kernel_bf16():
    rng = np.random.default_rng(5)
    b, hq, hkv, s, d = 2, 4, 4, 32, 32
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.bfloat16)
    kc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.bfloat16)
    cl = jnp.asarray([32, 9], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, n_splits=4, interpret=True)
    o_ref = decode_ref(q, kc, vc, cl)
    np.testing.assert_allclose(
        o.astype(jnp.float32), o_ref.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("mask", [
    MaskSpec("full"),
    MaskSpec("causal"),
    # structured masks exercise the bwd tile-pruning predicate (tile_live)
    MaskSpec("local", window=7),
    MaskSpec("local", window=20),
    MaskSpec("chunked", chunk=16),
    MaskSpec("chunked", chunk=8),
])
def test_bwd_kernel_vs_autodiff(hq, hkv, mask):
    """Pallas backward (dq/dkv kernels) == autodiff of the oracle."""
    from repro.kernels.flashd_bwd import flashd_bwd_pallas

    rng = np.random.default_rng(7)
    b, sq, skv, d = 2, 33, 49, 16
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    do = jnp.asarray(rng.normal(size=(b, hq, sq, d)), jnp.float32)
    o, lam = attention_ref(q, k, v, mask=mask)
    dq, dk, dv = flashd_bwd_pallas(
        q, k, v, o, lam, do, mask=mask, block_q=16, block_k=16, interpret=True
    )

    def loss(q, k, v):
        o, _ = attention_ref(q, k, v, mask=mask)
        return jnp.sum(o * do)

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for got, want in zip((dq, dk, dv), g):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    hkv=st.integers(min_value=1, max_value=2),
    group=st.sampled_from([1, 2, 4]),
    sq=st.integers(min_value=1, max_value=36),
    skv=st.integers(min_value=1, max_value=36),
    d=st.sampled_from([8, 16]),
    maskkind=st.sampled_from(["full", "causal", "local", "chunked"]),
    maskparam=st.integers(min_value=0, max_value=63),
)
def test_bwd_kernel_property_vs_autodiff(seed, hkv, group, sq, skv, d,
                                         maskkind, maskparam):
    """Gradient property: flashd_bwd (dq/dkv Pallas kernels) == jax.grad of
    the reference attention on randomized shapes AND randomized mask
    parameters — not just the fixed window/chunk cases. Catches tile-edge
    bugs (ragged sq/skv vs block 16) and mask-boundary dΛ terms the
    enumerated suite cannot reach."""
    from repro.kernels.flashd_bwd import flashd_bwd_pallas

    mask = _drawn_mask(maskkind, maskparam, skv)
    rng = np.random.default_rng(seed % 100000)
    hq = hkv * group
    q = jnp.asarray(rng.normal(size=(2, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, hkv, skv, d)), jnp.float32)
    do = jnp.asarray(rng.normal(size=(2, hq, sq, d)), jnp.float32)
    o, lam = attention_ref(q, k, v, mask=mask)
    dq, dk, dv = flashd_bwd_pallas(
        q, k, v, o, lam, do, mask=mask, block_q=16, block_k=16, interpret=True
    )

    def loss(q, k, v):
        o, _ = attention_ref(q, k, v, mask=mask)
        return jnp.sum(o * do)

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for got, want in zip((dq, dk, dv), g):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dead_q_rows_zero_everywhere():
    """sq > skv + window leaves q rows with NO visible key. Forward kernels
    and the (fixed) oracle must emit zeros + Λ = NEG_INF for them — not the
    uniform-softmax artifact logsumexp(-1e30·k) invites — and the backward
    must stay finite with zero grads flowing through those rows."""
    mask = MaskSpec("local", window=12)
    q, k, v = _inputs(9, 1, 2, 1, 35, 17, 16, jnp.float32)
    o_ref, lam_ref = attention_ref(q, k, v, mask=mask)
    dead = np.asarray(lam_ref) <= -1e29
    assert dead.any()  # rows ≥ skv + window − 1 are dead by construction
    np.testing.assert_array_equal(np.asarray(o_ref)[dead], 0.0)
    for kernel in (flashd_fwd_pallas, fa2_fwd_pallas):
        o, lam = kernel(q, k, v, mask=mask, block_q=16, block_k=16, interpret=True)
        np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)
        assert (np.asarray(lam)[dead] <= -1e29).all()


def test_full_pallas_train_path():
    """End-to-end: flash_attention(impl=flashd_pallas) forward + the Pallas
    backward kernels inside jax.grad — grads match the jnp path."""
    from repro.core.attention import flash_attention

    rng = np.random.default_rng(8)
    b, s, hq, hkv, d = 2, 24, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)

    def loss(impl, q, k, v):
        o = flash_attention(q, k, v, mask=MaskSpec("causal"), impl=impl,
                            block_q=8, block_k=8)
        return jnp.sum(jnp.tanh(o))

    g_pallas = jax.grad(lambda *a: loss("flashd_pallas", *a), argnums=(0, 1, 2))(q, k, v)
    g_jnp = jax.grad(lambda *a: loss("flashd", *a), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_pallas, g_jnp):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-5)
