"""Scheduler invariants + mixed-step serving (DESIGN.md §3.5).

Host-side scheduler: token budget respected, decode slots never starved,
FIFO admission and prefill ordering, EOS/max-token completion. Engine:
`serve()` through the mixed varlen step is token-identical to the
sequential contiguous and paged engines (greedy), including under the
Pallas varlen kernel; prompt bucketing pins the compiled-program count at
O(log max_len) across many distinct prompt lengths.
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import paper_llama
from repro.models import get_model
from repro.serve import Engine, Scheduler, ServeConfig


def _cfg(**kw):
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64, **kw,
    )


def _reqs(rng, lens, vocab=64):
    return [rng.integers(0, vocab, (n,)).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# pure scheduler invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_slots=st.integers(min_value=1, max_value=4),
    n_reqs=st.integers(min_value=1, max_value=8),
    budget=st.integers(min_value=1, max_value=12),
    pchunk=st.integers(min_value=1, max_value=6),
    max_new=st.integers(min_value=1, max_value=5),
)
def test_mixed_schedule_invariants(seed, n_slots, n_reqs, budget, pchunk, max_new):
    """Drive plan/commit to completion with fake sampled tokens and check:
    budget respected (with the decode floor), decode slots never skipped,
    prefill budget granted FIFO, every request completes, results FIFO-
    consistent with per-request greedy order."""
    rng = np.random.default_rng(seed)
    reqs = _reqs(rng, rng.integers(1, 9, size=n_reqs))
    sched = Scheduler(reqs, max_new, n_slots, eos_id=-1)

    def admit_all():
        while (s := sched.free_slot()) is not None and sched.head():
            rid, prompt = sched.take_head()
            sched.admit_prefilling(s, rid, prompt)

    admit_all()
    admitted_order = []
    steps = 0
    while sched.has_active():
        steps += 1
        assert steps < 1000, "scheduler did not converge"
        decoding_before = [
            s for s, sl in enumerate(sched.slots) if sl.live and not sl.prefilling
        ]
        plan = sched.plan_step(budget, pchunk)
        # budget: total tokens ≤ max(budget, #decoding) — decode floor only
        assert plan.n_tokens <= max(budget, len(decoding_before))
        # decode slots never starve: every decoding slot is in the plan
        planned = {g.slot for g in plan.segments}
        assert set(decoding_before) <= planned
        for g in plan.segments:
            if g.slot in decoding_before:
                assert len(g.tokens) == 1 and g.emits
        # prefill budget granted in FIFO (request-id) order: the planned
        # prefill slots must be the lowest-rid prefilling slots
        pre_planned = [g.slot for g in plan.segments if g.slot not in decoding_before]
        pre_rids = sorted(
            sched.slots[s].rid for s, sl in enumerate(sched.slots) if sl.prefilling
        )
        got_rids = sorted(sched.slots[s].rid for s in pre_planned)
        assert got_rids == pre_rids[: len(got_rids)]
        # chunks never exceed prefill_chunk
        for g in plan.segments:
            if g.slot in pre_planned:
                assert len(g.tokens) <= pchunk
        sampled = rng.integers(0, 64, size=(len(sched.slots),)).astype(np.int32)
        for s in sched.commit(plan, sampled):
            admitted_order.append(sched.slots[s].rid)
            sched.retire(s)
        admit_all()
    outs = sched.results_list()
    assert all(len(o) == max_new for o in outs)


def test_scheduler_eos_and_immediate_finish():
    sched = Scheduler([np.asarray([1, 2])] * 3, 5, 2, eos_id=9)
    # immediate finish: first token is EOS → slot never taken
    assert not sched.admit_or_finish(0, 0, np.asarray([1, 2]), 9)
    assert sched.results[0].tolist() == [9]
    # normal path then EOS mid-chunk: speculative tail discarded
    assert sched.admit_or_finish(0, 1, np.asarray([1, 2]), 4)
    toks = np.asarray([[7], [9], [3]], np.int32)  # chunk of 3, slot 0 only
    finished = sched.absorb_chunk(toks)
    assert finished == [0]
    assert sched.results[1].tolist() == [4, 7, 9]  # stops at eos, drops 3
    assert sched.retire(0) == 1
    # max_new completion
    assert sched.admit_or_finish(1, 2, np.asarray([1, 2]), 5)
    finished = sched.absorb_chunk(np.asarray([[0], [0], [0], [0], [0]]).reshape(5, 1).repeat(2, 1)[:, :2])
    assert finished == [1]
    assert len(sched.results[2]) == 5


def test_scheduler_fifo_head_of_line():
    """Later requests never jump a blocked head: take_head is the only way
    out of the queue and it pops in arrival order."""
    reqs = [np.asarray([i]) for i in range(5)]
    sched = Scheduler(reqs, 2, 2, eos_id=-1)
    seen = []
    while sched.head() is not None:
        rid, _ = sched.take_head()
        seen.append(rid)
    assert seen == [0, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# engine: mixed == sequential (greedy token identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attn_impl", ["flashd", "flashd_pallas"])
def test_serve_mixed_token_identical(attn_impl):
    cfg = _cfg(attn_impl=attn_impl)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = _reqs(rng, (4, 9, 6, 12, 3, 5))
    n_new = 4
    base = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, n_new)
    paged = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, kv_layout="paged")).serve(reqs, n_new)
    mixed = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, step_mode="mixed", prefill_chunk=4,
        token_budget=8)).serve(reqs, n_new)
    for a, b, c in zip(base, paged, mixed):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_serve_mixed_long_prompt_interleaves():
    """A long prompt arriving while others decode must not block them: the
    mixed engine finishes short requests in fewer steps than the long
    prompt's prefill alone would take (chunked-prefill interleaving)."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(4)
    reqs = _reqs(rng, (3, 24, 3))  # short, LONG, short
    eng = Engine(params, cfg, ServeConfig(
        max_batch=3, max_len=40, step_mode="mixed", prefill_chunk=4,
        token_budget=8))
    outs = eng.serve(reqs, max_new_tokens=3)
    assert all(len(o) == 3 for o in outs)
    # identical to the sequential result
    want = Engine(params, cfg, ServeConfig(max_batch=3, max_len=40)).serve(reqs, 3)
    for a, b in zip(outs, want):
        np.testing.assert_array_equal(a, b)


def test_serve_mixed_immediate_eos_and_max1():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    reqs = _reqs(rng, (4, 6))
    base = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, 1)
    mixed = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, step_mode="mixed")).serve(reqs, 1)
    for a, b in zip(base, mixed):
        np.testing.assert_array_equal(a, b)
    # force an early EOS: run 5 tokens, pick req0's 2nd token as eos
    probe = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, 5)
    eos = int(probe[0][1])
    a = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, eos_id=eos)).serve(reqs, 5)
    b = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, eos_id=eos, step_mode="mixed")).serve(reqs, 5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_serve_mixed_falls_back_without_global_attn():
    """Stacks the packed step cannot run (ring-region mixers) silently use
    the sequential path and still serve correctly."""
    cfg = _cfg(pattern=(("attn_local", "swiglu"),), attn_window=8)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    reqs = _reqs(rng, (4, 5))
    eng = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, step_mode="mixed"))
    assert not eng._mixed_ok
    outs = eng.serve(reqs, 3)
    want = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, 3)
    for a, b in zip(outs, want):
        np.testing.assert_array_equal(a, b)


def test_serve_mixed_pool_pressure_waits_fifo():
    """A pool too small for all requests at once completes them all in
    order by waiting for frees (head-of-line admission)."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    reqs = _reqs(rng, (6, 6, 6, 6))
    tight = Engine(params, cfg, ServeConfig(
        max_batch=4, max_len=32, step_mode="mixed",
        kv_pool_tokens=16, page_size=4))
    outs = tight.serve(reqs, 3)
    want = Engine(params, cfg, ServeConfig(max_batch=4, max_len=32)).serve(reqs, 3)
    for a, b in zip(outs, want):
        np.testing.assert_array_equal(a, b)
    assert tight.peak_active < 4  # the pool really did gate admission


# ---------------------------------------------------------------------------
# trace-count pins (static-shape bucketing)
# ---------------------------------------------------------------------------

def test_prefill_trace_count_logarithmic():
    """Serving many distinct prompt lengths compiles O(log max_len) prefill
    programs, not one per length (power-of-two bucketing + lengths mask)."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    lens = list(range(1, 17))  # 16 distinct lengths
    reqs = _reqs(rng, lens)
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_len=64))
    eng.serve(reqs, max_new_tokens=2)
    n_traces = eng._prefill._cache_size()
    # buckets 8 and 16 only → 2 programs; allow slack but far below 16
    assert n_traces <= 4, f"{n_traces} prefill traces for {len(lens)} lengths"

    # greedy result unchanged by bucketing: solo generate matches serve
    solo = eng.generate(reqs[10][None], 2)[0]
    outs = eng.serve([reqs[10]], 2)
    np.testing.assert_array_equal(outs[0], solo)


def test_mixed_step_trace_count_bucketed():
    """Mixed steps retrace per packed-length BUCKET, not per packed length:
    a workload with many distinct per-step token counts stays ≤ log2."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    reqs = _reqs(rng, (1, 3, 5, 7, 9, 11, 13, 2))
    eng = Engine(params, cfg, ServeConfig(
        max_batch=3, max_len=32, step_mode="mixed", prefill_chunk=3,
        token_budget=9))
    eng.serve(reqs, max_new_tokens=3)
    assert eng._mixed._cache_size() <= 4
