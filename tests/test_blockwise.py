"""Blockwise (tiled) FLASH-D: the paper's tiling-preserved claim, per-tile.

Key invariants: tile-size independence (any B_q × B_k gives the same
output), agreement with FA2 tiling and the naive oracle, mask handling at
tile boundaries, exactness of the split-K sigmoid merge, and that the
tile-skip predication is numerically inert.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.blockwise import (
    MaskSpec,
    blockwise_fa2,
    blockwise_flashd,
    merge_partials,
)
from repro.core import naive_attention


def _qkv(seed, sq, skv, d, dv, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (sq, d)) * scale,
        jax.random.normal(ks[1], (skv, d)),
        jax.random.normal(ks[2], (skv, dv)),
    )


def _naive(q, k, v, mask):
    s = q @ k.T
    bias = mask.block_bias(jnp.arange(q.shape[0]), jnp.arange(k.shape[0]))
    if bias is not None:
        s = s + bias
    lam = jax.nn.logsumexp(s, axis=-1)
    return jnp.exp(s - lam[:, None]) @ v, lam


@pytest.mark.parametrize("bq,bk", [(1, 1), (4, 8), (16, 16), (64, 64), (13, 7)])
@pytest.mark.parametrize("maskkind", ["full", "causal", "local", "chunked"])
def test_tile_size_invariance(bq, bk, maskkind):
    mask = MaskSpec(maskkind, window=9, chunk=16)
    q, k, v = _qkv(0, 37, 53, 16, 8, scale=2.0)
    o, lam = blockwise_flashd(q, k, v, mask=mask, scale=1.0, block_q=bq, block_k=bk)
    o_ref, lam_ref = _naive(q, k, v, mask)
    np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    sq=st.integers(1, 60),
    skv=st.integers(1, 60),
    bq=st.integers(1, 64),
    bk=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_flashd_matches_fa2_property(sq, skv, bq, bk, seed):
    """FLASH-D tiling ≡ FA2 tiling ≡ oracle — over random tilings/shapes."""
    q, k, v = _qkv(seed, sq, skv, 8, 8)
    mask = MaskSpec("full")
    o1, l1 = blockwise_flashd(q, k, v, mask=mask, scale=1.0, block_q=bq, block_k=bk)
    o2, l2 = blockwise_fa2(q, k, v, mask=mask, scale=1.0, block_q=bq, block_k=bk)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_blockwise_collapses_to_alg3():
    """With B_q = B_k = 1 the tile recurrence IS the paper's Alg. 3."""
    from repro.core import flashd_alg3

    q, k, v = _qkv(5, 6, 21, 8, 4)
    o, _ = blockwise_flashd(q, k, v, mask=MaskSpec("full"), scale=1.0, block_q=1, block_k=1)
    for i in range(q.shape[0]):
        np.testing.assert_allclose(o[i], flashd_alg3(q[i], k, v), rtol=2e-5, atol=2e-5)


def test_skip_inert_and_counts():
    q, k, v = _qkv(1, 32, 64, 16, 16, scale=4.0)
    mask = MaskSpec("causal")
    o0, _ = blockwise_flashd(q, k, v, mask=mask, block_q=8, block_k=8)
    o1, _, rate = blockwise_flashd(
        q, k, v, mask=mask, block_q=8, block_k=8, skip=True, return_skiprate=True
    )
    np.testing.assert_allclose(o0, o1, atol=5e-3)
    assert 0.0 <= float(rate) < 1.0


def test_merge_partials_exact():
    """Split-K FLASH-D merge == attention over the concatenated keys."""
    q, k, v = _qkv(7, 10, 64, 8, 8)
    parts = []
    for i in range(4):
        o, lam = blockwise_flashd(
            q, k[i * 16:(i + 1) * 16], v[i * 16:(i + 1) * 16],
            mask=MaskSpec("full"), scale=1.0, block_q=8, block_k=8,
        )
        parts.append((o, lam))
    o, lam = merge_partials(
        jnp.stack([p[0] for p in parts]), jnp.stack([p[1] for p in parts])
    )
    o_ref, lam_ref = _naive(q, k, v, MaskSpec("full"))
    np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-4, atol=1e-4)


def test_merge_partials_with_empty_split():
    """A fully-masked (dead) partial must be a no-op in the merge."""
    q, k, v = _qkv(9, 4, 16, 8, 8)
    o1, l1 = blockwise_flashd(q, k, v, mask=MaskSpec("full"), scale=1.0)
    dead_o = jnp.zeros_like(o1)
    dead_l = jnp.full_like(l1, -1e30)
    o, lam = merge_partials(jnp.stack([o1, dead_o]), jnp.stack([l1, dead_l]))
    np.testing.assert_allclose(o, o1, rtol=1e-6)
    o, lam = merge_partials(jnp.stack([dead_o, o1]), jnp.stack([dead_l, l1]))
    np.testing.assert_allclose(o, o1, rtol=1e-6)


def test_merge_partials_log_depth_and_odd_counts():
    """merge_partials reduces as a pairwise tree: ⌈log₂P⌉ blend levels (one
    vectorized sigmoid each) instead of a P−1-step sequential scan, and odd
    partial counts carry the leftover up a level without loss."""
    import math

    rng = np.random.default_rng(13)
    for p in (2, 3, 5, 8, 11):
        q, k, v = _qkv(p, 6, p * 8, 8, 8)
        parts = [
            blockwise_flashd(
                q, k[i * 8:(i + 1) * 8], v[i * 8:(i + 1) * 8],
                mask=MaskSpec("full"), scale=1.0, block_q=8, block_k=8,
            )
            for i in range(p)
        ]
        o_parts = jnp.stack([x[0] for x in parts])
        lam_parts = jnp.stack([x[1] for x in parts])
        o, lam = merge_partials(o_parts, lam_parts)
        o_ref, lam_ref = _naive(q, k, v, MaskSpec("full"))
        np.testing.assert_allclose(o, o_ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(lam, lam_ref, rtol=1e-4, atol=1e-4)
        # log-depth witness: one sigmoid (logistic) per tree level — the
        # old lax.scan form hid P−1 of them inside a scan body
        jaxpr = jax.make_jaxpr(merge_partials)(o_parts, lam_parts)
        n_sig = sum(1 for e in jaxpr.jaxpr.eqns if e.primitive.name == "logistic")
        assert 1 <= n_sig <= math.ceil(math.log2(p)) + 1, (p, n_sig)
        assert not any(e.primitive.name == "scan" for e in jaxpr.jaxpr.eqns)


def test_fully_masked_rows():
    """chunked mask with q_offset can mask whole rows; output must be 0/finite."""
    q, k, v = _qkv(11, 8, 8, 4, 4)
    mask = MaskSpec("local", window=1)
    o, lam = blockwise_flashd(q, k, v, mask=mask, scale=1.0, block_q=4, block_k=4)
    assert bool(jnp.all(jnp.isfinite(o)))
