"""Property-based differential suite for the paged KV-cache path.

Three kernel families now share masks, splits and the FLASH-D sigmoid
merge (flashd/fa2 forward + bwd, fused/unfused decode, ring/cp) and the
paged decode adds block-table indirection on top — hand-enumerated cases
no longer cover the cross-product. This fuzzer draws
batch / GQA ratio / head_dim / page geometry / ragged cache_len / mask
family and asserts the three-way agreement

    paged decode (block-table gather) == contiguous fused decode == decode_ref

including the edges the allocator produces in real schedules: empty
sequences (cache_len = 0), a page boundary exactly at cache_len, a full
table, and block tables pointing at arbitrary (non-contiguous, unsorted)
physical pages. Engine-level properties: paged `serve` is token-identical
to the contiguous engine, shared-prefix CoW admission diverges without
cross-talk, and a page-starved pool still completes every request by
waiting for frees.

Runs on the real `hypothesis` when installed and on the deterministic
stub in `tests/conftest.py` otherwise (CI exercises both).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.attention import decode_attention_paged, gather_pages
from repro.kernels.flashd_decode import (
    flashd_decode_paged_pallas,
    flashd_decode_pallas,
)
from repro.kernels.ref import decode_ref

_F32_TOL = 1e-4  # acceptance bound; observed agreement is ~2 f32 ulps


def _paged_case(seed, b, hkv, group, d, n_tbl, page, edge):
    """Random pool + per-row block tables of distinct physical pages
    (page 0 left as the garbage page, like the engine convention)."""
    rng = np.random.default_rng(seed)
    hq = hkv * group
    s_max = n_tbl * page
    n_pool = b * n_tbl + 2
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n_pool, page, hkv, d)), jnp.float32)
    perm = rng.permutation(np.arange(1, n_pool))[: b * n_tbl].reshape(b, n_tbl)
    tbl = jnp.asarray(perm, jnp.int32)
    if edge == "empty":
        cl = np.zeros((b,), np.int32)  # no visible key anywhere
    elif edge == "page_boundary":  # cache_len exactly at a page edge
        cl = page * rng.integers(0, n_tbl + 1, size=(b,))
    elif edge == "full":
        cl = np.full((b,), s_max, np.int32)
    else:
        cl = rng.integers(0, s_max + 1, size=(b,))
    return q, k_pages, v_pages, tbl, jnp.asarray(cl, jnp.int32)


def _mask_kw(maskkind, maskparam, s_max):
    if maskkind == "window":
        return {"window": 1 + maskparam % s_max, "chunk": 0}
    if maskkind == "chunk":
        return {"window": 0, "chunk": 1 + maskparam % s_max}
    return {"window": 0, "chunk": 0}


@settings(max_examples=14, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    b=st.integers(min_value=1, max_value=3),
    hkv=st.integers(min_value=1, max_value=2),
    group=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([8, 16, 32]),
    n_tbl=st.integers(min_value=1, max_value=4),
    page=st.sampled_from([4, 8, 16]),
    maskkind=st.sampled_from(["none", "window", "chunk"]),
    maskparam=st.integers(min_value=0, max_value=63),
    edge=st.sampled_from(["rand", "empty", "page_boundary", "full"]),
)
def test_paged_differential_fuzz(seed, b, hkv, group, d, n_tbl, page,
                                 maskkind, maskparam, edge):
    """paged kernel == contiguous fused kernel == decode_ref, model layout
    gather as the bridge, across the fuzzed shape/mask/raggedness grid."""
    q, k_pages, v_pages, tbl, cl = _paged_case(
        seed, b, hkv, group, d, n_tbl, page, edge
    )
    s_max = n_tbl * page
    kw = _mask_kw(maskkind, maskparam, s_max)

    o_paged = flashd_decode_paged_pallas(
        q, k_pages, v_pages, tbl, cl, interpret=True, **kw
    )
    # contiguous oracle: materialize the block-table gather
    kc = jnp.moveaxis(k_pages[tbl], 3, 1).reshape(-1, k_pages.shape[2], s_max, d)
    vc = jnp.moveaxis(v_pages[tbl], 3, 1).reshape(-1, v_pages.shape[2], s_max, d)
    o_fused = flashd_decode_pallas(
        q, kc, vc, cl, n_splits=n_tbl, fused=True, interpret=True, **kw
    )
    o_ref = decode_ref(q, kc, vc, cl, **kw)
    np.testing.assert_allclose(o_paged, o_fused, rtol=0, atol=_F32_TOL)
    np.testing.assert_allclose(o_paged, o_ref, rtol=_F32_TOL, atol=_F32_TOL)
    # dead rows obey the zero (dead-partial) convention through the table
    for i, n in enumerate(np.asarray(cl)):
        if n == 0:
            np.testing.assert_array_equal(np.asarray(o_paged[i]), 0.0)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    group=st.sampled_from([1, 4]),
    page=st.sampled_from([4, 8]),
    n_tbl=st.integers(min_value=1, max_value=3),
)
def test_paged_jnp_route_matches_kernel(seed, group, page, n_tbl):
    """core.decode_attention_paged (gather + split-K jnp path — what
    non-pallas impls and the CP fallback run) agrees with the paged kernel
    and with gather_pages feeding decode_ref."""
    b, hkv, d = 2, 2, 16
    q, k_pages, v_pages, tbl, cl = _paged_case(
        seed, b, hkv, group, d, n_tbl, page, "rand"
    )
    o_jnp = decode_attention_paged(q[:, None], k_pages, v_pages, tbl, cl)[:, 0]
    o_kern = flashd_decode_paged_pallas(q, k_pages, v_pages, tbl, cl,
                                        interpret=True)
    np.testing.assert_allclose(o_jnp, o_kern, rtol=_F32_TOL, atol=_F32_TOL)
    # gather_pages is the shared bridge: one reshape of the table gather
    kc = gather_pages(k_pages, tbl)  # [B, S, Hkv, d] model layout
    np.testing.assert_array_equal(
        np.asarray(kc),
        np.asarray(k_pages)[np.asarray(tbl)].reshape(b, n_tbl * page, hkv, d),
    )


def test_paged_bf16_tolerance():
    q, k_pages, v_pages, tbl, cl = _paged_case(11, 2, 2, 2, 16, 3, 8, "rand")
    qb = q.astype(jnp.bfloat16)
    kb, vb = k_pages.astype(jnp.bfloat16), v_pages.astype(jnp.bfloat16)
    o = flashd_decode_paged_pallas(qb, kb, vb, tbl, cl, interpret=True)
    assert o.dtype == jnp.bfloat16
    kc = jnp.moveaxis(kb[tbl], 3, 1).reshape(2, 2, 24, 16)
    vc = jnp.moveaxis(vb[tbl], 3, 1).reshape(2, 2, 24, 16)
    o_ref = decode_ref(qb, kc, vc, cl)
    np.testing.assert_allclose(
        o.astype(jnp.float32), o_ref.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )


def test_paged_garbage_table_slots_are_inert():
    """Table entries past cache_len may hold anything (the engine points
    dead rows at page 0): they must not leak into the output."""
    q, k_pages, v_pages, tbl, cl = _paged_case(3, 2, 1, 2, 8, 3, 4, "rand")
    cl = jnp.asarray([5, 9], jnp.int32)  # live pages: ⌈5/4⌉=2, ⌈9/4⌉=3
    o1 = flashd_decode_paged_pallas(q, k_pages, v_pages, tbl, cl, interpret=True)
    tbl2 = tbl.at[0, 2].set(0)  # row 0's dead tail page → garbage page
    o2 = flashd_decode_paged_pallas(q, k_pages, v_pages, tbl2, cl, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# ---------------------------------------------------------------------------
# engine-level properties
# ---------------------------------------------------------------------------

def _cfg(**kw):
    from repro.configs import paper_llama

    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64, **kw,
    )


@pytest.fixture(scope="module")
def engine_fixture():
    from repro.models import get_model

    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_paged_serve_matches_contiguous(engine_fixture):
    """Token-identical continuous batching: the paged engine (pool +
    block tables + tail prefills) reproduces the contiguous engine's
    outputs for the same queue."""
    from repro.serve import Engine, ServeConfig

    cfg, params = engine_fixture
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in (4, 9, 6, 3, 7)]
    want = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, 5)
    eng = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=32, kv_layout="paged", page_size=8))
    got = eng.serve(reqs, 5)
    for a, c in zip(want, got):
        np.testing.assert_array_equal(a, c)


def test_paged_serve_pallas_kernel_route(engine_fixture):
    """attn_impl=flashd_pallas decodes through the scalar-prefetch paged
    kernel inside the jitted chunk loop — same tokens as the jnp engine."""
    from repro.serve import Engine, ServeConfig

    cfg, params = engine_fixture
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (5, 8)]
    want = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, 4)
    got = Engine(params, dataclasses.replace(cfg, attn_impl="flashd_pallas"),
                 ServeConfig(max_batch=2, max_len=32, kv_layout="paged",
                             page_size=8)).serve(reqs, 4)
    for a, c in zip(want, got):
        np.testing.assert_array_equal(a, c)


def test_paged_shared_prefix_cow_after_divergence(engine_fixture):
    """Prompts sharing a >page prefix admit by reference + boundary CoW;
    after they diverge, every stream must still match the unshared
    contiguous engine (a corrupted shared page would flip the parent's or
    a sibling's tokens)."""
    from repro.serve import Engine, ServeConfig

    cfg, params = engine_fixture
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    reqs = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)])
        for n in (3, 2, 5)
    ]
    want = Engine(params, cfg, ServeConfig(max_batch=3, max_len=32)).serve(reqs, 5)
    eng = Engine(params, cfg, ServeConfig(
        max_batch=3, max_len=32, kv_layout="paged", page_size=8,
        prefix_sharing=True))
    got = eng.serve(reqs, 5)
    for a, c in zip(want, got):
        np.testing.assert_array_equal(a, c)


def test_paged_admission_waits_for_free_pages(engine_fixture):
    """Without preemption, admission reserves the worst case: a pool too
    small for all requests at once still completes every one (head-of-line
    requests wait for frees) and outputs match the ample-pool engine. With
    preemption (the default), the same pool is oversubscribed instead —
    every request admits optimistically and outputs stay identical."""
    from repro.serve import Engine, ServeConfig

    cfg, params = engine_fixture
    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32) for _ in range(4)]
    ample = Engine(params, cfg, ServeConfig(
        max_batch=4, max_len=32, kv_layout="paged", page_size=8,
        prefix_sharing=False))
    want = ample.serve(reqs, 4)
    tight = Engine(params, cfg, ServeConfig(
        max_batch=4, max_len=32, kv_layout="paged", page_size=8,
        kv_pool_tokens=48, prefix_sharing=False, preemption=False))
    got = tight.serve(reqs, 4)
    assert all(o.shape == (4,) for o in got)
    # the tight pool cannot host all four worst-case reservations at once
    assert tight.peak_active < 4
    for a, c in zip(want, got):
        np.testing.assert_array_equal(a, c)
    # preemptive mode: optimistic per-chunk allocation admits all four at
    # once and resolves the growth pressure by preemption, token-identical
    over = Engine(params, cfg, ServeConfig(
        max_batch=4, max_len=32, kv_layout="paged", page_size=8,
        kv_pool_tokens=48, prefix_sharing=False))
    got2 = over.serve(reqs, 4)
    assert over.peak_active == 4
    for a, c in zip(want, got2):
        np.testing.assert_array_equal(a, c)


def test_paged_serve_at_max_len_boundary(engine_fixture):
    """prompt + max_new == max_len: the speculative chunk slack must NOT
    grow the block table past its ⌈max_len/page⌉ width (writes past
    max_len clamp to the garbage page instead). Regression: this used to
    crash broadcasting a 1-page-too-long table row."""
    from repro.serve import Engine, ServeConfig

    cfg, params = engine_fixture
    rng = np.random.default_rng(5)
    reqs = [rng.integers(0, cfg.vocab_size, (26,)).astype(np.int32)]
    sc = dict(max_batch=1, max_len=32, decode_chunk=4)
    want = Engine(params, cfg, ServeConfig(**sc)).serve(reqs, 6)
    got = Engine(params, cfg, ServeConfig(**sc, kv_layout="paged",
                                          page_size=8)).serve(reqs, 6)
    np.testing.assert_array_equal(want[0], got[0])


def test_paged_hybrid_stack_disables_prefix_sharing(engine_fixture):
    """Ring/recurrent layers carry state the skipped prefill steps would
    have produced, so prefix sharing must auto-disable on hybrid stacks —
    shared-prefix prompts still serve token-identically to contiguous."""
    from repro.models import get_model
    from repro.serve import Engine, ServeConfig

    cfg = _cfg(pattern=(("attn_chunked", "swiglu"), ("attn", "swiglu")),
               attn_chunk=8)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
    reqs = [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)])
            for n in (2, 3)]
    want = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32)).serve(reqs, 4)
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32,
                                          kv_layout="paged", page_size=8,
                                          prefix_sharing=True))
    assert eng._page_layout is not None  # the global-attn layers DO page
    assert not eng._can_share_prefix  # but sharing is gated off
    got = eng.serve(reqs, 4)
    for a, c in zip(want, got):
        np.testing.assert_array_equal(a, c)


def test_paged_pool_too_small_raises(engine_fixture):
    from repro.runtime.kvcache import PageError
    from repro.serve import Engine, ServeConfig

    cfg, params = engine_fixture
    eng = Engine(params, cfg, ServeConfig(
        max_batch=2, max_len=64, kv_layout="paged", page_size=8,
        kv_pool_tokens=16))
    req = np.arange(10, dtype=np.int32) % cfg.vocab_size
    with pytest.raises(PageError):
        eng.serve([req], max_new_tokens=8)


def test_paged_falls_back_without_global_attention(engine_fixture):
    """Pure ring/recurrent stacks have nothing to page: kv_layout='paged'
    must quietly serve through the contiguous layout."""
    from repro.models import get_model
    from repro.serve import Engine, ServeConfig

    cfg = _cfg(pattern=(("attn_local", "swiglu"),), attn_window=8)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    reqs = [rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)]
    eng = Engine(params, cfg, ServeConfig(max_batch=1, max_len=32,
                                          kv_layout="paged"))
    assert eng._page_layout is None
    want = Engine(params, cfg, ServeConfig(max_batch=1, max_len=32)).serve(reqs, 4)
    got = eng.serve(reqs, 4)
    np.testing.assert_array_equal(want[0], got[0])
