"""Paper-equivalence tests: Algs. 1-3 are the same function (§III).

The central mathematical claim of FLASH-D — Alg. 3 is a one-to-one exact
rewrite of baseline FlashAttention — is checked against the naive softmax
oracle with hypothesis-generated shapes/scales, including adversarial score
ranges that would overflow a max-free softmax done naively.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    flash_attention_alg1,
    flash_attention2_alg2,
    flashd_alg3,
    naive_attention,
)
from repro.core.flashd import SKIP_LO, flashd_alg3_skipstats


def _rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


@pytest.mark.parametrize("alg", [flash_attention_alg1, flash_attention2_alg2, flashd_alg3])
@pytest.mark.parametrize("n,d,dv", [(1, 4, 4), (7, 8, 16), (64, 32, 32), (129, 16, 8)])
def test_algs_equal_naive(alg, n, d, dv):
    q = _rand(0, d, scale=2.0)
    k = _rand(1, n, d)
    v = _rand(2, n, dv)
    np.testing.assert_allclose(alg(q, k, v), naive_attention(q, k, v), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 80),
    d=st.integers(1, 32),
    scale=st.floats(0.01, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_flashd_exactness_property(n, d, scale, seed):
    """Alg. 3 == softmax attention for any shape and score magnitude —
    including scales where exp(s) alone would overflow f32 (the paper's
    numerical-stability claim: no max subtraction needed)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (d,)) * scale
    k = jax.random.normal(ks[1], (n, d))
    v = jax.random.normal(ks[2], (n, 4))
    got = flashd_alg3(q, k, v)
    want = naive_attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(got)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_flashd_huge_scores_no_overflow():
    """Scores ~1e4: e^{s} overflows f32; FLASH-D must stay finite & exact."""
    q = jnp.full((8,), 40.0)
    k = jnp.concatenate([jnp.full((5, 8), 30.0), -jnp.full((5, 8), 30.0)])
    v = _rand(3, 10, 4)
    got = flashd_alg3(q, k, v)
    assert bool(jnp.all(jnp.isfinite(got)))
    # softmax concentrates on the first 5 keys equally
    np.testing.assert_allclose(got, jnp.mean(v[:5], axis=0), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 8.0))
def test_saturation_error_bounded(seed, scale):
    """§III-C: the [-6, 11] saturation rule changes each step's weight by at
    most σ(−6) ≈ 2.5e-3, so the output error stays within that order."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (16,)) * scale
    k = jax.random.normal(ks[1], (64, 16))
    v = jax.random.normal(ks[2], (64, 8))
    exact = flashd_alg3(q, k, v)
    sat = flashd_alg3(q, k, v, saturate=True)
    vspread = jnp.max(jnp.abs(v))
    assert float(jnp.max(jnp.abs(sat - exact))) < 0.05 * float(vspread) + 1e-4


def test_skipstats_counts():
    """Table-I instrumentation: counts are sane and skips correspond to
    saturation events (crafted so some steps must skip)."""
    n, d = 64, 8
    q = jnp.ones((d,)) * 4.0
    k = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(np.random.default_rng(1).normal(size=(n, 4)), jnp.float32)
    o, nlo, nhi = flashd_alg3_skipstats(q, k, v)
    assert 0 <= int(nlo) <= n - 1
    assert 0 <= int(nhi) <= n - 1
    exact = naive_attention(q, k, v)
    np.testing.assert_allclose(o, exact, atol=0.05)


def test_first_weight_is_one():
    """Alg. 3 line 7: w_1 = 1 ⇒ o_1 = v_1 regardless of scores."""
    q = jnp.asarray([100.0, -50.0])
    k = jnp.asarray([[1.0, 2.0]])
    v = jnp.asarray([[7.0, -3.0, 0.5]])
    np.testing.assert_allclose(flashd_alg3(q, k, v), v[0], rtol=1e-6)
