"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config, runs one forward + one train step on
CPU, asserts output shapes and no NaNs; plus decode-vs-forward consistency.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _smoke_batch(cfg, b=2, s=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.is_encdec:
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16
        )
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_tokens, cfg.d_model)), jnp.bfloat16
        )
        batch["tokens"] = batch["tokens"][:, : s - cfg.frontend_tokens]
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, batch["tokens"].shape), jnp.int32
    )
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_and_shapes(arch):
    cfg = configs.get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    logits, aux = api.apply(params, batch, cfg)
    b, s = batch["tokens"].shape
    s_total = logits.shape[1]
    assert logits.shape == (b, s_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    # padded vocab slots are masked off
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(jnp.max(logits[..., cfg.vocab_size:])) <= -1e29


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    tc = TrainConfig(warmup_steps=2, total_steps=10)
    state = init_train_state(jax.random.PRNGKey(1), cfg, tc)
    step = make_train_step(cfg, tc)
    batch = _smoke_batch(cfg)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state.step) == 1
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf))), "non-finite param after update"


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen2-1.5b", "llama4-scout-17b-a16e",
                                  "mamba2-2.7b", "recurrentgemma-9b", "qwen3-moe-235b-a22b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full forward's next-token logits
    (the serving-equivalence guarantee, incl. ring caches + SSM states)."""
    cfg = configs.get_smoke_config(arch)
    # capacity_factor high enough that no token drops: capacity-based MoE
    # legitimately differs between joint (prefill) and per-token (decode)
    # routing when tokens drop — the equivalence claim is for the no-drop
    # regime (drops are a training-efficiency tradeoff, not a serving one)
    cfg = dataclasses.replace(
        cfg, dtype="float32", param_dtype="float32", capacity_factor=16.0
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(2), cfg)
    b, s = 2, 16
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    logits_full, _ = api.apply(params, {"tokens": tokens}, cfg)

    cache = api.init_cache(b, s, cfg)
    got = []
    for i in range(s):
        logit, cache = api.decode_step(
            params, cache, tokens[:, i], jnp.full((b,), i, jnp.int32), cfg
        )
        got.append(logit)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        got[..., : cfg.vocab_size],
        logits_full[..., : cfg.vocab_size],
        rtol=2e-3, atol=2e-3,
    )


def test_encdec_decode_consistency():
    cfg = configs.get_smoke_config("seamless-m4t-medium")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(4), cfg)
    b, s = 2, 12
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    batch = {"tokens": tokens, "frame_embeds": frames}
    logits_full, _ = api.apply(params, batch, cfg)

    from repro.models.encdec import encode, fill_cross_cache, init_encdec_cache

    memory = encode(params, frames, cfg)
    cache = init_encdec_cache(b, s, s, cfg)
    cache = fill_cross_cache(params, memory, cache, cfg)
    got = []
    for i in range(s):
        logit, cache = api.decode_step(
            params, cache, tokens[:, i], jnp.full((b,), i, jnp.int32), cfg
        )
        got.append(logit)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        got[..., : cfg.vocab_size], logits_full[..., : cfg.vocab_size],
        rtol=2e-3, atol=2e-3,
    )


def test_flashd_vs_fa2_model_equivalence():
    """Whole-model logits identical whichever kernel family runs attention —
    the system-level statement of the paper's equivalence claim."""
    cfg = configs.get_smoke_config("deepseek-7b")
    cfg32 = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    api = get_model(cfg32)
    params = api.init(jax.random.PRNGKey(6), cfg32)
    batch = _smoke_batch(cfg32)
    outs = {}
    for impl in ("flashd", "fa2", "naive", "flashd_pallas"):
        c = dataclasses.replace(cfg32, attn_impl=impl)
        outs[impl], _ = get_model(c).apply(params, batch, c)
    for impl in ("fa2", "naive", "flashd_pallas"):
        np.testing.assert_allclose(
            outs["flashd"][..., : cfg.vocab_size],
            outs[impl][..., : cfg.vocab_size],
            rtol=1e-4, atol=1e-4,
        )


def test_param_count_analytic_close_to_actual():
    for arch in ["deepseek-7b", "qwen3-moe-235b-a22b", "mamba2-2.7b"]:
        cfg = configs.get_smoke_config(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.25, (arch, actual, analytic)
