"""Fault tolerance: checkpoint roundtrip/atomicity, restart-identical
training, straggler detection, elastic mesh planning.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_llama
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import StragglerMonitor, plan_mesh, run_resilient
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _tiny():
    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, head_dim=16, vocab_size=64, vocab_pad_multiple=64,
    )
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=2, total_steps=50)
    return cfg, tc


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(2.5)}}
    ckpt.save(str(tmp_path), 3, tree, extra={"data_step": 3})
    got, extra = ckpt.restore(str(tmp_path), tree)
    assert extra["data_step"] == 3
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(x, y)


def test_latest_skips_tmp_and_gc(tmp_path):
    tree = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    os.makedirs(str(tmp_path / "step_00000099.tmp"))  # simulated crash mid-save
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_async_manager(tmp_path):
    tree = {"x": jnp.arange(10, dtype=jnp.float32)}
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save_async(s, jax.tree.map(lambda x: x * s, tree))
    mgr.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
    got, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_allclose(got["x"], tree["x"] * 3)
    # keep=2 garbage collection
    assert not os.path.exists(str(tmp_path / "step_00000001"))


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"x": jnp.zeros((5,))})


def test_restart_identical_loss_curve(tmp_path):
    """A run killed at step 23 and restarted reproduces the uninterrupted
    run's loss curve exactly (checkpoint carries params+opt+data state)."""
    cfg, tc = _tiny()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    step_fn_jit = jax.jit(make_train_step(cfg, tc))

    def init_state():
        return init_train_state(jax.random.PRNGKey(0), cfg, tc)

    def step_fn(state, data_step):
        state, m = step_fn_jit(state, jax.tree.map(jnp.asarray, data.batch(data_step)))
        return state, {"loss": m["loss"]}

    total = 30
    # uninterrupted reference
    ref_state = init_state()
    ref_losses = []
    for i in range(total):
        ref_state, m = step_fn(ref_state, i)
        ref_losses.append(float(m["loss"]))

    failed = {"done": False}

    def fail_at(step):
        if step == 23 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    _, history = run_resilient(
        ckpt_dir=str(tmp_path), init_state_fn=init_state, step_fn=step_fn,
        total_steps=total, ckpt_every=10, fail_at=fail_at,
    )
    got = {h["step"]: h["loss"] for h in history}
    assert len(got) == total
    for i in range(total):
        np.testing.assert_allclose(got[i], ref_losses[i], rtol=1e-6, atol=1e-6)


def test_run_resilient_retryable_tuple(tmp_path):
    """Only exception types in the policy's `retryable` tuple restart the
    loop; anything else propagates immediately (default: RuntimeError,
    the historical behavior)."""
    from repro.runtime.resilience import RetryPolicy

    class Flaky(Exception):
        pass

    def make_fail_once(exc_type):
        box = {"done": False}

        def fail_at(step):
            if step == 2 and not box["done"]:
                box["done"] = True
                raise exc_type("simulated")
            return False

        return fail_at

    def init_state():
        return {"x": jnp.zeros((2,))}

    def step_fn(state, data_step):
        return state, {"loss": 0.0}

    # not retryable under the default policy → propagates
    with pytest.raises(Flaky):
        run_resilient(
            ckpt_dir=str(tmp_path / "a"), init_state_fn=init_state,
            step_fn=step_fn, total_steps=5, ckpt_every=2,
            fail_at=make_fail_once(Flaky),
        )
    # retryable under a widened policy → restarts and completes
    _, history = run_resilient(
        ckpt_dir=str(tmp_path / "b"), init_state_fn=init_state,
        step_fn=step_fn, total_steps=5, ckpt_every=2,
        fail_at=make_fail_once(Flaky),
        retry=RetryPolicy(retryable=(Flaky,)),
    )
    assert len(history) == 5


def test_straggler_end_step_without_start_is_noop():
    """`end_step` with no matching `start_step` (e.g. the serve loop bailed
    before the watchdog armed) must measure nothing instead of raising —
    the pre-PR-6 TypeError."""
    mon = StragglerMonitor()
    mon.end_step(0)  # no start_step, no elapsed: no-op
    assert mon.ewma is None
    mon.start_step()
    mon.end_step(1)
    assert mon.ewma is not None  # armed pairs still measure
    mon.end_step(2, elapsed=0.25)  # explicit elapsed bypasses the timer
    assert len(mon.deviations) == 1


def test_straggler_monitor_flags_outlier():
    events = []
    mon = StragglerMonitor(threshold=3.0, warmup=3,
                           on_straggler=lambda s, dt, mu: events.append(s))
    for s in range(20):
        mon.observe(s, 0.1 + 0.001 * (s % 3))
    mon.observe(20, 1.5)  # 15× step time: a straggling pod
    assert 20 in mon.flagged and events == [20]
    # recovery: normal steps after are not flagged
    for s in range(21, 26):
        mon.observe(s, 0.1)
    assert mon.flagged == [20]


@pytest.mark.parametrize("n,expect", [
    (512, (2, 16, 16)), (256, (16, 16)), (128, (8, 16)), (64, (4, 16)),
    (48, (3, 16)), (8, (1, 8)),
])
def test_plan_mesh(n, expect):
    plan = plan_mesh(n)
    assert plan.mesh_shape == expect
    assert int(np.prod(plan.mesh_shape)) == n


def test_latest_step_tolerates_malformed_dirs(tmp_path):
    """A stray `step_backup` (or any non-numeric step_*) dir must be
    skipped, not crash every restore with ValueError from int()."""
    tree = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 7, tree)
    os.makedirs(str(tmp_path / "step_backup"))
    os.makedirs(str(tmp_path / "step_old2"))
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert ckpt.valid_steps(str(tmp_path)) == [7]
    got, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(got["x"], tree["x"])


def test_restore_rejects_extra_leaves(tmp_path):
    """A checkpoint with leaves the target structure lacks is a structure
    mismatch (wrong config, wrong model), not data to silently drop."""
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="leaves the target structure does not"):
        ckpt.restore(str(tmp_path), {"a": jnp.zeros(2)})


def test_corrupt_shard_detected_and_fallback(tmp_path):
    """A torn/bit-flipped shard fails CRC verification: explicit restore
    raises CheckpointCorrupt, step=None falls back to the previous good
    checkpoint — corruption costs one interval, never the run."""
    tree = {"x": jnp.arange(8, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 1, jax.tree.map(lambda v: v * 1, tree))
    ckpt.save(str(tmp_path), 2, jax.tree.map(lambda v: v * 2, tree))
    shard = tmp_path / "step_00000002" / "shard_0.npz"
    raw = shard.read_bytes()
    shard.write_bytes(raw[: len(raw) // 2])  # torn write
    assert ckpt.verify_step(str(tmp_path), 1)
    assert not ckpt.verify_step(str(tmp_path), 2)
    assert ckpt.valid_steps(str(tmp_path)) == [1]
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(str(tmp_path), tree, step=2)
    got, _ = ckpt.restore(str(tmp_path), tree)  # falls back to step 1
    np.testing.assert_array_equal(got["x"], np.arange(8, dtype=np.float32))
    # verify=False opts out (forensics path): loads whatever parses
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), tree, step=2, verify=False)


def test_async_save_failure_surfaces_and_keeps_previous(tmp_path, monkeypatch):
    """A failed background save must re-raise on the next wait()/
    save_async() and must NOT garbage-collect the previous good
    checkpoint (gc runs only after a successful write)."""
    tree = {"x": jnp.arange(4, dtype=jnp.float32)}
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=1)
    mgr.save_async(1, tree)
    mgr.wait()
    assert ckpt.valid_steps(str(tmp_path)) == [1]

    real_save = ckpt.save

    def failing_save(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt, "save", failing_save)
    mgr.save_async(2, tree)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # error is consumed: next wait() is clean, good ckpt survived
    mgr.wait()
    monkeypatch.setattr(ckpt, "save", real_save)
    assert ckpt.valid_steps(str(tmp_path)) == [1]
    got, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(got["x"], tree["x"])
    # surfacing also happens at the head of the NEXT save_async
    monkeypatch.setattr(ckpt, "save", failing_save)
    mgr.save_async(3, tree)
    monkeypatch.setattr(ckpt, "save", real_save)
    with pytest.raises(OSError):
        mgr.save_async(4, tree)
    mgr.wait()


def test_run_resilient_falls_back_past_corrupt_newest(tmp_path):
    """The supervisor's restore path uses verified steps: corrupt the
    newest checkpoint mid-run and the restart resumes from the previous
    one, still completing with the right history."""
    saved = []

    def init_state():
        return {"w": jnp.float32(0.0)}

    def step_fn(state, data_step):
        return {"w": state["w"] + 1.0}, {"loss": float(state["w"])}

    box = {"done": False}

    def fail_at(step):
        if step == 7 and not box["done"]:
            box["done"] = True
            # corrupt the newest checkpoint right before the crash
            newest = ckpt.latest_step(str(tmp_path))
            shard = tmp_path / f"step_{newest:08d}" / "shard_0.npz"
            shard.write_bytes(b"garbage")
            return True
        return False

    state, history = run_resilient(
        ckpt_dir=str(tmp_path), init_state_fn=init_state, step_fn=step_fn,
        total_steps=10, ckpt_every=3, fail_at=fail_at,
    )
    assert float(state["w"]) == 10.0
    assert [h["step"] for h in history] == list(range(10))
    assert [h["loss"] for h in history] == [float(i) for i in range(10)]


def test_elastic_restore_across_scale(tmp_path):
    """A checkpoint written at one logical scale restores bit-exact at
    another (re-placement is host-side; no resharding math involved)."""
    cfg, tc = _tiny()
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    ckpt.save(str(tmp_path), 5, state, extra={"data_step": 5})
    # "new cluster": restore into a freshly-initialized template
    template = init_train_state(jax.random.PRNGKey(42), cfg, tc)
    got, extra = ckpt.restore(str(tmp_path), template)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(got.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
