"""Split/block autotuner: budget adherence, clamping, measured mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tuning
from repro.kernels.tuning import (
    DecodeSplit,
    PrefillTiling,
    bucket_pow2,
    choose_decode_split,
    choose_prefill_blocks,
    decode_vmem_bytes,
    prefill_vmem_bytes,
)


def test_prefill_defaults_to_sweet_spot():
    t = choose_prefill_blocks(4096, 4096, 128)
    assert t == PrefillTiling(512, 512)
    assert prefill_vmem_bytes(t.block_q, t.block_k, 128, 128) <= tuning.VMEM_BUDGET_BYTES


def test_prefill_shrinks_for_fat_heads():
    """Large head dims must shrink tiles until the working set fits."""
    t = choose_prefill_blocks(8192, 8192, 1024, 1024)
    assert prefill_vmem_bytes(t.block_q, t.block_k, 1024, 1024) <= tuning.VMEM_BUDGET_BYTES
    assert t.block_q < 512 or t.block_k < 512


def test_prefill_clamps_to_short_sequences():
    t = choose_prefill_blocks(33, 57, 64)
    assert t.block_q == 33 and t.block_k == 57


def test_prefill_respects_tiny_budget():
    t = choose_prefill_blocks(4096, 4096, 64, vmem_budget=256 * 1024)
    assert prefill_vmem_bytes(t.block_q, t.block_k, 64, 64) <= 256 * 1024
    assert t.block_q >= 8 and t.block_k >= 8


def test_decode_split_covers_cache():
    for s_max in (1, 7, 64, 500, 4096, 100_000):
        ds = choose_decode_split(s_max, 128, group=8)
        assert ds.n_splits >= 1
        assert ds.n_splits * ds.split >= s_max  # splits tile the padded cache
        assert decode_vmem_bytes(ds.split, 128, 128, 8) <= tuning.VMEM_BUDGET_BYTES


def test_decode_split_small_cache_single_pass():
    assert choose_decode_split(64, 16).n_splits == 1


def test_decode_split_caps_at_live_window():
    """A window-masked cache only ever attends `window` positions — splits
    longer than that waste masked work."""
    ds = choose_decode_split(65536, 128, window=1024)
    assert ds.split <= 1024


def test_decode_split_respects_budget():
    ds = choose_decode_split(65536, 256, 256, group=16,
                             vmem_budget=512 * 1024)
    assert decode_vmem_bytes(ds.split, 256, 256, 16) <= 512 * 1024


def test_measure_best_caches_and_skips_failures():
    tuning.clear_measure_cache()
    calls = []

    def build(c):
        if c == "bad":
            raise RuntimeError("unbuildable")

        def thunk():
            calls.append(c)
            return jnp.zeros(())

        return thunk

    best = tuning.measure_best(("k",), ["bad", "a", "b"], build, iters=1)
    assert best in ("a", "b")
    n_calls = len(calls)
    assert tuning.measure_best(("k",), ["bad", "a", "b"], build) == best
    assert len(calls) == n_calls  # cached: no re-measurement
    tuning.clear_measure_cache()


def test_measured_decode_split_runs():
    tuning.clear_measure_cache()
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 16)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, 2, 32, 16)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 2, 32, 16)), jnp.float32)
    cl = jnp.asarray([32, 9], jnp.int32)
    ds = tuning.measured_decode_split(q, kc, vc, cl, candidates=(1, 2),
                                      interpret=True)
    assert isinstance(ds, DecodeSplit) and ds.n_splits in (1, 2)
    tuning.clear_measure_cache()


def test_decode_attention_pads_non_divisor_splits():
    """Tuned split-K must not collapse to one split when S_max is prime —
    the jnp path zero-pads the cache like the pallas kernel does."""
    from repro.core.attention import decode_attention

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 1, 4, 16)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, 31, 2, 16)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 31, 2, 16)), jnp.float32)
    cl = jnp.asarray([31, 7], jnp.int32)
    o1 = decode_attention(q, kc, vc, cl, n_splits=1)
    for ns in (2, 4, 5):  # none divide 31
        o = decode_attention(q, kc, vc, cl, n_splits=ns)
        np.testing.assert_allclose(o, o1, rtol=1e-5, atol=1e-6)


def test_decode_attention_split_path_dv_neq_d():
    """Split-K decode must handle v head dim != q/k head dim (the reshape
    historically hard-coded d)."""
    from repro.core.attention import decode_attention

    rng = np.random.default_rng(4)
    d, dv = 16, 8
    q = jnp.asarray(rng.normal(size=(2, 1, 4, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, 64, 2, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 64, 2, dv)), jnp.float32)
    cl = jnp.asarray([64, 21], jnp.int32)
    o1 = decode_attention(q, kc, vc, cl, n_splits=1)
    o4 = decode_attention(q, kc, vc, cl, n_splits=4)
    assert o4.shape == (2, 1, 4, dv)
    np.testing.assert_allclose(o4, o1, rtol=1e-5, atol=1e-6)


def test_choose_page_size_leaves_cacheable_pages():
    """Regression: the heuristic used to return page == max_len for small
    sequences (≤ 64 tokens), which makes every page a partial page — the
    radix prefix cache can only donate FULL pages, so warm hits were
    impossible at toy scales without an explicit page_size override. Any
    max_len ≥ 16 must now yield at least two pages per max-length
    sequence."""
    for max_len in (16, 32, 64, 128, 4096):
        page = tuning.choose_page_size(max_len, 64)
        assert max_len // page >= 2, (max_len, page)
        assert max_len % page == 0


def test_choose_page_size_quantized_itemsize():
    """A 1-byte pool fits 4x the tokens per VMEM budget; the heuristic
    must not shrink pages below the f32 choice when bytes get cheaper."""
    for max_len in (256, 4096):
        p4 = tuning.choose_page_size(max_len, 64, kv_itemsize=4)
        p1 = tuning.choose_page_size(max_len, 64, kv_itemsize=1)
        assert p1 >= p4


def test_bucket_pow2_refuses_truncating_hi():
    """Regression: bucket_pow2(n, hi=h) with h < n used to silently clamp
    to h — callers then sized buffers too small for the data they held."""
    with pytest.raises(ValueError, match="truncate"):
        bucket_pow2(33, hi=32)
    # hi == n and hi > n stay valid
    assert bucket_pow2(32, hi=32) == 32
    assert bucket_pow2(17, hi=64) == 32
