"""Public attention op: GQA batching, gradients, decode, PWL variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import MaskSpec, decode_attention, flash_attention
from repro.kernels.ref import attention_ref, decode_ref


def _inputs(seed, b, sq, skv, hq, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (b, sq, hq, d)).astype(dtype),
        jax.random.normal(ks[1], (b, skv, hkv, d)).astype(dtype),
        jax.random.normal(ks[2], (b, skv, hkv, d)).astype(dtype),
    )


@pytest.mark.parametrize("impl", ["flashd", "fa2", "naive", "flashd_pallas", "fa2_pallas"])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
def test_impls_agree(impl, hq, hkv):
    q, k, v = _inputs(0, 2, 24, 24, hq, hkv, 16)
    o = flash_attention(q, k, v, mask=MaskSpec("causal"), impl=impl, block_q=8, block_k=8)
    o_ref, _ = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        mask=MaskSpec("causal"),
    )
    np.testing.assert_allclose(o, o_ref.transpose(0, 2, 1, 3), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", ["flashd", "flashd_pallas"])
def test_gradients_match_autodiff(impl):
    q, k, v = _inputs(1, 2, 16, 16, 4, 2, 8)

    def loss_impl(q, k, v):
        o = flash_attention(q, k, v, mask=MaskSpec("causal"), impl=impl,
                            block_q=8, block_k=8)
        return jnp.sum(jnp.sin(o))

    def loss_ref(q, k, v):
        o, _ = attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), mask=MaskSpec("causal"),
        )
        return jnp.sum(jnp.sin(o.transpose(0, 2, 1, 3)))

    g1 = jax.grad(loss_impl, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_grad_under_jit_and_vmapped_batch():
    q, k, v = _inputs(2, 3, 12, 12, 4, 4, 8)
    f = jax.jit(jax.grad(lambda q: jnp.sum(
        flash_attention(q, k, v, impl="flashd", block_q=4, block_k=4) ** 2
    )))
    g = f(q)
    assert g.shape == q.shape and bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("n_splits", [1, 4])
def test_decode_attention_matches_ref(n_splits):
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 3, 40, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cl = jnp.asarray([40, 13, 27], jnp.int32)
    o = decode_attention(q, kc, vc, cl, n_splits=n_splits)
    o_ref = decode_ref(
        q[:, 0], kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3), cl
    )
    np.testing.assert_allclose(o[:, 0], o_ref, rtol=2e-5, atol=2e-5)


def test_decode_equals_prefill_last_row():
    """Decoding token t against cache == causal prefill row t."""
    q, k, v = _inputs(3, 2, 9, 9, 4, 4, 8)
    o_all = flash_attention(q, k, v, mask=MaskSpec("causal"), impl="flashd",
                            block_q=4, block_k=4)
    o_last = decode_attention(
        q[:, -1:], k, v, jnp.full((2,), 9, jnp.int32)
    )
    np.testing.assert_allclose(o_last[:, 0], o_all[:, -1], rtol=2e-5, atol=2e-5)


def test_pwl_sigmoid_close_to_exact():
    from repro.core.pwl import pwl_ln, pwl_sigmoid

    x = jnp.linspace(-6.0, 11.0, 4001)
    assert float(jnp.max(jnp.abs(pwl_sigmoid(x) - jax.nn.sigmoid(x)))) < 0.05
    w = jnp.linspace(0.05, 1.0, 1001)
    assert float(jnp.max(jnp.abs(pwl_ln(w) - jnp.log(w)))) < 0.08
    # saturation defaults outside the active region
    assert float(pwl_sigmoid(jnp.float32(-6.5))) == 0.0
    assert float(pwl_sigmoid(jnp.float32(11.5))) == 1.0
