"""Fused split-K decode kernel vs the naive oracle and the unfused path.

The fused kernel carries the FLASH-D sigmoid merge in VMEM scratch across
splits (single [B, Hq, dv] output, no HBM partials); the unfused path emits
per-split partials and merges on the host graph. Both execute the same
per-split arithmetic and the same merge op sequence, so they agree to a
couple of f32 ulps — they are separately compiled XLA programs, so strict
bitwise equality is not guaranteed (FMA contraction may differ), and the
tolerance below is a 2-ulp bound at the observed output scale.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashd_decode import flashd_decode_pallas
from repro.kernels.ref import decode_ref

_ULP2 = 2.5e-7  # two f32 ulps at magnitude ~1


def _inputs(seed, b, hq, hkv, s, d, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    kc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, kc, vc


@pytest.mark.parametrize("group", [1, 4, 8])
@pytest.mark.parametrize("n_splits", [1, 4, 8])
def test_fused_gqa_groups(group, n_splits):
    hkv = 2
    q, kc, vc = _inputs(0, 3, hkv * group, hkv, 64, 16)
    cl = jnp.asarray([64, 17, 33], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, n_splits=n_splits, fused=True,
                             interpret=True)
    o_ref = decode_ref(q, kc, vc, cl)
    assert o.shape == (3, hkv * group, 16)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("w,c", [(12, 0), (7, 0), (0, 16), (0, 8)])
@pytest.mark.parametrize("n_splits", [2, 8])
def test_fused_structured_masks(w, c, n_splits):
    q, kc, vc = _inputs(1, 3, 8, 2, 64, 16)
    cl = jnp.asarray([64, 17, 33], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, n_splits=n_splits, window=w,
                             chunk=c, fused=True, interpret=True)
    o_ref = decode_ref(q, kc, vc, cl, window=w, chunk=c)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_splits", [1, 4])
def test_fused_ragged_and_edge_lengths(n_splits):
    """cache_len ∈ {0, 1, mid, full}: the 0-length row must come out ZERO
    (the dead-partial convention — no visible key ⇒ no contribution)."""
    q, kc, vc = _inputs(2, 4, 4, 4, 32, 8)
    cl = jnp.asarray([0, 1, 15, 32], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, n_splits=n_splits, fused=True,
                             interpret=True)
    o_ref = decode_ref(q, kc, vc, cl)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(o[0]), np.zeros_like(o[0]))
    # cache_len == 1 attends exactly the first key ⇒ o = v[:, 0] (G = 1 here)
    np.testing.assert_allclose(o[1], vc[1, :, 0], rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_splits", [1, 2, 4, 8])
@pytest.mark.parametrize("w,c", [(0, 0), (12, 0), (0, 16)])
def test_fused_matches_unfused(n_splits, w, c):
    """Fused (in-VMEM merge) vs unfused (HBM partials + host merge):
    identical op sequences ⇒ agreement within 2 f32 ulps."""
    q, kc, vc = _inputs(3, 3, 8, 2, 64, 16)
    cl = jnp.asarray([64, 17, 33], jnp.int32)
    kw = dict(n_splits=n_splits, window=w, chunk=c, interpret=True)
    o_f = flashd_decode_pallas(q, kc, vc, cl, fused=True, **kw)
    o_u = flashd_decode_pallas(q, kc, vc, cl, fused=False, **kw)
    scale = max(1.0, float(jnp.max(jnp.abs(o_u))))
    np.testing.assert_allclose(o_f, o_u, rtol=0, atol=_ULP2 * scale)


def test_fused_single_output_no_partials():
    """The fused call's jaxpr must contain no [.., n_splits, ..] partial
    outputs — one pallas_call, one [B, Hq, dv] result."""
    q, kc, vc = _inputs(4, 2, 4, 2, 64, 16)
    cl = jnp.asarray([64, 33], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: flashd_decode_pallas(*a, n_splits=8, fused=True, interpret=True)
    )(q, kc, vc, cl)
    [call] = [e for e in jaxpr.eqns if e.primitive.name == "pallas_call"]
    out_shapes = [tuple(v.aval.shape) for v in call.outvars]
    assert out_shapes == [(2, 2, 2, 16)]  # [B, Hkv, G, dv] — no split axis
    # and the whole function returns exactly the reshaped single output
    assert [tuple(v.aval.shape) for v in jaxpr.jaxpr.outvars] == [(2, 4, 16)]


def test_fused_bf16():
    q, kc, vc = _inputs(5, 2, 4, 4, 32, 32, jnp.bfloat16)
    cl = jnp.asarray([32, 9], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, n_splits=4, fused=True, interpret=True)
    assert o.dtype == jnp.bfloat16
    o_ref = decode_ref(q, kc, vc, cl)
    np.testing.assert_allclose(
        o.astype(jnp.float32), o_ref.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )


def test_fused_tuned_splits_default():
    """n_splits=None routes through repro.kernels.tuning and stays exact."""
    q, kc, vc = _inputs(6, 2, 4, 2, 96, 16)
    cl = jnp.asarray([96, 41], jnp.int32)
    o = flashd_decode_pallas(q, kc, vc, cl, fused=True, interpret=True)
    o_ref = decode_ref(q, kc, vc, cl)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)
