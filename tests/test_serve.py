"""Serving engine: prefill+decode equivalence, sampling, continuous batching."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_llama
from repro.models import get_model
from repro.models.transformer import prefill_lm
from repro.serve import Engine, ServeConfig, sample_token


def _cfg():
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64,
    )


def test_prefill_matches_forward():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_full, _ = api.apply(params, {"tokens": tokens}, cfg)
    cache = api.init_cache(b, 32, cfg)
    last_logits, cache = prefill_lm(params, tokens, cache, cfg)
    np.testing.assert_allclose(
        last_logits[..., : cfg.vocab_size],
        logits_full[:, -1, : cfg.vocab_size],
        rtol=2e-4, atol=2e-4,
    )


def test_generate_greedy_deterministic():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=64, temperature=0.0))
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    out1 = eng.generate(prompts, max_new_tokens=6)
    out2 = eng.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (3, 6)
    assert out1.max() < cfg.vocab_size  # never samples padded vocab slots


def test_generate_matches_stepwise_argmax():
    """Greedy generation == repeatedly running the full forward and taking
    argmax — end-to-end correctness of cache plumbing."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)

    eng = Engine(params, cfg, ServeConfig(max_len=32, temperature=0.0))
    fast = eng.generate(prompt, max_new_tokens=5)[0]

    seq = list(prompt[0])
    for _ in range(5):
        logits, _ = api.apply(
            params, {"tokens": jnp.asarray([seq], jnp.int32)}, cfg
        )
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        seq.append(nxt)
    np.testing.assert_array_equal(fast, np.asarray(seq[6:], np.int32))


def test_sampling_temperature_topk():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 10.0]])
    greedy = sample_token(logits, jax.random.PRNGKey(0), ServeConfig(temperature=0.0))
    assert int(greedy[0]) == 3
    cfgk = ServeConfig(temperature=1.0, top_k=2)
    draws = {
        int(sample_token(logits, jax.random.PRNGKey(i), cfgk)[0]) for i in range(50)
    }
    assert draws <= {2, 3}  # top-2 only


def test_continuous_batching_queue():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(5), cfg)
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32, temperature=0.0))
    rng = np.random.default_rng(6)
    reqs = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (4, 6, 5)]
    outs = eng.serve(reqs, max_new_tokens=4)
    assert len(outs) == 3 and all(o.shape == (4,) for o in outs)
    # queue result == dedicated generate for the same prompt
    solo = eng.generate(reqs[2][None], max_new_tokens=4)[0]
    np.testing.assert_array_equal(outs[2], solo)
