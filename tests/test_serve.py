"""Serving engine: prefill+decode equivalence, sampling, continuous batching."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_llama
from repro.models import get_model
from repro.models.transformer import prefill_lm
from repro.serve import Engine, ServeConfig, sample_token


def _cfg():
    return dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, head_dim=12, vocab_size=64, vocab_pad_multiple=64,
    )


def test_prefill_matches_forward():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_full, _ = api.apply(params, {"tokens": tokens}, cfg)
    cache = api.init_cache(b, 32, cfg)
    last_logits, cache = prefill_lm(params, tokens, cache, cfg)
    np.testing.assert_allclose(
        last_logits[..., : cfg.vocab_size],
        logits_full[:, -1, : cfg.vocab_size],
        rtol=2e-4, atol=2e-4,
    )


def test_generate_greedy_deterministic():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=64, temperature=0.0))
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    out1 = eng.generate(prompts, max_new_tokens=6)
    out2 = eng.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (3, 6)
    assert out1.max() < cfg.vocab_size  # never samples padded vocab slots


def test_generate_matches_stepwise_argmax():
    """Greedy generation == repeatedly running the full forward and taking
    argmax — end-to-end correctness of cache plumbing."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)

    eng = Engine(params, cfg, ServeConfig(max_len=32, temperature=0.0))
    fast = eng.generate(prompt, max_new_tokens=5)[0]

    seq = list(prompt[0])
    for _ in range(5):
        logits, _ = api.apply(
            params, {"tokens": jnp.asarray([seq], jnp.int32)}, cfg
        )
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        seq.append(nxt)
    np.testing.assert_array_equal(fast, np.asarray(seq[6:], np.int32))


def test_sampling_temperature_topk():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 10.0]])
    greedy = sample_token(logits, jax.random.PRNGKey(0), ServeConfig(temperature=0.0))
    assert int(greedy[0]) == 3
    cfgk = ServeConfig(temperature=1.0, top_k=2)
    draws = {
        int(sample_token(logits, jax.random.PRNGKey(i), cfgk)[0]) for i in range(50)
    }
    assert draws <= {2, 3}  # top-2 only


def test_continuous_batching_queue():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(5), cfg)
    eng = Engine(params, cfg, ServeConfig(max_batch=2, max_len=32, temperature=0.0))
    rng = np.random.default_rng(6)
    reqs = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (4, 6, 5)]
    outs = eng.serve(reqs, max_new_tokens=4)
    assert len(outs) == 3 and all(o.shape == (4,) for o in outs)
    # queue result == dedicated generate for the same prompt
    solo = eng.generate(reqs[2][None], max_new_tokens=4)[0]
    np.testing.assert_array_equal(outs[2], solo)


class _CountingNp:
    """Proxy for the engine module's `np` that counts device→host pulls."""

    def __init__(self, real):
        self._real = real
        self.asarray_calls = 0

    def __getattr__(self, name):
        return getattr(self._real, name)

    def asarray(self, *args, **kwargs):
        self.asarray_calls += 1
        return self._real.asarray(*args, **kwargs)


def test_generate_exactly_one_host_sync(monkeypatch):
    """The whole decode loop is one jitted scan: a generate() call performs
    exactly ONE device→host transfer (the final token fetch), independent of
    max_new_tokens."""
    import repro.serve.engine as engine_mod

    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=64, temperature=0.0))

    counting = _CountingNp(np)
    monkeypatch.setattr(engine_mod, "np", counting)
    prompts = np.asarray([[1, 2, 3, 4]], np.int32)
    for n_new in (3, 7):
        before_np, before_ctr = counting.asarray_calls, eng.host_syncs
        eng.generate(prompts, max_new_tokens=n_new)
        assert counting.asarray_calls - before_np == 1
        assert eng.host_syncs - before_ctr == 1


def test_serve_syncs_once_per_chunk(monkeypatch):
    import repro.serve.engine as engine_mod

    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        params, cfg,
        ServeConfig(max_batch=2, max_len=32, temperature=0.0, decode_chunk=4),
    )
    counting = _CountingNp(np)
    monkeypatch.setattr(engine_mod, "np", counting)
    rng = np.random.default_rng(7)
    reqs = [rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32) for _ in range(2)]
    before = counting.asarray_calls
    outs = eng.serve(reqs, max_new_tokens=8)
    # 8 tokens, chunk=4, both slots in lockstep → 2 chunk syncs; plus one
    # _to_host per prefill-assign (first sampled token) and one np.asarray
    # per request finalization (host-side bookkeeping, not a sync)
    assert all(o.shape == (8,) for o in outs)
    assert counting.asarray_calls - before <= 2 + 2 * len(reqs)


def test_generate_early_eos_masking():
    """After a sequence samples eos, every later slot emits eos (the scan
    keeps running — static trip count — but its tokens are masked)."""
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    prompts = rng.integers(0, cfg.vocab_size, (2, 5)).astype(np.int32)

    plain = Engine(params, cfg, ServeConfig(max_len=64, temperature=0.0))
    out_plain = plain.generate(prompts, max_new_tokens=6)

    eos = int(out_plain[0, 2])  # force an early EOS on row 0
    eng = Engine(params, cfg, ServeConfig(max_len=64, temperature=0.0, eos_id=eos))
    out = eng.generate(prompts, max_new_tokens=6)
    for b in range(2):
        row, row_plain = out[b], out_plain[b]
        hits = np.nonzero(row_plain == eos)[0]
        j = int(hits[0]) if hits.size else len(row_plain)
        np.testing.assert_array_equal(row[: j + 1], row_plain[: j + 1])
        assert (row[j + 1:] == eos).all()


def test_engine_with_fused_pallas_decode():
    """attn_impl=flashd_pallas routes decode through the fused split-K
    kernel; greedy generation must match the jnp decode path."""
    cfg = dataclasses.replace(_cfg(), attn_impl="flashd_pallas")
    cfg_jnp = _cfg()
    api = get_model(cfg_jnp)
    params = api.init(jax.random.PRNGKey(0), cfg_jnp)
    rng = np.random.default_rng(9)
    prompts = rng.integers(0, cfg_jnp.vocab_size, (1, 4)).astype(np.int32)
    want = Engine(params, cfg_jnp, ServeConfig(max_len=16)).generate(prompts, 3)
    got = Engine(params, cfg, ServeConfig(max_len=16)).generate(prompts, 3)
    np.testing.assert_array_equal(got, want)
