"""End-to-end train → serve lifecycle under chaos.

The closing loop of the repo (ROADMAP item 5): train a toy model with the
fused Pallas FLASH-D fwd+bwd pair under 10% train-site fault injection,
checkpoint it, and serve the trained weights — asserting that

  1. the chaos-ridden training run ends bitwise identical to a clean one
     (the resilience layer is a no-op on the math), and
  2. greedy decoding from the restored checkpoint is token-identical to
     decoding from the in-memory final state (the checkpoint carries the
     weights exactly; serving sees no difference).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import paper_llama
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import FaultInjector
from repro.serve import Engine, ServeConfig
from repro.train import (
    ResilienceConfig,
    TrainConfig,
    init_train_state,
    train_resilient,
)


def _tiny(attn_impl="flashd_pallas"):
    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, head_dim=16, vocab_size=64, vocab_pad_multiple=64,
        attn_impl=attn_impl,
    )
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=2,
                     total_steps=12)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4, seed=0))
    return cfg, tc, data


def test_train_chaos_checkpoint_serve_token_identical(tmp_path):
    cfg, tc, data = _tiny()
    total = 12
    res = ResilienceConfig(ckpt_every=3, max_restarts=500)

    # clean reference with the Pallas fwd+bwd pair
    clean_state, clean_hist, _ = train_resilient(
        ckpt_dir=str(tmp_path / "clean"), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=total, res=res)

    # 10% fault injection at every train site
    inj = FaultInjector(rate=0.10, seed=3, sites=FaultInjector.TRAIN_SITES)
    chaos_dir = str(tmp_path / "chaos")
    chaos_state, chaos_hist, ctr = train_resilient(
        ckpt_dir=chaos_dir, model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=total, res=res, injector=inj)

    assert ctr["faults"] > 0 and ctr["restarts"] > 0  # chaos actually bit
    assert [h["loss"] for h in clean_hist] == [h["loss"] for h in chaos_hist]
    for a, b in zip(jax.tree.leaves(clean_state.params),
                    jax.tree.leaves(chaos_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the training actually learned something
    assert chaos_hist[-1]["loss"] < chaos_hist[0]["loss"]

    # restore the chaos run's final checkpoint into a DIFFERENTLY-seeded
    # template (proves the weights come from disk, not the template)
    template = init_train_state(jax.random.PRNGKey(99), cfg, tc)
    restored, extra = ckpt.restore(chaos_dir, template)
    assert int(extra["data_step"]) == total

    # serve both; greedy decode must be token-identical. Serving runs the
    # jnp FLASH-D path (`flashd`) — same math as the Pallas pair it was
    # trained with, and interpret-mode decode would be needlessly slow.
    serve_cfg = dataclasses.replace(cfg, attn_impl="flashd")
    prompts = np.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)), np.int32)
    sc = ServeConfig(max_batch=2, max_len=64, temperature=0.0, seed=0)
    out_restored = Engine(restored.params, serve_cfg, sc).generate(prompts, 8)
    out_memory = Engine(chaos_state.params, serve_cfg, sc).generate(prompts, 8)
    np.testing.assert_array_equal(out_restored, out_memory)
    assert out_restored.shape == (2, 8)


def test_trained_weights_change_served_tokens(tmp_path):
    """Sanity companion: the lifecycle test would pass vacuously if serve
    ignored the restored weights — check trained ≠ fresh-init decoding on
    at least one position (tiny vocab, so require any mismatch)."""
    cfg, tc, data = _tiny(attn_impl="flashd")
    res = ResilienceConfig(ckpt_every=4)
    state, _, _ = train_resilient(
        ckpt_dir=str(tmp_path), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=8, res=res)
    fresh = init_train_state(jax.random.PRNGKey(99), cfg, tc)
    prompts = np.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8)), np.int32)
    sc = ServeConfig(max_batch=2, max_len=64, temperature=0.0, seed=0)
    out_trained = Engine(state.params, cfg, sc).generate(prompts, 8)
    out_fresh = Engine(fresh.params, cfg, sc).generate(prompts, 8)
    assert (out_trained != out_fresh).any()
