"""Training-loop resilience: bitwise-identical resume under fault injection
at every train site, on-device numerics guard (non-finite skip + dynamic
loss scaling), and loss-spike divergence rollback.

The bitwise contract: `SyntheticLM.batch(step)` is a pure function of
(seed, step) and ALL mutable training state (params, opt, EF residual,
step, loss scale, counters) lives in the checkpoint, so a run that crashes
and restores replays the exact same float sequence as one that never did.
These tests assert `==`, not allclose.
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import paper_llama
from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.runtime.resilience import DivergenceRollback, FaultInjector, InjectedFault
from repro.train import (
    ResilienceConfig,
    TrainConfig,
    init_train_state,
    make_train_step,
    train_resilient,
)


def _tiny(**tc_kw):
    cfg = dataclasses.replace(
        paper_llama.CONFIG, n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, head_dim=16, vocab_size=64, vocab_pad_multiple=64,
    )
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=2,
                     total_steps=50, **tc_kw)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4, seed=0))
    return cfg, tc, data


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fault injector: train sites
# ---------------------------------------------------------------------------

def test_injector_train_sites_registered():
    assert set(FaultInjector.TRAIN_SITES) == {
        "data_batch", "grad_step", "optimizer_update", "ckpt_save", "collective",
    }
    assert set(FaultInjector.TRAIN_SITES) <= set(FaultInjector.SITES)
    inj = FaultInjector(schedule=[("grad_step", 0)])
    with pytest.raises(InjectedFault) as ei:
        inj.check("grad_step")
    assert ei.value.site == "grad_step"
    inj.check("grad_step")  # occurrence 1 not scheduled
    with pytest.raises(ValueError):
        FaultInjector(schedule=[("warp_core", 0)])


def test_injector_rate_restricted_to_train_sites():
    inj = FaultInjector(rate=1.0, sites=FaultInjector.TRAIN_SITES, seed=0)
    inj.check("page_alloc")  # serve site not selected: never fires
    with pytest.raises(InjectedFault):
        inj.check("data_batch")
    assert inj.fired["page_alloc"] == 0 and inj.fired["data_batch"] == 1


# ---------------------------------------------------------------------------
# bitwise resume identity at every train site class
# ---------------------------------------------------------------------------

def test_bitwise_resume_under_fault_at_every_site(tmp_path):
    """One scheduled fault at EACH train site; the loss curve and final
    params must be bitwise identical to the uninterrupted run."""
    cfg, tc, data = _tiny()
    res = ResilienceConfig(ckpt_every=5)
    total = 20

    clean_state, clean_hist, clean_ctr = train_resilient(
        ckpt_dir=str(tmp_path / "clean"), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=total, res=res)
    assert clean_ctr["restarts"] == 0

    inj = FaultInjector(schedule=[
        ("data_batch", 7), ("grad_step", 9), ("optimizer_update", 11),
        ("collective", 13), ("ckpt_save", 2),
    ])
    faulted_state, faulted_hist, ctr = train_resilient(
        ckpt_dir=str(tmp_path / "faulted"), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=total, res=res, injector=inj)

    assert ctr["restarts"] == 5 and ctr["faults"] == 5
    assert [h["loss"] for h in clean_hist] == [h["loss"] for h in faulted_hist]
    assert [h["step"] for h in faulted_hist] == list(range(total))
    _params_equal(clean_state, faulted_state)


def test_keep_checkpoints_gc(tmp_path):
    from repro.runtime import checkpoint as ckpt

    cfg, tc, data = _tiny()
    res = ResilienceConfig(ckpt_every=4, keep_checkpoints=2)
    train_resilient(ckpt_dir=str(tmp_path), model_cfg=cfg, train_cfg=tc,
                    data=data, total_steps=16, res=res)
    assert ckpt.valid_steps(str(tmp_path)) == [12, 16]


# ---------------------------------------------------------------------------
# numerics guard: skip-update + dynamic loss scale
# ---------------------------------------------------------------------------

def test_loss_scale_backoff_recovers_from_overflow():
    """An absurd initial scale overflows f32 grads: the guard must skip
    those updates (params untouched), halve the scale until finite, then
    train normally."""
    cfg, tc, data = _tiny()
    tc = dataclasses.replace(tc, loss_scale_init=2.0 ** 127,
                             loss_scale_growth_interval=4)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    finites, scales = [], []
    for i in range(16):
        prev = jax.tree.map(np.asarray, state.params)
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        state, m = step(state, batch)
        finites.append(float(m["finite"]))
        scales.append(float(m["loss_scale"]))
        if finites[-1] == 0.0:  # skipped step: params bitwise untouched
            for a, b in zip(jax.tree.leaves(prev), jax.tree.leaves(state.params)):
                np.testing.assert_array_equal(a, np.asarray(b))
    assert finites[0] == 0.0 and int(state.skipped) >= 1
    assert scales[-1] < scales[0] and finites[-1] == 1.0
    assert np.isfinite(float(m["loss"]))
    # scale settled: power-of-two all the way down
    assert all(float(s) == 2.0 ** round(np.log2(s)) for s in scales)


def test_guard_identity_with_static_unit_scale():
    """numerics_guard=True with the default static scale 1.0 is bitwise
    identical to numerics_guard=False on finite steps — the guard costs
    nothing when nothing goes wrong."""
    cfg, tc, data = _tiny()
    tc_off = dataclasses.replace(tc, numerics_guard=False)
    s_on = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    s_off = init_train_state(jax.random.PRNGKey(0), cfg, tc_off)
    f_on = jax.jit(make_train_step(cfg, tc))
    f_off = jax.jit(make_train_step(cfg, tc_off))
    for i in range(6):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        s_on, m_on = f_on(s_on, batch)
        s_off, m_off = f_off(s_off, batch)
        assert float(m_on["loss"]) == float(m_off["loss"])
    _params_equal(s_on, s_off)
    assert int(s_on.skipped) == 0


def test_guard_scales_loss_before_grad():
    """The reported loss is unscaled regardless of the carried scale, and
    a large-but-finite scale produces bitwise-identical updates (power-of-
    two scale/unscale round-trips exactly through f32 grads)."""
    cfg, tc, data = _tiny()
    tc_scaled = dataclasses.replace(tc, loss_scale_init=2.0 ** 10)
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, tc)
    s2 = init_train_state(jax.random.PRNGKey(0), cfg, tc_scaled)
    _, m1 = jax.jit(make_train_step(cfg, tc))(s1, batch)
    _, m2 = jax.jit(make_train_step(cfg, tc_scaled))(s2, batch)
    assert float(m2["loss_scale"]) == 2.0 ** 10
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# loss-spike divergence rollback
# ---------------------------------------------------------------------------

def test_spike_rollback_restores_clean_curve(tmp_path):
    """Silent state corruption (params ×100 injected mid-run) spikes the
    loss; the detector rolls back to the last good checkpoint and the
    final curve is bitwise identical to the clean run."""
    cfg, tc, data = _tiny()
    res = ResilienceConfig(ckpt_every=5, spike_threshold=2.0,
                           spike_window=8, spike_warmup=4)
    total = 20

    clean_state, clean_hist, _ = train_resilient(
        ckpt_dir=str(tmp_path / "clean"), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=total, res=res)

    fired = []

    def corrupt_once(step, state):
        if step == 12 and not fired:
            fired.append(step)
            return state._replace(
                params=jax.tree.map(lambda p: p * 100.0, state.params))
        return None

    got_state, got_hist, ctr = train_resilient(
        ckpt_dir=str(tmp_path / "corrupted"), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=total, res=res, chaos_hook=corrupt_once)

    assert fired == [12]
    assert ctr["rollbacks"] >= 1 and ctr["restarts"] >= 1
    assert [h["loss"] for h in clean_hist] == [h["loss"] for h in got_hist]
    _params_equal(clean_state, got_state)


def test_spike_accepted_after_rollback_cap(tmp_path):
    """A spike that persists across clean replays is a genuine shift, not
    corruption: after `max_rollbacks_per_step` the loop accepts it and
    completes instead of looping forever."""
    cfg, tc, data = _tiny()
    res = ResilienceConfig(ckpt_every=5, spike_threshold=2.0,
                           spike_window=8, spike_warmup=4,
                           max_rollbacks_per_step=2)

    def always_corrupt(step, state):
        if step == 12:  # fires on every replay too — a persistent shift
            return state._replace(
                params=jax.tree.map(lambda p: p * 100.0, state.params))
        return None

    _, hist, ctr = train_resilient(
        ckpt_dir=str(tmp_path), model_cfg=cfg, train_cfg=tc,
        data=data, total_steps=20, res=res, chaos_hook=always_corrupt)
    # every post-shift step gets at most the per-step cap before acceptance;
    # the decisive property is termination at full length (no infinite loop)
    assert ctr["rollbacks"] >= 2
    assert ctr["rollbacks"] <= 2 * 20
    assert len(hist) == 20


def test_divergence_rollback_carries_context():
    e = DivergenceRollback(7, 120.0, 6.0)
    assert e.step == 7 and e.loss == 120.0 and e.reference == 6.0
    assert "step 7" in str(e)


# ---------------------------------------------------------------------------
# property: random fault schedules never change the curve
# ---------------------------------------------------------------------------

_PROP_REF = {}


def _prop_reference():
    if "ref" not in _PROP_REF:
        cfg, tc, data = _tiny()
        with tempfile.TemporaryDirectory() as d:
            state, hist, _ = train_resilient(
                ckpt_dir=d, model_cfg=cfg, train_cfg=tc, data=data,
                total_steps=10, res=ResilienceConfig(ckpt_every=2))
        _PROP_REF["ref"] = (
            [h["loss"] for h in hist],
            jax.tree.map(np.asarray, state.params),
        )
    return _PROP_REF["ref"]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       rate=st.floats(min_value=0.0, max_value=0.05))
def test_random_fault_schedule_preserves_curve(seed, rate):
    ref_losses, ref_params = _prop_reference()
    cfg, tc, data = _tiny()
    inj = FaultInjector(rate=rate, seed=seed, sites=FaultInjector.TRAIN_SITES)
    with tempfile.TemporaryDirectory() as d:
        state, hist, ctr = train_resilient(
            ckpt_dir=d, model_cfg=cfg, train_cfg=tc, data=data,
            total_steps=10, res=ResilienceConfig(ckpt_every=2, max_restarts=500),
            injector=inj)
    assert [h["loss"] for h in hist] == ref_losses
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(a, np.asarray(b))
