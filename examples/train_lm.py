"""End-to-end driver: train a llama2.c-scale LM with FLASH-D attention.

This is deliverable (b)'s end-to-end example: a ~15M-param model (the
paper's own llama2.c validation vehicle — use --full for the 110M config)
for a few hundred steps on the synthetic grammar, with checkpointing,
restart-on-failure, and a final FLASH-D == FA2 sanity comparison.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_llama
from repro.data import DataConfig, SyntheticLM
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import run_resilient
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true", help="110M-param config")
    ap.add_argument("--ckpt-dir", default="/tmp/flashd_train_lm")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="simulate a node failure at this step (restart demo)")
    args = ap.parse_args()

    cfg = paper_llama.PAPER_110M if args.full else paper_llama.CONFIG
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=20,
                     total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                  global_batch=8))
    jit_step = jax.jit(make_train_step(cfg, tc))

    def init_state():
        return init_train_state(jax.random.PRNGKey(0), cfg, tc)

    def step_fn(state, i):
        state, m = jit_step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        if i % 25 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f}", flush=True)
        return state, {"loss": m["loss"]}

    failed = {"done": False}

    def fail_at(step):
        if step == args.fail_at and not failed["done"]:
            failed["done"] = True
            print(f"*** simulated node failure at step {step}; restarting from checkpoint")
            return True
        return False

    state, history = run_resilient(
        ckpt_dir=args.ckpt_dir, init_state_fn=init_state, step_fn=step_fn,
        total_steps=args.steps, ckpt_every=50,
        fail_at=fail_at if args.fail_at >= 0 else None,
    )
    losses = [h["loss"] for h in history]
    print(f"loss: {losses[0]:.3f} → {np.mean(losses[-10:]):.3f} over {len(losses)} steps")

    # the paper verified bit-matching llama2.c outputs; our equivalent check:
    api = get_model(cfg)
    batch = jax.tree.map(jnp.asarray, data.batch(10_000))
    outs = {}
    for impl in ("flashd", "fa2"):
        c = dataclasses.replace(cfg, attn_impl=impl)
        outs[impl], _ = get_model(c).apply(state.params, batch, c)
    diff = float(jnp.max(jnp.abs(outs["flashd"] - outs["fa2"])))
    print(f"trained-model logits, FLASH-D vs FA2 max|Δ| = {diff:.2e} (paper: identical replies)")


if __name__ == "__main__":
    main()
