"""Batched serving example: continuous batching over a request queue.

Trains nothing — initializes a small model, runs the slot-based engine:
prefill per request, shared decode steps, queue refill on completion.
Then the paged page-pool engine, then the MIXED varlen step
(DESIGN.md §3.5): chunked prefill interleaved with decode in one packed
dispatch — watch a long prompt stop blocking the short requests'
time-to-first-token. Also demonstrates the FLASH-D split-K decode merge
on a longer cache.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.attention import decode_attention
from repro.models import get_model
from repro.serve import Engine, ServeConfig

cfg = configs.get_smoke_config("qwen2-1.5b")  # GQA + QKV-bias smoke config
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)

eng = Engine(params, cfg, ServeConfig(max_batch=4, max_len=96, temperature=0.8,
                                      top_k=20, seed=7))
rng = np.random.default_rng(0)
requests = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in (8, 12, 6, 10, 9, 7)]
t0 = time.time()
outs = eng.serve(requests, max_new_tokens=12)
dt = time.time() - t0
for i, o in enumerate(outs):
    print(f"req[{i}] ({len(requests[i])} prompt toks) → {o.tolist()}")
tok = sum(map(len, outs))
print(f"{tok} tokens, {tok/dt:.1f} tok/s on {eng.sc.max_batch} slots")

# paged KV cache: same queue, same tokens, but KV lives in a page pool and
# admission is by free pages — a quarter of the contiguous memory commit
# still serves every request (greedy engines would be token-identical;
# sampled engines here just demonstrate the density win)
paged = Engine(params, cfg, ServeConfig(
    max_batch=8, max_len=96, temperature=0.8, top_k=20, seed=7,
    kv_layout="paged", kv_pool_tokens=96, page_size=16))
outs_p = paged.serve(requests, max_new_tokens=12)
print(f"paged pool (96 tokens vs {4 * 96} contiguous): "
      f"{sum(map(len, outs_p))} tokens, peak {paged.peak_active} concurrent")

# mixed varlen step (DESIGN.md §3.5): one LONG prompt in a queue of short
# ones. The sequential engines run its whole prefill as one blocking
# dispatch; the mixed engine drips it in prefill_chunk-token pieces packed
# together with every decoding slot's next token — same greedy tokens,
# much lower time-to-first-token for everything behind the long prompt.
long_reqs = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
             for n in (6, 128, 5, 7)]  # short, LONG, short, short
seq_cfg = ServeConfig(max_batch=2, max_len=160, temperature=0.0)
mix_cfg = ServeConfig(max_batch=2, max_len=160, temperature=0.0,
                      step_mode="mixed", prefill_chunk=32, token_budget=34)
eng_seq = Engine(params, cfg, seq_cfg)
outs_seq = eng_seq.serve(long_reqs, max_new_tokens=8)
eng_mix = Engine(params, cfg, mix_cfg)
outs_mix = eng_mix.serve(long_reqs, max_new_tokens=8)
assert all(np.array_equal(a, b) for a, b in zip(outs_seq, outs_mix))
print("mixed varlen step: token-identical to sequential; TTFT per request")
for rid in sorted(eng_seq.ttft):
    print(f"  req[{rid}] ({len(long_reqs[rid])} prompt toks): "
          f"sequential {eng_seq.ttft[rid]*1e3:7.1f} ms → "
          f"mixed {eng_mix.ttft[rid]*1e3:7.1f} ms")

# radix prefix cache + preemption (DESIGN.md §3.6): the paged engine's
# page pool persists across serve() calls, retired sequences donate their
# pages to a content-addressed radix tree, and a later prompt replaying
# the same system prompt (or a whole prior conversation) aliases the
# cached pages and prefills only the tail — same greedy tokens, a
# fraction of the time-to-first-token.
system = rng.integers(0, cfg.vocab_size, (64,)).astype(np.int32)
chat = Engine(params, cfg, ServeConfig(max_batch=2, max_len=112,
                                       temperature=0.0, kv_layout="paged",
                                       page_size=8))
turn1 = np.concatenate([system, rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)])
ans1 = chat.serve([turn1], max_new_tokens=8)[0]
cold_ttft = chat.ttft[0]
turn2 = np.concatenate([turn1, ans1,
                        rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)])
chat.serve([turn2], max_new_tokens=8)
st = chat.stats()
print(f"prefix cache: turn-1 TTFT {cold_ttft*1e3:.1f} ms → turn-2 "
      f"{chat.ttft[0]*1e3:.1f} ms (hit {st['hit_tokens']} cached tokens, "
      f"{st['cached_pages']} pages retained)")

# split-K decode: one query over a long cache, partials merged by sigmoid
b, s, hq, hkv, d = 2, 512, 8, 2, 64
ks = jax.random.split(jax.random.PRNGKey(1), 3)
q = jax.random.normal(ks[0], (b, 1, hq, d))
kc = jax.random.normal(ks[1], (b, s, hkv, d))
vc = jax.random.normal(ks[2], (b, s, hkv, d))
o1 = decode_attention(q, kc, vc, jnp.asarray([512, 300]), n_splits=1)
o8 = decode_attention(q, kc, vc, jnp.asarray([512, 300]), n_splits=8)
print("split-K (8 partials, FLASH-D merge) vs single pass max|Δ|:",
      float(jnp.max(jnp.abs(o1 - o8))))
