"""Quickstart: FLASH-D in five minutes.

1. The paper's equivalence claim, numerically (Alg. 3 == softmax attention).
2. The tiled TPU formulation + tile-skip.
3. Drop-in use inside a transformer and one training step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import flashd_alg3, naive_attention, flash_attention, MaskSpec
from repro.core.blockwise import blockwise_flashd

# ---- 1. the paper's claim: exact equivalence, no max subtraction --------
key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (64,)) * 20.0  # scores big enough to overflow e^s
k = jax.random.normal(kk, (128, 64))
v = jax.random.normal(kv, (128, 32))
o_flashd = flashd_alg3(q, k, v)          # carries (s_prev, ln w, o) — no max, no ℓ
o_ref = naive_attention(q, k, v)
print("Alg.3 vs softmax max|Δ|:", float(jnp.max(jnp.abs(o_flashd - o_ref))))

# ---- 2. the tiled form (what the Pallas TPU kernel implements) ----------
Q = jax.random.normal(kq, (256, 64))
o_tiled, lse = blockwise_flashd(Q, k, v, mask=MaskSpec("causal"), block_q=64, block_k=32)
o_skip, _, rate = blockwise_flashd(
    Q, k, v, mask=MaskSpec("causal"), block_q=64, block_k=32,
    skip=True, return_skiprate=True,
)
print("tiled vs skip-mode max|Δ|:", float(jnp.max(jnp.abs(o_tiled - o_skip))),
      f"| tiles skipped: {100*float(rate):.1f}%")

# ---- 3. inside a model: one forward + one train step --------------------
from repro import configs
from repro.models import get_model
from repro.train.train_step import TrainConfig, init_train_state, make_train_step
from repro.data import DataConfig, SyntheticLM

cfg = configs.get_smoke_config("deepseek-7b")  # reduced config, FLASH-D attention
api = get_model(cfg)
tc = TrainConfig()
state = init_train_state(jax.random.PRNGKey(1), cfg, tc)
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
step = jax.jit(make_train_step(cfg, tc))
state, metrics = step(state, jax.tree.map(jnp.asarray, data.batch(0)))
print("one train step through FLASH-D attention — loss:", float(metrics["loss"]))
