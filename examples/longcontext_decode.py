"""Long-context decode example (the long_500k shape, scaled to CPU).

Demonstrates what the long_500k dry-run cells exercise: O(1)-state decode
for the sub-quadratic archs — mamba2 (SSD recurrence) and recurrentgemma
(RG-LRU + local-attention ring buffer) — on a 4k-token synthetic context,
plus the ring-buffer equivalence check for windowed attention.

Run:  PYTHONPATH=src python examples/longcontext_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import get_model

for arch in ("mamba2-2.7b", "recurrentgemma-9b"):
    cfg = configs.get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, ctx_len = 2, 512
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, cfg.vocab_size, (b, ctx_len)).astype(np.int32)

    # window-sized cache regardless of context length — the property that
    # makes 524k-token serving feasible for these archs
    cache = api.init_cache(b, ctx_len, cfg)
    cache_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    step = jax.jit(lambda p, c, t, pos: api.decode_step(p, c, t, pos, cfg))

    t0 = time.time()
    logits = None
    for i in range(ctx_len):
        logits, cache = step(params, cache, jnp.asarray(tokens[:, i]),
                             jnp.full((b,), i, jnp.int32))
    dt = time.time() - t0
    print(f"{arch}: {ctx_len} decode steps, cache {cache_bytes/2**20:.1f} MiB "
          f"(constant in context length), {ctx_len*b/dt:.0f} tok/s, "
          f"finite={bool(jnp.all(jnp.isfinite(logits)))}")
