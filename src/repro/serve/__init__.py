from repro.serve.engine import Engine, ServeConfig, sample_token
from repro.serve.scheduler import Request, Scheduler, Segment, StepPlan

__all__ = [
    "Engine", "ServeConfig", "sample_token",
    "Request", "Scheduler", "Segment", "StepPlan",
]
