from repro.runtime.resilience import (
    EngineCrash,
    FaultInjector,
    InjectedFault,
    RetryPolicy,
)
from repro.serve.engine import Engine, ServeConfig, sample_token
from repro.serve.speculative import DraftModel, OracleDraft, SpecState
from repro.serve.scheduler import (
    DONE,
    EXPIRED,
    FAILED,
    QUEUED,
    RUNNING,
    TERMINAL,
    Request,
    Scheduler,
    Segment,
    StepPlan,
)

__all__ = [
    "Engine", "ServeConfig", "sample_token",
    "DraftModel", "OracleDraft", "SpecState",
    "Request", "Scheduler", "Segment", "StepPlan",
    "QUEUED", "RUNNING", "DONE", "FAILED", "EXPIRED", "TERMINAL",
    "FaultInjector", "InjectedFault", "EngineCrash", "RetryPolicy",
]
