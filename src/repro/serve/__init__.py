from repro.serve.engine import Engine, ServeConfig, sample_token
__all__ = ["Engine", "ServeConfig", "sample_token"]
