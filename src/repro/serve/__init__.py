from repro.serve.engine import Engine, ServeConfig, sample_token
from repro.serve.scheduler import Scheduler, Segment, StepPlan

__all__ = ["Engine", "ServeConfig", "sample_token", "Scheduler", "Segment", "StepPlan"]
