"""Batched serving engine: prefill → decode with per-sequence state.

A deliberately small but real continuous-batching engine: requests join a
slot array; finished slots are refilled from the queue. Sampling: greedy /
temperature / top-k. Two KV memory models (ServeConfig.kv_layout):

  contiguous (default) — each slot owns a fixed max_len-wide cache region;
    memory commits max_batch × max_len tokens up front.
  paged (DESIGN.md §3.4) — KV lives in a global page pool with
    per-sequence block tables (runtime/kvcache.py); admission is by FREE
    PAGES, prompts sharing a page-aligned prefix with a live sequence
    reuse its pages (full pages by reference, the boundary page as a CoW
    copy) and prefill only the tail, and decode runs the block-table
    scalar-prefetch kernel (kernels/flashd_decode) under *_pallas impls.
    Short-request workloads pack the same memory budget several-fold
    denser (BENCH_paged.json).

The decode hot loop is fully on-device (DESIGN.md §3.3):

  * `generate` runs prefill + the entire token loop as ONE jitted
    `lax.scan` — sampling, cache updates, position advance and early-EOS
    masking all happen inside the scan, so a whole generation costs one
    dispatch and exactly ONE device→host sync (the final token fetch).
    The engine counts its host syncs in `self.host_syncs`; tests pin the
    one-sync contract.
  * `serve` (continuous batching) decodes in jitted multi-token chunks
    (`ServeConfig.decode_chunk` steps per dispatch): one host sync per
    chunk instead of per token, with completions / slot refills resolved
    between chunks. Tokens a slot produced after its EOS inside a chunk
    are discarded on the host; the speculative steps are harmless — the
    refill prefill overwrites the slot's cache region (contiguous), or
    the dead slot's block-table row is pointed at the garbage page
    before its pages are reused (paged).

The caches come from the model API (`init_cache`) — attention layers hold
KV rings, SSM/RG-LRU layers hold recurrent state — so the same engine
serves every assigned architecture. When `cfg.attn_impl` is a `*_pallas`
impl, decode attention inside the scan runs the fused split-K kernel
(`repro.kernels.flashd_decode`) with tuned splits.

Sharded serving: pass a `repro.distributed.sharding.ShardingCtx` and the
engine activates it (plus the ambient mesh) around every trace/dispatch,
so the model's logical sharding constraints apply inside the jitted loops.
When the rules engine seq-shards a KV cache (long-context, B too small to
batch-shard), decode attention routes through the cross-device FLASH-D
merge (`repro.distributed.context.cp_decode`) instead of gathering the
cache (DESIGN.md §4.1).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.models.transformer import prefill_lm

__all__ = ["ServeConfig", "Engine", "sample_token"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    eos_id: int = -1  # <0: run to max_new_tokens
    seed: int = 0
    decode_chunk: int = 8  # tokens per device dispatch in `serve`
    # ---- paged KV cache (DESIGN.md §3.4) ----
    kv_layout: str = "contiguous"  # "paged": page-pool KV in `serve`
    page_size: int = 0  # 0 → repro.kernels.tuning heuristic
    kv_pool_tokens: int = 0  # pool size in tokens; 0 → max_batch·max_len
    prefix_sharing: bool = True  # share common prompt-prefix pages (CoW)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_pages(pages, srcs, dsts):
    """pages[:, d] ← pages[:, s] for every owed CoW copy, in one update.
    The pool is donated so backends that support donation do it in place
    (O(pages copied), not O(pool))."""
    return pages.at[:, dsts].set(pages[:, srcs])


def _map_paged(cache, *rest, pool=None, tbl=None, batch=None):
    """Tree-map over a (possibly paged) cache with per-leaf-kind functions.

    Leaf kinds by dict key: `k_pages`/`v_pages` are POOL leaves (global
    page arrays, no batch axis — [n_blocks, P, page, Hkv, hd]); everything
    else — including the block table `tbl` — is a PER-BATCH leaf (batch on
    axis 1 after block stacking). `tbl=` overrides the per-batch handler
    for table leaves (engine table mirroring); a missing handler leaves the
    leaf unchanged. Extra cache trees in `rest` are zipped leaf-wise."""
    from jax import tree_util as jtu

    def leaf_name(path):
        for e in reversed(path):
            if isinstance(e, jtu.DictKey):
                return e.key
        return None

    def apply(path, x, *xs):
        name = leaf_name(path)
        if name in ("k_pages", "v_pages"):
            fn = pool
        elif name == "tbl":
            fn = tbl if tbl is not None else batch
        else:
            fn = batch
        return x if fn is None else fn(x, *xs)

    return jtu.tree_map_with_path(apply, cache, *rest)


def sample_token(logits: jax.Array, key, cfg: ServeConfig) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Engine:
    def __init__(self, params, model_cfg: ModelConfig, serve_cfg: ServeConfig,
                 *, sharding_ctx=None):
        self.params = params
        self.mc = model_cfg
        self.sc = serve_cfg
        self.ctx = sharding_ctx  # Optional[repro.distributed.sharding.ShardingCtx]
        self.api = get_model(model_cfg)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, c, t, pos, model_cfg)
        )
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self.host_syncs = 0  # device→host transfers issued by this engine
        self.peak_active = 0  # max concurrent sequences observed by `serve`
        self._gen = jax.jit(self._gen_fn, static_argnums=(4,))
        self._chunk = jax.jit(self._chunk_fn, static_argnums=(5,))
        self._page_layout = None
        if serve_cfg.kv_layout == "paged":
            from repro.kernels.tuning import choose_page_layout  # lazy
            from repro.models.transformer import paged_mixers

            if getattr(model_cfg, "is_encdec", False) or not paged_mixers(model_cfg):
                # no global-attention layer to page (pure SSM/ring stacks,
                # enc-dec) — serve falls back to the contiguous layout
                pass
            else:
                self._page_layout = choose_page_layout(
                    serve_cfg.max_len,
                    model_cfg.head_dim_,
                    model_cfg.head_dim_,
                    group=model_cfg.n_heads // model_cfg.n_kv_heads,
                    pool_tokens=serve_cfg.kv_pool_tokens
                    or serve_cfg.max_batch * serve_cfg.max_len,
                    page_size=serve_cfg.page_size or None,
                )
        # prefix sharing skips the shared positions' prefill steps, which is
        # only sound when EVERY mixer reads the paged cache: ring
        # (local/chunked) and SSM/RG-LRU layers carry state those steps
        # would have produced (see prefill_lm's start_pos contract)
        self._can_share_prefix = (
            self._page_layout is not None
            and serve_cfg.prefix_sharing
            and all(
                m in ("attn", "attn_nope", "attn_bidir")
                for m, _ in (*model_cfg.pattern, *model_cfg.remainder)
            )
        )

    def _scope(self):
        """Sharding scope for traces/dispatches: activates the ctx and the
        ambient mesh so logical constraints (and context-parallel routing)
        resolve inside the jitted loops. No-op without a sharding_ctx."""
        if self.ctx is None:
            return contextlib.nullcontext()
        from repro.distributed import sharding as shd  # lazy: optional dep

        stack = contextlib.ExitStack()
        stack.enter_context(shd.activate(self.ctx))
        mctx = shd.mesh_ctx(self.ctx.mesh)
        if hasattr(mctx, "__enter__"):
            stack.enter_context(mctx)
        return stack

    def _to_host(self, x) -> np.ndarray:
        """The engine's ONLY device→host sync point (counted for tests)."""
        self.host_syncs += 1
        return np.asarray(x)

    # ---- jitted device loops ----
    def _gen_fn(self, params, prompts, cache, key, max_new_tokens: int):
        """Prefill + full decode loop as one device program → tokens [B, T].

        Early-EOS masking: once a sequence has emitted eos_id, subsequent
        positions emit eos_id (the decode steps still run — a lax.scan has
        static trip count — but their tokens are masked in the output)."""
        b, s = prompts.shape
        logits, cache = prefill_lm(params, prompts, cache, self.mc)
        pos0 = jnp.full((b,), s, jnp.int32)
        done0 = jnp.zeros((b,), bool)
        eos = self.sc.eos_id

        def body(carry, k_i):
            logits, cache, pos, done = carry
            tok = sample_token(logits, k_i, self.sc)
            if eos >= 0:
                emit = jnp.where(done, jnp.int32(eos), tok)
                done = jnp.logical_or(done, tok == eos)
            else:
                emit = tok
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            return (logits, cache, pos + 1, done), emit

        keys = jax.random.split(key, max_new_tokens)
        _, toks = jax.lax.scan(body, (logits, cache, pos0, done0), keys)
        return toks.T  # [B, T]

    def _chunk_fn(self, params, cache, tok, pos, key, n: int):
        """`n` decode+sample steps as one device program (continuous batching)."""

        def body(carry, k_i):
            cache, tok, pos = carry
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            nxt = sample_token(logits, k_i, self.sc)
            return (cache, nxt, pos + 1), nxt

        keys = jax.random.split(key, n)
        (cache, tok, pos), toks = jax.lax.scan(body, (cache, tok, pos), keys)
        return cache, tok, pos, toks  # toks [n, B]

    # ---- single-prompt-batch generation (prefill + n decode steps) ----
    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts [B, S_prompt] int32 (right-aligned, no padding support in
        this minimal path) → generated tokens [B, max_new_tokens]."""
        b, s = prompts.shape
        with self._scope():
            cache = self.api.init_cache(b, self.sc.max_len, self.mc)
            self._key, k = jax.random.split(self._key)
            toks = self._gen(
                self.params, jnp.asarray(prompts, jnp.int32), cache, k,
                int(max_new_tokens),
            )
        return self._to_host(toks)

    # ---- continuous batching over a request queue ----
    def serve(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Each request: 1-D prompt array. Returns generated arrays, in order.

        Slot-parallel: up to max_batch requests decode together; finished
        slots take the next queued request between chunks (its prefill runs
        as a batch-1 prefill — into that slot's cache region under the
        contiguous layout, or straight into its allocated pages under
        `kv_layout="paged"`, where admission is gated by the allocator's
        free-page count instead of slot width; a production engine would
        chunk prefills into the decode batch)."""
        with self._scope():
            if self._page_layout is not None:
                return self._serve_paged(requests, max_new_tokens)
            return self._serve_impl(requests, max_new_tokens)

    def _serve_impl(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        queue = list(enumerate(requests))
        active: List[dict] = []
        b = self.sc.max_batch
        cache = self.api.init_cache(b, self.sc.max_len, self.mc)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        slot_req = [-1] * b
        slot_out: List[List[int]] = [[] for _ in range(b)]
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))

        def _write_slot(c, o, slot):
            # caches are stacked [n_blocks, batch, ...]: batch is axis 1
            return c.at[:, slot].set(o[:, 0])

        def assign(slot: int):
            """Prefill the next queued request into `slot`. The prefill's
            sampled token is output token 0 (same as `generate`); requests
            that complete immediately are finalized and the next is taken."""
            nonlocal cache, tok, pos
            while queue:
                rid, prompt = queue.pop(0)
                one_cache = self.api.init_cache(1, self.sc.max_len, self.mc)
                logits, one_cache = prefill_lm(
                    self.params, jnp.asarray(prompt[None], jnp.int32), one_cache, self.mc
                )
                self._key, k = jax.random.split(self._key)
                t0 = int(self._to_host(sample_token(logits, k, self.sc))[0])
                done = max_new_tokens <= 1 or (self.sc.eos_id >= 0 and t0 == self.sc.eos_id)
                if done:
                    results[rid] = np.asarray([t0], np.int32)
                    continue
                slot_req[slot] = rid
                slot_out[slot] = [t0]
                cache = jax.tree.map(lambda c, o: _write_slot(c, o, slot), cache, one_cache)
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(len(prompt))
                return
            slot_req[slot] = -1

        for s in range(b):
            assign(s)

        self.peak_active = max(self.peak_active, sum(r >= 0 for r in slot_req))
        while any(r >= 0 for r in slot_req):
            self._key, k = jax.random.split(self._key)
            cache, tok, pos, toks = self._chunk(
                self.params, cache, tok, pos, k, chunk_n
            )
            toks_np = self._to_host(toks)  # one sync per chunk
            finished = []
            for s in range(b):
                rid = slot_req[s]
                if rid < 0:
                    continue
                for step in range(chunk_n):
                    t = int(toks_np[step, s])
                    slot_out[s].append(t)
                    done = len(slot_out[s]) >= max_new_tokens or (
                        self.sc.eos_id >= 0 and t == self.sc.eos_id
                    )
                    if done:  # later tokens in this chunk are speculative garbage
                        results[rid] = np.asarray(slot_out[s], np.int32)
                        finished.append(s)
                        break
            for s in finished:
                assign(s)  # refill overwrites the slot's cache / tok / pos
            self.peak_active = max(
                self.peak_active, sum(r >= 0 for r in slot_req)
            )
        return [r if r is not None else np.zeros((0,), np.int32) for r in results]

    # ---- paged continuous batching (DESIGN.md §3.4) ----
    def _serve_paged(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Continuous batching over a page-pool KV cache.

        Differences from the contiguous loop:

          * admission is by FREE PAGES, not slot count: a request is
            admitted when the pool can cover its worst case
            (prompt + max_new_tokens + one decode chunk of speculative
            slack, minus shared prefix pages); a blocked head-of-line
            request waits for frees, so short sequences pack the pool far
            denser than `max_batch × max_len` slots would;
          * prompts sharing a page-aligned-or-longer prefix with a live
            sequence reuse its KV pages (full pages by reference, the
            boundary page as a CoW copy) and prefill only the tail;
          * before every chunk the allocator materializes pages covering
            the chunk's writes and the engine mirrors grown block tables
            to the device; finished slots free their pages and point
            their table row at the garbage page, so lockstep speculative
            writes from dead slots stay harmless.
        """
        from repro.runtime.kvcache import PagedKVAllocator, PageError, pages_for

        lay = self._page_layout
        page = lay.page_size
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        queue = list(enumerate(requests))
        b = self.sc.max_batch
        alloc = PagedKVAllocator(lay.n_pages, page)
        cache = self.api.init_cache(
            b, self.sc.max_len, self.mc,
            layout="paged", page_size=page, n_pages=lay.n_pages,
        )
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        slot_req = [-1] * b
        slot_out: List[List[int]] = [[] for _ in range(b)]
        slot_len = [0] * b  # host mirror: positions materialized so far
        slot_prompt: List[Optional[np.ndarray]] = [None] * b
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))

        def best_prefix(prompt: np.ndarray):
            """Longest common prompt prefix with a live sequence — the
            prefix-sharing candidate. Worth taking only when it covers at
            least one full page (a shorter match saves nothing: the
            boundary CoW copy costs the same page a fresh alloc would)."""
            if not self._can_share_prefix:
                return -1, 0
            best_s, best_n = -1, 0
            for s in range(b):
                if slot_req[s] < 0 or slot_prompt[s] is None:
                    continue
                other = slot_prompt[s]
                m = min(len(prompt), len(other))
                n = int(np.argmin(np.equal(prompt[:m], other[:m]))) \
                    if not np.array_equal(prompt[:m], other[:m]) else m
                if n > best_n:
                    best_s, best_n = s, n
            best_n = min(best_n, len(prompt) - 1)  # the tail must run ≥ 1 token
            if best_n < page:
                return -1, 0
            return best_s, best_n

        def set_tbl_row(c, slot: int, table: List[int]):
            row = np.zeros((lay.pages_per_seq,), np.int32)
            row[: len(table)] = table
            row_j = jnp.asarray(row)
            return _map_paged(
                c,
                tbl=lambda x: x.at[:, slot].set(row_j[None]),
            )

        def copy_pages(c, cows):
            if not cows:
                return c
            # one jitted gather-scatter for ALL owed copies per leaf, with
            # the pool buffer donated: XLA updates the pages in place
            # instead of rewriting a pool-sized array per CowCopy
            srcs = jnp.asarray([cw.src for cw in cows], jnp.int32)
            dsts = jnp.asarray([cw.dst for cw in cows], jnp.int32)
            return _map_paged(c, pool=lambda x: _copy_pool_pages(x, srcs, dsts))

        def assign(slot: int) -> bool:
            """Admit the head-of-line request into `slot` if the pool can
            cover it. Returns False (and leaves the queue intact) when it
            cannot — the request waits for pages to free. FIFO order is
            preserved: later requests never jump a blocked head."""
            nonlocal cache, tok, pos
            while queue:
                rid, prompt = queue[0]
                n_prompt = len(prompt)
                if n_prompt + max_new_tokens > self.sc.max_len:
                    raise ValueError(
                        f"request {rid}: prompt {n_prompt} + {max_new_tokens}"
                        f" exceeds max_len {self.sc.max_len}"
                    )
                # speculative post-EOS chunk steps need slack, but tables
                # are only ⌈max_len/page⌉ wide — writes past max_len land
                # on the garbage page instead (the in-table clamp), so the
                # reservation never needs to exceed max_len
                reserve = min(n_prompt + max_new_tokens + chunk_n,
                              self.sc.max_len)
                parent_slot, shared = best_prefix(np.asarray(prompt))
                if not alloc.can_admit(reserve, shared_tokens=shared):
                    # sharing never costs more pages than an unshared admit,
                    # so there is no cheaper retry — wait for frees
                    if any(r >= 0 for r in slot_req):
                        return False  # live sequences will free pages
                    raise PageError(
                        f"request {rid} needs {pages_for(reserve, page)} pages"
                        f" but the pool holds {lay.n_pages - 1}"
                    )
                queue.pop(0)
                cows = alloc.admit(
                    rid, prompt_len=n_prompt, reserve_tokens=reserve,
                    share_from=slot_req[parent_slot] if parent_slot >= 0 else None,
                    shared_tokens=shared,
                )
                cache = copy_pages(cache, cows)
                cache = set_tbl_row(cache, slot, alloc.table(rid))
                # tail-only prefill: shared pages already hold [0, shared)
                tail = np.asarray(prompt[shared:])
                view = _map_paged(
                    cache, batch=lambda x: x[:, slot:slot + 1]
                )
                logits, view = prefill_lm(
                    self.params, jnp.asarray(tail[None], jnp.int32), view,
                    self.mc, start_pos=shared,
                )
                cache = _map_paged(
                    cache, view,
                    pool=lambda x, o: o,  # updated pool (slot's pages only)
                    batch=lambda x, o: x.at[:, slot].set(o[:, 0]),
                )
                self._key, k = jax.random.split(self._key)
                t0 = int(self._to_host(sample_token(logits, k, self.sc))[0])
                done = max_new_tokens <= 1 or (
                    self.sc.eos_id >= 0 and t0 == self.sc.eos_id
                )
                if done:
                    results[rid] = np.asarray([t0], np.int32)
                    alloc.free(rid)
                    cache = set_tbl_row(cache, slot, [])
                    continue
                slot_req[slot] = rid
                slot_out[slot] = [t0]
                slot_len[slot] = n_prompt
                slot_prompt[slot] = np.asarray(prompt)
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(n_prompt)
                return True
            return False

        def retire(slot: int):
            alloc.free(slot_req[slot])
            slot_req[slot] = -1
            slot_prompt[slot] = None

        for s in range(b):
            assign(s)

        self.peak_active = max(self.peak_active, sum(r >= 0 for r in slot_req))
        while any(r >= 0 for r in slot_req):
            # materialize pages for this chunk's writes; mirror grown tables
            for s in range(b):
                if slot_req[s] < 0:
                    continue
                before = len(alloc.table(slot_req[s]))
                # clamp to max_len: table width is ⌈max_len/page⌉ and writes
                # past it clamp to the garbage page in _paged_attn_step
                cows = alloc.extend(
                    slot_req[s], min(slot_len[s] + chunk_n, self.sc.max_len)
                )
                cache = copy_pages(cache, cows)
                if cows or len(alloc.table(slot_req[s])) != before:
                    cache = set_tbl_row(cache, s, alloc.table(slot_req[s]))
            self._key, k = jax.random.split(self._key)
            cache, tok, pos, toks = self._chunk(
                self.params, cache, tok, pos, k, chunk_n
            )
            toks_np = self._to_host(toks)  # one sync per chunk
            finished = []
            for s in range(b):
                rid = slot_req[s]
                if rid < 0:
                    continue
                slot_len[s] = min(slot_len[s] + chunk_n, self.sc.max_len)
                for step in range(chunk_n):
                    t = int(toks_np[step, s])
                    slot_out[s].append(t)
                    done = len(slot_out[s]) >= max_new_tokens or (
                        self.sc.eos_id >= 0 and t == self.sc.eos_id
                    )
                    if done:  # later tokens in this chunk are speculative
                        results[rid] = np.asarray(slot_out[s], np.int32)
                        finished.append(s)
                        break
            for s in finished:
                retire(s)
                # the freed pages may be reassigned immediately — point the
                # dead slot's table at the garbage page before that happens
                cache = set_tbl_row(cache, s, [])
            for s in range(b):  # refill every empty slot the pool now admits
                if slot_req[s] < 0 and queue:
                    if not assign(s):
                        break
            self.peak_active = max(
                self.peak_active, sum(r >= 0 for r in slot_req)
            )
        return [r if r is not None else np.zeros((0,), np.int32) for r in results]
