"""Batched serving engine: prefill → decode with per-sequence state.

A deliberately small but real continuous-batching engine: requests join a
fixed-width slot array; each slot carries its own cache region and length;
finished slots are refilled from the queue. Sampling: greedy / temperature /
top-k.

The decode hot loop is fully on-device (DESIGN.md §3.3):

  * `generate` runs prefill + the entire token loop as ONE jitted
    `lax.scan` — sampling, cache updates, position advance and early-EOS
    masking all happen inside the scan, so a whole generation costs one
    dispatch and exactly ONE device→host sync (the final token fetch).
    The engine counts its host syncs in `self.host_syncs`; tests pin the
    one-sync contract.
  * `serve` (continuous batching) decodes in jitted multi-token chunks
    (`ServeConfig.decode_chunk` steps per dispatch): one host sync per
    chunk instead of per token, with completions / slot refills resolved
    between chunks. Tokens a slot produced after its EOS inside a chunk
    are discarded on the host; the refill prefill then overwrites that
    slot's cache region, so the speculative steps are harmless.

The caches come from the model API (`init_cache`) — attention layers hold
KV rings, SSM/RG-LRU layers hold recurrent state — so the same engine
serves every assigned architecture. When `cfg.attn_impl` is a `*_pallas`
impl, decode attention inside the scan runs the fused split-K kernel
(`repro.kernels.flashd_decode`) with tuned splits.

Sharded serving: pass a `repro.distributed.sharding.ShardingCtx` and the
engine activates it (plus the ambient mesh) around every trace/dispatch,
so the model's logical sharding constraints apply inside the jitted loops.
When the rules engine seq-shards a KV cache (long-context, B too small to
batch-shard), decode attention routes through the cross-device FLASH-D
merge (`repro.distributed.context.cp_decode`) instead of gathering the
cache (DESIGN.md §4.1).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.models.transformer import prefill_lm

__all__ = ["ServeConfig", "Engine", "sample_token"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    eos_id: int = -1  # <0: run to max_new_tokens
    seed: int = 0
    decode_chunk: int = 8  # tokens per device dispatch in `serve`


def sample_token(logits: jax.Array, key, cfg: ServeConfig) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Engine:
    def __init__(self, params, model_cfg: ModelConfig, serve_cfg: ServeConfig,
                 *, sharding_ctx=None):
        self.params = params
        self.mc = model_cfg
        self.sc = serve_cfg
        self.ctx = sharding_ctx  # Optional[repro.distributed.sharding.ShardingCtx]
        self.api = get_model(model_cfg)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, c, t, pos, model_cfg)
        )
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self.host_syncs = 0  # device→host transfers issued by this engine
        self._gen = jax.jit(self._gen_fn, static_argnums=(4,))
        self._chunk = jax.jit(self._chunk_fn, static_argnums=(5,))

    def _scope(self):
        """Sharding scope for traces/dispatches: activates the ctx and the
        ambient mesh so logical constraints (and context-parallel routing)
        resolve inside the jitted loops. No-op without a sharding_ctx."""
        if self.ctx is None:
            return contextlib.nullcontext()
        from repro.distributed import sharding as shd  # lazy: optional dep

        stack = contextlib.ExitStack()
        stack.enter_context(shd.activate(self.ctx))
        mctx = shd.mesh_ctx(self.ctx.mesh)
        if hasattr(mctx, "__enter__"):
            stack.enter_context(mctx)
        return stack

    def _to_host(self, x) -> np.ndarray:
        """The engine's ONLY device→host sync point (counted for tests)."""
        self.host_syncs += 1
        return np.asarray(x)

    # ---- jitted device loops ----
    def _gen_fn(self, params, prompts, cache, key, max_new_tokens: int):
        """Prefill + full decode loop as one device program → tokens [B, T].

        Early-EOS masking: once a sequence has emitted eos_id, subsequent
        positions emit eos_id (the decode steps still run — a lax.scan has
        static trip count — but their tokens are masked in the output)."""
        b, s = prompts.shape
        logits, cache = prefill_lm(params, prompts, cache, self.mc)
        pos0 = jnp.full((b,), s, jnp.int32)
        done0 = jnp.zeros((b,), bool)
        eos = self.sc.eos_id

        def body(carry, k_i):
            logits, cache, pos, done = carry
            tok = sample_token(logits, k_i, self.sc)
            if eos >= 0:
                emit = jnp.where(done, jnp.int32(eos), tok)
                done = jnp.logical_or(done, tok == eos)
            else:
                emit = tok
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            return (logits, cache, pos + 1, done), emit

        keys = jax.random.split(key, max_new_tokens)
        _, toks = jax.lax.scan(body, (logits, cache, pos0, done0), keys)
        return toks.T  # [B, T]

    def _chunk_fn(self, params, cache, tok, pos, key, n: int):
        """`n` decode+sample steps as one device program (continuous batching)."""

        def body(carry, k_i):
            cache, tok, pos = carry
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            nxt = sample_token(logits, k_i, self.sc)
            return (cache, nxt, pos + 1), nxt

        keys = jax.random.split(key, n)
        (cache, tok, pos), toks = jax.lax.scan(body, (cache, tok, pos), keys)
        return cache, tok, pos, toks  # toks [n, B]

    # ---- single-prompt-batch generation (prefill + n decode steps) ----
    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts [B, S_prompt] int32 (right-aligned, no padding support in
        this minimal path) → generated tokens [B, max_new_tokens]."""
        b, s = prompts.shape
        with self._scope():
            cache = self.api.init_cache(b, self.sc.max_len, self.mc)
            self._key, k = jax.random.split(self._key)
            toks = self._gen(
                self.params, jnp.asarray(prompts, jnp.int32), cache, k,
                int(max_new_tokens),
            )
        return self._to_host(toks)

    # ---- continuous batching over a request queue ----
    def serve(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Each request: 1-D prompt array. Returns generated arrays, in order.

        Slot-parallel: up to max_batch requests decode together; finished
        slots take the next queued request between chunks (its prefill runs
        as a batch-1 prefill into that slot's cache region — kept simple
        here; a production engine would chunk prefills into the decode
        batch)."""
        with self._scope():
            return self._serve_impl(requests, max_new_tokens)

    def _serve_impl(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        queue = list(enumerate(requests))
        active: List[dict] = []
        b = self.sc.max_batch
        cache = self.api.init_cache(b, self.sc.max_len, self.mc)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        slot_req = [-1] * b
        slot_out: List[List[int]] = [[] for _ in range(b)]
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))

        def _write_slot(c, o, slot):
            # caches are stacked [n_blocks, batch, ...]: batch is axis 1
            return c.at[:, slot].set(o[:, 0])

        def assign(slot: int):
            """Prefill the next queued request into `slot`. The prefill's
            sampled token is output token 0 (same as `generate`); requests
            that complete immediately are finalized and the next is taken."""
            nonlocal cache, tok, pos
            while queue:
                rid, prompt = queue.pop(0)
                one_cache = self.api.init_cache(1, self.sc.max_len, self.mc)
                logits, one_cache = prefill_lm(
                    self.params, jnp.asarray(prompt[None], jnp.int32), one_cache, self.mc
                )
                self._key, k = jax.random.split(self._key)
                t0 = int(self._to_host(sample_token(logits, k, self.sc))[0])
                done = max_new_tokens <= 1 or (self.sc.eos_id >= 0 and t0 == self.sc.eos_id)
                if done:
                    results[rid] = np.asarray([t0], np.int32)
                    continue
                slot_req[slot] = rid
                slot_out[slot] = [t0]
                cache = jax.tree.map(lambda c, o: _write_slot(c, o, slot), cache, one_cache)
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(len(prompt))
                return
            slot_req[slot] = -1

        for s in range(b):
            assign(s)

        while any(r >= 0 for r in slot_req):
            self._key, k = jax.random.split(self._key)
            cache, tok, pos, toks = self._chunk(
                self.params, cache, tok, pos, k, chunk_n
            )
            toks_np = self._to_host(toks)  # one sync per chunk
            finished = []
            for s in range(b):
                rid = slot_req[s]
                if rid < 0:
                    continue
                for step in range(chunk_n):
                    t = int(toks_np[step, s])
                    slot_out[s].append(t)
                    done = len(slot_out[s]) >= max_new_tokens or (
                        self.sc.eos_id >= 0 and t == self.sc.eos_id
                    )
                    if done:  # later tokens in this chunk are speculative garbage
                        results[rid] = np.asarray(slot_out[s], np.int32)
                        finished.append(s)
                        break
            for s in finished:
                assign(s)  # refill overwrites the slot's cache / tok / pos
        return [r if r is not None else np.zeros((0,), np.int32) for r in results]
