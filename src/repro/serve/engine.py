"""Batched serving engine: prefill → decode with per-sequence state.

A deliberately small but real continuous-batching engine: requests join a
fixed-width slot array; each slot carries its own cache region and length;
finished slots are refilled from the queue. Decode steps are one jitted
`decode_step` over the whole slot batch (the production pattern). Sampling:
greedy / temperature / top-k.

The caches come from the model API (`init_cache`) — attention layers hold
KV rings, SSM/RG-LRU layers hold recurrent state — so the same engine
serves every assigned architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.models.transformer import prefill_lm

__all__ = ["ServeConfig", "Engine", "sample_token"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    eos_id: int = -1  # <0: run to max_new_tokens
    seed: int = 0


def sample_token(logits: jax.Array, key, cfg: ServeConfig) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Engine:
    def __init__(self, params, model_cfg: ModelConfig, serve_cfg: ServeConfig):
        self.params = params
        self.mc = model_cfg
        self.sc = serve_cfg
        self.api = get_model(model_cfg)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, c, t, pos, model_cfg)
        )
        self._key = jax.random.PRNGKey(serve_cfg.seed)

    # ---- single-prompt-batch generation (prefill + n decode steps) ----
    def generate(
        self, prompts: np.ndarray, max_new_tokens: int
    ) -> np.ndarray:
        """prompts [B, S_prompt] int32 (right-aligned, no padding support in
        this minimal path) → generated tokens [B, max_new_tokens]."""
        b, s = prompts.shape
        cache = self.api.init_cache(b, self.sc.max_len, self.mc)
        logits, cache = prefill_lm(
            self.params, jnp.asarray(prompts, jnp.int32), cache, self.mc
        )
        out = []
        pos = jnp.full((b,), s, jnp.int32)
        self._key, k = jax.random.split(self._key)
        tok = sample_token(logits, k, self.sc)
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok, pos)
            pos = pos + 1
            self._key, k = jax.random.split(self._key)
            tok = sample_token(logits, k, self.sc)
        return np.stack(out, axis=1)

    # ---- continuous batching over a request queue ----
    def serve(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Each request: 1-D prompt array. Returns generated arrays, in order.

        Slot-parallel: up to max_batch requests decode together; finished
        slots immediately take the next queued request (its prefill runs as
        a batch-1 prefill into that slot's cache region — kept simple here;
        a production engine would chunk prefills into the decode batch).
        """
        results: List[Optional[np.ndarray]] = [None] * len(requests)
        queue = list(enumerate(requests))
        active: List[dict] = []
        b = self.sc.max_batch
        cache = self.api.init_cache(b, self.sc.max_len, self.mc)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        slot_req = [-1] * b
        slot_out: List[List[int]] = [[] for _ in range(b)]

        def _write_slot(c, o, slot):
            # caches are stacked [n_blocks, batch, ...]: batch is axis 1
            return c.at[:, slot].set(o[:, 0])

        def assign(slot: int):
            """Prefill the next queued request into `slot`. The prefill's
            sampled token is output token 0 (same as `generate`); requests
            that complete immediately are finalized and the next is taken."""
            nonlocal cache, tok, pos
            while queue:
                rid, prompt = queue.pop(0)
                one_cache = self.api.init_cache(1, self.sc.max_len, self.mc)
                logits, one_cache = prefill_lm(
                    self.params, jnp.asarray(prompt[None], jnp.int32), one_cache, self.mc
                )
                self._key, k = jax.random.split(self._key)
                t0 = int(sample_token(logits, k, self.sc)[0])
                done = max_new_tokens <= 1 or (self.sc.eos_id >= 0 and t0 == self.sc.eos_id)
                if done:
                    results[rid] = np.asarray([t0], np.int32)
                    continue
                slot_req[slot] = rid
                slot_out[slot] = [t0]
                cache = jax.tree.map(lambda c, o: _write_slot(c, o, slot), cache, one_cache)
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(len(prompt))
                return
            slot_req[slot] = -1

        for s in range(b):
            assign(s)

        while any(r >= 0 for r in slot_req):
            logits, cache = self._decode(self.params, cache, tok, pos)
            self._key, k = jax.random.split(self._key)
            nxt = sample_token(logits, k, self.sc)
            pos = pos + 1
            refilled = []
            for s in range(b):
                rid = slot_req[s]
                if rid < 0:
                    continue
                t = int(nxt[s])
                slot_out[s].append(t)
                done = len(slot_out[s]) >= max_new_tokens or (
                    self.sc.eos_id >= 0 and t == self.sc.eos_id
                )
                if done:
                    results[rid] = np.asarray(slot_out[s], np.int32)
                    assign(s)  # sets tok[s]/pos[s] for the incoming request
                    refilled.append(s)
            # advance continuing slots to their sampled token; refilled slots
            # keep the token/pos `assign` just installed (prefill output)
            keep_assigned = tok
            tok = nxt
            for s in refilled:
                tok = tok.at[s].set(keep_assigned[s])
        return [r if r is not None else np.zeros((0,), np.int32) for r in results]
