"""Batched serving engine: continuous batching over contiguous, paged, or
packed-varlen KV memory, with automatic prefix caching and preemptive
priority scheduling (DESIGN.md §3.4–§3.6).

Requests join a slot array; finished slots are refilled from a priority
queue (FIFO within a class). Slot lifecycle (queue, per-slot outputs,
EOS/max-token completion, refill, preemption bookkeeping, peak-concurrency
and per-request TTFT accounting) lives in `repro.serve.scheduler.Scheduler`
— shared by every path below; this module owns memory admission and device
dispatch only. Sampling: greedy / temperature / top-k.

Three serving modes (ServeConfig.kv_layout × ServeConfig.step_mode):

  contiguous (default) — each slot owns a fixed max_len-wide cache region;
    memory commits max_batch × max_len tokens up front.
  paged (DESIGN.md §3.4) — KV lives in a global page pool with
    per-sequence block tables (runtime/kvcache.py); admission is by FREE
    PAGES and decode runs the block-table scalar-prefetch kernel under
    `*_pallas`.
  mixed (step_mode="mixed", DESIGN.md §3.5) — chunked-prefill continuous
    batching over the paged pool: every step packs each decoding slot's
    one pending token TOGETHER WITH the next prefill chunks of admitted
    prompts into one flat varlen batch and dispatches ONE jitted
    `forward_packed` step.

Cache-aware, preemptible serving core (DESIGN.md §3.6) — paged + mixed:

  * automatic prefix caching — the allocator's content-addressed radix
    tree persists ACROSS serve() calls on this engine (`self._alloc` and
    the device page pool are engine-lifetime state). Admission walks the
    tree with the prompt's page chain; matched full pages are aliased
    into the new block table and prefill starts at the first uncached
    token (`prefill_lm(start_pos=…)` / the mixed packer's `fed0`), so a
    warm system prompt costs O(new tokens) TTFT. Prompts are indexed once
    their prefill completes (live sharing); retirement donates the whole
    clean token stream — including generated tokens — so a multi-turn
    follow-up that replays the previous conversation hits the cache too.
  * preemptive scheduling — with `ServeConfig.preemption` (default on),
    worst-case `reserve_tokens` admission is replaced by optimistic
    per-chunk allocation: a request is admitted when its PROMPT fits, and
    growth draws the free pool. When the pool (or the slot array, given a
    higher-priority arrival) is exhausted, the scheduler's victim — the
    lowest-priority, youngest slot — is preempted: its pages are donated
    to the prefix cache (making resume nearly free) and the request is
    re-queued with recompute-on-resume, which keeps every output stream
    token-identical to an unconstrained run while letting the pool be
    oversubscribed (pool < worst-case demand still completes).

`Engine.stats()` exposes the hit-rate / preemption / eviction counters,
cumulative over the engine's lifetime.

Static-shape bucketing (DESIGN.md §3.5): prompt lengths and packed-batch
sizes are padded to powers of two (`tuning.bucket_pow2`) before they reach
a jitted program — prefills run with per-row `lengths` masking
(`prefill_lm`), packs carry −1 padding rows — so `serve()` compiles
O(log max_len) programs instead of one per distinct length (pinned by
tests/test_scheduler.py).

The decode hot loop is fully on-device (DESIGN.md §3.3): `generate` is one
jitted prefill + `lax.scan` (exactly ONE device→host sync, counted in
`self.host_syncs`); the sequential `serve` loops decode in jitted
`decode_chunk`-token chunks (one sync per chunk); the mixed loop syncs
once per packed step.

Sharded serving: pass a `repro.distributed.sharding.ShardingCtx` and the
engine activates it (plus the ambient mesh) around every trace/dispatch;
seq-sharded KV caches route decode through the cross-device FLASH-D merge
(`repro.distributed.context.cp_decode`, DESIGN.md §4.1).

Fault tolerance (DESIGN.md §3.7): every request gets a lifecycle contract
— it ends DONE, FAILED (fault-retry budget exhausted), or EXPIRED
(deadline), never silently dropped. A seeded `FaultInjector` can raise
simulated failures at four named sites threaded through all three serve
loops (page_alloc / kernel_dispatch / device_step / host_sync); faults are
isolated to the request (or step) they hit — the faulted request rolls
back through the same recompute-on-resume path preemption uses, charged
against `ServeConfig.max_retries`, while its neighbors keep decoding. A
real crash no longer resets the page pool: the recovery handler folds
live slots back into the queue (pages donated — their KV is committed
state) and keeps the allocator + radix tree warm. `snapshot()/restore()`
serialize the queue, results, and the radix tree's TOKEN chains through
`runtime/checkpoint.py` — never KV pages, because FLASH-D's (O, Λ) state
is a pure function of the token stream and `restore()` recomputes it
exactly. Repeated kernel faults downgrade a `*_pallas` attention impl to
its registered jnp fallback for the rest of the engine's life
(`kernels/ops.py`), recorded in `stats()`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Set, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.models.transformer import forward_packed, packed_mixers_ok, prefill_lm
from repro.runtime.resilience import FaultInjector, InjectedFault, StragglerMonitor
from repro.serve.scheduler import TERMINAL, Request, Scheduler, StepPlan

__all__ = ["ServeConfig", "Engine", "sample_token"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    eos_id: int = -1  # <0: run to max_new_tokens
    seed: int = 0
    decode_chunk: int = 8  # tokens per device dispatch in sequential `serve`
    # ---- paged KV cache (DESIGN.md §3.4) ----
    kv_layout: str = "contiguous"  # "paged": page-pool KV in `serve`
    page_size: int = 0  # 0 → repro.kernels.tuning heuristic
    kv_pool_tokens: int = 0  # pool size in tokens; 0 → max_batch·max_len
    # quantized page pool (DESIGN.md §3.8): "" keeps the compute dtype;
    # a name from repro.runtime.quant.available() ("int8", and "fp8" where
    # the host jax has float8) stores pages in that format with per-(page,
    # head) f32 scale leaves, dequantized inside the attention kernels
    kv_dtype: str = ""
    # prefix reuse: `prefix_sharing` is the soundness gate (global-attn
    # stacks only — auto-disabled on hybrid stacks), `prefix_cache` the
    # mechanism (the radix tree, which subsumes the old live-scan sharing:
    # live prompts are indexed at prefill). Either False disables ALL
    # prefix reuse — every prompt prefills in full.
    prefix_sharing: bool = True
    # ---- radix prefix cache + preemption (DESIGN.md §3.6) ----
    prefix_cache: bool = True  # content-addressed page cache across requests
    cache_min_free_pages: int = -1  # eviction watermark; -1 → tuning heuristic
    cache_max_pages: int = -1  # retained-page cap; -1 → tuning heuristic
    preemption: bool = True  # optimistic admission + victim preemption
    # ---- mixed varlen step (DESIGN.md §3.5) ----
    step_mode: str = "sequential"  # "mixed": chunked-prefill packed steps
    token_budget: int = 0  # packed tokens per mixed step; 0 → heuristic
    prefill_chunk: int = 16  # max prompt tokens one sequence feeds per step
    # ---- speculative decoding (DESIGN.md §3.9) ----
    # K draft tokens verified per target step through one packed varlen
    # dispatch; 0 disables. Needs `Engine(draft=...)` (a (params, cfg)
    # pair for a small draft model, or a host callable), greedy sampling
    # (temperature 0 — acceptance is argmax-exact), and a paged,
    # packed-capable stack for the verify step.
    spec_tokens: int = 0
    # ---- fault tolerance (DESIGN.md §3.7) ----
    max_retries: int = 3  # per-request fault-retry budget (then FAILED)
    retry_backoff_s: float = 0.0  # base of the exponential requeue backoff
    deadline_s: float = 0.0  # default per-request deadline; 0 → none
    fault_rate: float = 0.0  # chaos: per-site injected-fault probability
    fault_seed: int = 0  # chaos: injector stream seed
    downgrade_after: int = 3  # consecutive kernel faults before jnp fallback


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_pages(pages, srcs, dsts):
    """pages[:, d] ← pages[:, s] for every owed CoW copy, in one update.
    The pool is donated so backends that support donation do it in place
    (O(pages copied), not O(pool))."""
    return pages.at[:, dsts].set(pages[:, srcs])


def _map_paged(cache, *rest, pool=None, tbl=None, batch=None):
    """Tree-map over a (possibly paged) cache with per-leaf-kind functions.

    Leaf kinds by dict key: `k_pages`/`v_pages` are POOL leaves (global
    page arrays, no batch axis — [n_blocks, P, page, Hkv, hd]), as are the
    quantized pool's scale side-bands `k_scale`/`v_scale` ([n_blocks, P,
    Hkv] — physical-page axis in the same position, so page copies move
    page bytes and scale together); everything else — including the block
    table `tbl` — is a PER-BATCH leaf (batch on axis 1 after block
    stacking). `tbl=` overrides the per-batch handler for table leaves
    (engine table mirroring); a missing handler leaves the leaf unchanged.
    Extra cache trees in `rest` are zipped leaf-wise."""
    from jax import tree_util as jtu

    def leaf_name(path):
        for e in reversed(path):
            if isinstance(e, jtu.DictKey):
                return e.key
        return None

    def apply(path, x, *xs):
        name = leaf_name(path)
        if name in ("k_pages", "v_pages", "k_scale", "v_scale"):
            fn = pool
        elif name == "tbl":
            fn = tbl if tbl is not None else batch
        else:
            fn = batch
        return x if fn is None else fn(x, *xs)

    return jtu.tree_map_with_path(apply, cache, *rest)


def sample_token(logits: jax.Array, key, cfg: ServeConfig) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class _PoolCtx:
    """Mutable per-serve() context of the paged loops: the device cache
    tree plus the slot → allocator-sequence map and which slots' prompts
    are already indexed in the radix tree."""

    __slots__ = ("cache", "seq_of", "inserted")

    def __init__(self, cache):
        self.cache = cache
        self.seq_of: Dict[int, int] = {}
        self.inserted: Set[int] = set()


class Engine:
    def __init__(self, params, model_cfg: ModelConfig, serve_cfg: ServeConfig,
                 *, sharding_ctx=None,
                 fault_injector: Optional[FaultInjector] = None,
                 draft=None):
        self.params = params
        self.mc = model_cfg
        self.sc = serve_cfg
        self.ctx = sharding_ctx  # Optional[repro.distributed.sharding.ShardingCtx]
        self.api = get_model(model_cfg)
        self._build_jits()
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self.host_syncs = 0  # device→host transfers issued by this engine
        self.peak_active = 0  # max concurrent sequences observed by `serve`
        self.ttft = {}  # rid → time-to-first-token of the last serve() call
        self._page_layout = None
        if serve_cfg.kv_layout == "paged" or serve_cfg.step_mode == "mixed":
            from repro.kernels.tuning import choose_page_layout  # lazy
            from repro.models.transformer import paged_mixers

            if getattr(model_cfg, "is_encdec", False) or not paged_mixers(model_cfg):
                # no global-attention layer to page (pure SSM/ring stacks,
                # enc-dec) — serve falls back to the contiguous layout
                pass
            else:
                from repro.runtime import quant  # lazy: no cycle

                self._page_layout = choose_page_layout(
                    serve_cfg.max_len,
                    model_cfg.head_dim_,
                    model_cfg.head_dim_,
                    group=model_cfg.n_heads // model_cfg.n_kv_heads,
                    pool_tokens=serve_cfg.kv_pool_tokens
                    or serve_cfg.max_batch * serve_cfg.max_len,
                    page_size=serve_cfg.page_size or None,
                    kv_itemsize=quant.kv_itemsize(serve_cfg.kv_dtype),
                )
        # the mixed varlen step runs every layer on flat packed tokens
        # through the paged pool — global-attention-only stacks
        self._mixed_ok = (
            serve_cfg.step_mode == "mixed"
            and self._page_layout is not None
            and packed_mixers_ok(model_cfg)
        )
        # prefix reuse skips the shared positions' prefill steps, which is
        # only sound when EVERY mixer reads the paged cache: ring
        # (local/chunked) and SSM/RG-LRU layers carry state those steps
        # would have produced (see prefill_lm's start_pos contract)
        self._can_share_prefix = (
            self._page_layout is not None
            and serve_cfg.prefix_sharing
            and all(
                m in ("attn", "attn_nope", "attn_bidir")
                for m, _ in (*model_cfg.pattern, *model_cfg.remainder)
            )
        )
        # radix prefix cache (DESIGN.md §3.6): page-content addressing is
        # sound exactly when prefix reuse is (KV at position p is a pure
        # function of tokens [0, p] for a global-attention stack)
        self._cache_on = self._can_share_prefix and serve_cfg.prefix_cache
        # engine-lifetime paged state: the allocator (and its radix tree)
        # plus the device page pool persist across serve() calls so cached
        # prefixes survive between request batches
        self._alloc = None
        self._paged_cache = None
        self._seq_base = 0  # allocator sequence ids, unique across calls
        self._stats = {
            "prefix_lookups": 0, "prefix_hits": 0, "hit_tokens": 0,
            "prompt_tokens": 0, "preemptions": 0,
            "failed": 0, "retried": 0, "expired": 0,
            "downgrades": 0, "slow_steps": 0,
            "spec_rounds": 0, "spec_drafted": 0, "spec_accepted": 0,
        }
        # ---- speculative decoding (DESIGN.md §3.9) ----
        self._spec = None
        if serve_cfg.spec_tokens > 0:
            from repro.serve.speculative import DraftModel, SpecState  # lazy

            if draft is None:
                raise ValueError(
                    "spec_tokens > 0 needs Engine(draft=...): a (params, "
                    "ModelConfig) pair for a draft model or a host callable"
                )
            if serve_cfg.temperature > 0:
                raise ValueError(
                    "speculative decoding is greedy-only: the accept rule "
                    "compares draft tokens against the target's argmax, so "
                    "temperature must be 0"
                )
            if self._page_layout is None or not packed_mixers_ok(model_cfg) \
                    or not (self._mixed_ok or serve_cfg.kv_layout == "paged"):
                raise ValueError(
                    "speculative decoding verifies drafts through the packed "
                    "varlen step over the paged pool — needs kv_layout="
                    "'paged' or step_mode='mixed' on a packed-capable stack"
                )
            if isinstance(draft, tuple):
                dparams, dcfg = draft
                draft = DraftModel(
                    dparams, dcfg, max_batch=serve_cfg.max_batch,
                    max_len=serve_cfg.max_len,
                )
            self._spec = SpecState(k=int(serve_cfg.spec_tokens), draft=draft)
        # ---- fault tolerance (DESIGN.md §3.7) ----
        if fault_injector is None and serve_cfg.fault_rate > 0:
            fault_injector = FaultInjector(
                serve_cfg.fault_rate, serve_cfg.fault_seed
            )
        self._injector = fault_injector
        self._kernel_faults = 0  # consecutive kernel-site faults (downgrade)
        self._step_faults = 0  # consecutive faulted steps (victim charging)
        self._step_no = 0  # engine-lifetime serve steps (watchdog key)
        self._watchdog = StragglerMonitor(on_straggler=self._note_slow_step)
        self._sched: Optional[Scheduler] = None  # last/current serve's scheduler
        self._resume_state: Optional[dict] = None  # restored snapshot, pre-resume

    def _build_jits(self) -> None:
        """(Re)build every jitted entry point. Each wrapper closes over
        `self.mc` / `self.api`, which jit treats as trace-time constants —
        so the graceful-degradation path MUST call this after swapping
        `attn_impl` (mutating `self.mc` alone would keep serving the old
        compiled programs)."""
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, c, t, pos, self.mc)
        )
        self._gen = jax.jit(self._gen_fn, static_argnums=(5,))
        self._chunk = jax.jit(self._chunk_fn, static_argnums=(5,))
        # bucketed prefill: one program per power-of-two prompt bucket;
        # start_pos rides as a traced scalar so shared-prefix tails of any
        # length reuse the same program
        self._prefill = jax.jit(
            lambda p, t, c, sp, ln: prefill_lm(
                p, t, c, self.mc, start_pos=sp, lengths=ln
            )
        )
        self._mixed = jax.jit(self._mixed_fn, static_argnums=(8,))
        self._verify = jax.jit(self._verify_fn, static_argnums=(8,))

    def _scope(self):
        """Sharding scope for traces/dispatches: activates the ctx and the
        ambient mesh so logical constraints (and context-parallel routing)
        resolve inside the jitted loops. No-op without a sharding_ctx."""
        if self.ctx is None:
            return contextlib.nullcontext()
        from repro.distributed import sharding as shd  # lazy: optional dep

        stack = contextlib.ExitStack()
        stack.enter_context(shd.activate(self.ctx))
        mctx = shd.mesh_ctx(self.ctx.mesh)
        if hasattr(mctx, "__enter__"):
            stack.enter_context(mctx)
        return stack

    def _to_host(self, x) -> np.ndarray:
        """The engine's ONLY device→host sync point (counted for tests)."""
        self.host_syncs += 1
        return np.asarray(x)

    def _bucket(self, n: int) -> int:
        from repro.kernels.tuning import bucket_pow2  # lazy: no cycle

        return bucket_pow2(n, lo=8, hi=self.sc.max_len)

    # ---- fault injection / degradation (DESIGN.md §3.7) ----
    def _inj(self, site: str, rid: Optional[int] = None) -> None:
        if self._injector is not None:
            self._injector.check(site, rid=rid)

    def _sync(self, x, rid: Optional[int] = None) -> np.ndarray:
        """Serve-loop device→host sync: the host_sync injection site."""
        self._inj("host_sync", rid)
        return self._to_host(x)

    def _note_slow_step(self, step: int, dt: float, ewma: float) -> None:
        self._stats["slow_steps"] += 1

    def _bump_step(self) -> int:
        self._step_no += 1
        return self._step_no

    def _note_fault(self, exc: InjectedFault) -> None:
        """Record an injected fault; consecutive kernel-site faults on a
        Pallas impl trigger the jnp downgrade."""
        if exc.site in ("kernel_dispatch", "device_step"):
            self._kernel_faults += 1
            if (self._kernel_faults >= self.sc.downgrade_after
                    and self.mc.attn_impl.endswith("_pallas")):
                self._downgrade()

    def _clear_fault_streak(self) -> None:
        """Any successful dispatch breaks the consecutive-fault streaks."""
        self._kernel_faults = 0
        self._step_faults = 0

    def _downgrade(self) -> None:
        """Graceful degradation: flip the attention impl to its registered
        jnp fallback and rebuild the jitted entry points (they close over
        `self.mc` — see `_build_jits`). One-way for the engine's lifetime;
        recorded in `stats()["downgrades"]` / `["attn_impl"]`."""
        from repro.kernels.ops import fallback_impl  # lazy: no cycle

        fb = fallback_impl(self.mc.attn_impl)
        if fb == self.mc.attn_impl:
            return
        self._stats["downgrades"] += 1
        self.mc = dataclasses.replace(self.mc, attn_impl=fb)
        self.api = get_model(self.mc)
        self._build_jits()
        self._kernel_faults = 0

    def _on_step_fault(self, sched: Scheduler, exc: InjectedFault,
                       release) -> None:
        """A step-wide injected fault: the step's uncommitted device
        results were discarded, so retrying it is exact (committed host
        state never advanced). To guarantee progress under a hostile
        schedule, after `max_retries` consecutive faulted steps the
        scheduler's victim slot is charged one retry (requeue, or FAILED
        when its budget is out) via `release` — which also frees/donates
        its memory — and the streak resets."""
        self._note_fault(exc)
        self._step_faults += 1
        if self._step_faults > sched.max_retries:
            self._step_faults = 0
            v = sched.victim_slot()
            if v is not None:
                release(v)

    def _await_backoff(self, sched: Scheduler) -> bool:
        """No live slot: everything left is queued (usually behind a retry
        backoff gate). Sleep until the earliest becomes eligible; False
        when the queue is empty too (serving is over)."""
        if not sched.queue:
            return False
        wait = sched.next_ready_in()
        if wait is not None and wait > 0:
            time.sleep(wait)
        return True

    def _make_sched(self, requests, max_new_tokens: int, priorities,
                    deadlines) -> Scheduler:
        if deadlines is None and self.sc.deadline_s > 0:
            deadlines = [self.sc.deadline_s] * len(requests)
        sched = Scheduler(
            requests, max_new_tokens, self.sc.max_batch, self.sc.eos_id,
            priorities=priorities, deadlines=deadlines,
            max_retries=self.sc.max_retries,
            retry_backoff_s=self.sc.retry_backoff_s,
        )
        self._sched = sched
        return sched

    def _finish_serve(self, sched: Scheduler) -> None:
        self.ttft = dict(sched.first_token_at)
        self._stats["preemptions"] += sched.preemptions
        self._stats["retried"] += sched.retried
        self._stats["failed"] += sched.failed
        self._stats["expired"] += sched.expired
        self._stats["spec_rounds"] += sched.spec_rounds
        self._stats["spec_drafted"] += sched.spec_drafted
        self._stats["spec_accepted"] += sched.spec_accepted

    # ---- observability ----
    def stats(self) -> dict:
        """Serving counters, cumulative over this engine's lifetime:
        prefix-cache hit rate (token-weighted), preemption / eviction /
        donation counts, pool occupancy, and the last serve() call's
        per-request TTFT."""
        s = dict(self._stats)
        s["hit_rate"] = s["hit_tokens"] / max(s["prompt_tokens"], 1)
        s["prefix_cache_enabled"] = self._cache_on
        s["preemption_enabled"] = bool(self.sc.preemption)
        s["spec_enabled"] = self._spec is not None
        s["spec_rejected"] = s["spec_drafted"] - s["spec_accepted"]
        s["spec_acceptance_rate"] = (
            s["spec_accepted"] / max(s["spec_drafted"], 1)
        )
        # committed tokens per verify round = accepted drafts + the bonus
        # token every round emits — the speedup lever BENCH_spec sweeps
        s["spec_mean_accepted"] = (
            s["spec_accepted"] / max(s["spec_rounds"], 1)
        )
        if self._alloc is not None:
            s.update(
                evictions=self._alloc.evictions,
                donated_pages=self._alloc.donated_pages,
                cached_pages=self._alloc.cached_pages,
                pages_in_use=self._alloc.pages_in_use,
                free_pages=self._alloc.free_pages,
            )
        if self._paged_cache is not None and self._page_layout is not None:
            # actual device footprint of the page pools (quantized pages +
            # scale side-band included) per pool token — the equal-HBM
            # denominator BENCH_quant.json budgets against
            seen = 0
            from jax import tree_util as jtu

            for path, leaf in jtu.tree_leaves_with_path(self._paged_cache):
                name = next(
                    (e.key for e in reversed(path)
                     if isinstance(e, jtu.DictKey)), None,
                )
                if name in ("k_pages", "v_pages", "k_scale", "v_scale"):
                    seen += leaf.nbytes
            pool_tokens = self._page_layout.n_pages * self._page_layout.page_size
            s["kv_pool_bytes"] = int(seen)
            s["kv_bytes_per_token"] = seen / max(pool_tokens, 1)
            s["kv_dtype"] = self.sc.kv_dtype or "native"
        s["peak_active"] = self.peak_active
        s["ttft"] = dict(self.ttft)
        s["attn_impl"] = self.mc.attn_impl
        if self._injector is not None:
            s["injected_faults"] = dict(self._injector.fired)
            s["fault_checks"] = dict(self._injector.calls)
        if self._sched is not None:
            s["request_status"] = dict(self._sched.status)
        return s

    # ---- jitted device loops ----
    def _gen_fn(self, params, prompts, cache, key, real_len, max_new_tokens: int):
        """Prefill + full decode loop as one device program → tokens [B, T].

        `prompts` may be padded past the real prompt to a power-of-two
        bucket; `real_len` (traced i32 scalar) is the shared true length —
        prefill_lm masks the padding steps, so the bucket only decides
        which compiled program runs, never the result.

        Early-EOS masking: once a sequence has emitted eos_id, subsequent
        positions emit eos_id (the decode steps still run — a lax.scan has
        static trip count — but their tokens are masked in the output)."""
        b, _ = prompts.shape
        logits, cache = prefill_lm(
            params, prompts, cache, self.mc,
            lengths=jnp.full((b,), real_len, jnp.int32),
        )
        pos0 = jnp.full((b,), real_len, jnp.int32)
        done0 = jnp.zeros((b,), bool)
        eos = self.sc.eos_id

        def body(carry, k_i):
            logits, cache, pos, done = carry
            tok = sample_token(logits, k_i, self.sc)
            if eos >= 0:
                emit = jnp.where(done, jnp.int32(eos), tok)
                done = jnp.logical_or(done, tok == eos)
            else:
                emit = tok
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            return (logits, cache, pos + 1, done), emit

        keys = jax.random.split(key, max_new_tokens)
        _, toks = jax.lax.scan(body, (logits, cache, pos0, done0), keys)
        return toks.T  # [B, T]

    def _chunk_fn(self, params, cache, tok, pos, key, n: int):
        """`n` decode+sample steps as one device program (continuous batching)."""

        def body(carry, k_i):
            cache, tok, pos = carry
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            nxt = sample_token(logits, k_i, self.sc)
            return (cache, nxt, pos + 1), nxt

        keys = jax.random.split(key, n)
        (cache, tok, pos), toks = jax.lax.scan(body, (cache, tok, pos), keys)
        return cache, tok, pos, toks  # toks [n, B]

    def _mixed_fn(self, params, cache, tokens, seq_ids, positions, kv_len,
                  last_rows, key, block_q: int):
        """ONE mixed prefill/decode step (DESIGN.md §3.5): the packed
        varlen forward over the whole stack + sampling at each emitting
        sequence's last row. Retraces only per packed-length bucket.
        `block_q` is the packer's alignment granularity (static)."""
        logits, cache = forward_packed(
            params, tokens, seq_ids, positions, kv_len, cache, self.mc,
            last_rows, block_q=block_q,
        )
        return cache, sample_token(logits, key, self.sc)

    def _verify_fn(self, params, cache, tokens, seq_ids, positions, kv_len,
                   rows, draft_toks, block_q: int):
        """ONE speculative verify step (DESIGN.md §3.9): the packed varlen
        forward with logits read at EVERY verify row, plus the on-device
        longest-accepted-prefix rule — all inside the jitted step, so a
        speculative round costs exactly one host sync.

        `rows` [B, R]: each verify segment's pack rows (row 0 is the
        committed pending token, rows 1..n its draft chain; −1 pads — a
        prefill-final segment uses only row 0). `draft_toks` [B, R−1]
        (−1 = no draft) may live on device (DraftModel proposals never
        visit the host): they are scattered into the pack's placeholder
        token rows here, before the forward. Returns (cache, [B, R+1]):
        the target's greedy token at every row, with the accepted-draft
        count appended as the last column (split host-side after the one
        sync). Greedy only — acceptance compares drafts against argmax,
        which makes the committed stream token-identical to
        non-speculative greedy decoding by construction."""
        t = tokens.shape[0]
        dr = rows[:, 1:]
        # clamp proposals into the real vocab: an out-of-range id would
        # embed as NaN (jnp.take fills OOB gathers) and poison the whole
        # packed step through the masked accumulation. Acceptance below
        # compares against the CLAMPED id — the rule is "accept iff the
        # token actually fed equals the previous row's argmax", so output
        # stays token-identical whatever a (vocab-mismatched, buggy,
        # adversarial) draft proposes. Negatives stay −1 = no draft.
        dt = jnp.minimum(draft_toks, self.mc.vocab_size - 1)
        ok = (dr >= 0) & (dt >= 0)
        # out-of-bounds index (t) + mode="drop" skips masked entries
        # (−1 would WRAP to the last row)
        idx = jnp.where(ok, dr, t)
        vals = jnp.where(ok, dt, 0).astype(tokens.dtype)
        tokens = tokens.at[idx.reshape(-1)].set(
            vals.reshape(-1), mode="drop"
        )
        logits, cache = forward_packed(
            params, tokens, seq_ids, positions, kv_len, cache, self.mc,
            rows, block_q=block_q,
        )  # [B, R, Vpad]
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, R]
        match = (g[:, :-1] == dt) & (dt >= 0)
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        return cache, jnp.concatenate([g, n_acc[:, None]], axis=1)

    # ---- single-prompt-batch generation (prefill + n decode steps) ----
    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts [B, S_prompt] int32 (right-aligned, no padding support in
        this minimal path) → generated tokens [B, max_new_tokens].

        Both the prompt length and the decode-step count are bucketed to
        powers of two (excess steps run masked, excess output is sliced
        off), so repeated calls at drifting lengths reuse O(log max_len)
        compiled programs."""
        b, s = prompts.shape
        if s + max_new_tokens > self.sc.max_len:
            raise ValueError(
                f"prompt {s} + {max_new_tokens} exceeds max_len {self.sc.max_len}"
            )
        from repro.kernels.tuning import bucket_pow2  # lazy: no cycle

        sb = self._bucket(s)
        nb = bucket_pow2(max_new_tokens, lo=1)
        padded = np.zeros((b, sb), np.int32)
        padded[:, :s] = prompts
        with self._scope():
            cache = self.api.init_cache(b, self.sc.max_len, self.mc)
            self._key, k = jax.random.split(self._key)
            toks = self._gen(
                self.params, jnp.asarray(padded), cache, k, jnp.int32(s),
                int(nb),
            )
        return self._to_host(toks)[:, :max_new_tokens]

    # ---- continuous batching over a request queue ----
    def serve(self, requests: Sequence[Union[np.ndarray, Request]],
              max_new_tokens: int,
              priorities: Optional[Sequence[int]] = None,
              deadlines: Optional[Sequence[Optional[float]]] = None,
              ) -> List[np.ndarray]:
        """Each request: 1-D prompt array (or a `Request` carrying resume
        state, e.g. from a snapshot). Returns generated arrays, in order —
        one per request, ALWAYS: a FAILED or EXPIRED request's entry holds
        whatever it generated before going terminal (`stats()
        ["request_status"]` tells them apart).

        `priorities` (optional, higher = more urgent, default all-0 FIFO)
        steer admission order and — with `ServeConfig.preemption` — let a
        high-priority arrival preempt a lower-priority victim.
        `deadlines` (seconds from enqueue, None = none; default from
        `ServeConfig.deadline_s`) cancel overdue requests exactly like
        EOS.

        Routing: `step_mode="mixed"` (and a packed-capable stack) runs the
        chunked-prefill mixed varlen loop; otherwise the paged or
        contiguous sequential loop. All three share the Scheduler's slot
        lifecycle and are token-identical under greedy sampling — with
        the prefix cache and preemption enabled or disabled."""
        with self._scope():
            if self._mixed_ok:
                return self._serve_mixed(
                    requests, max_new_tokens, priorities, deadlines
                )
            # fall back along the CONFIGURED memory model: a mixed request
            # on a non-packed-capable stack must not silently switch an
            # explicitly contiguous engine onto the page pool
            if self._page_layout is not None and self.sc.kv_layout == "paged":
                return self._serve_paged(
                    requests, max_new_tokens, priorities, deadlines
                )
            return self._serve_impl(
                requests, max_new_tokens, priorities, deadlines
            )

    def _check_len(self, rid: int, n_prompt: int, max_new_tokens: int) -> None:
        if n_prompt + max_new_tokens > self.sc.max_len:
            raise ValueError(
                f"request {rid}: prompt {n_prompt} + {max_new_tokens}"
                f" exceeds max_len {self.sc.max_len}"
            )

    def _set_tbl_row(self, cache, slot: int, table: List[int]):
        """Mirror one slot's allocator block table into every layer's
        device `tbl` leaf (zero-padded: unmapped logical pages point at
        the garbage page). Shared by the paged and mixed loops."""
        row = np.zeros((self._page_layout.pages_per_seq,), np.int32)
        row[: len(table)] = table
        row_j = jnp.asarray(row)
        return _map_paged(cache, tbl=lambda x: x.at[:, slot].set(row_j[None]))

    def _prefill_bucketed(self, prompt: np.ndarray, cache, *, start_pos: int = 0):
        """Prefill `prompt[start_pos:]` into a batch-1 cache view with the
        token axis padded to a power-of-two bucket (prefill_lm masks the
        padding rows), so distinct prompt lengths share compiled programs."""
        tail = np.asarray(prompt[start_pos:])
        n = len(tail)
        nb = self._bucket(n)
        padded = np.zeros((1, nb), np.int32)
        padded[0, :n] = tail
        return self._prefill(
            self.params, jnp.asarray(padded), cache,
            jnp.int32(start_pos), jnp.asarray([n], jnp.int32),
        )

    # ---- contiguous continuous batching ----
    def _serve_impl(self, requests, max_new_tokens: int,
                    priorities=None, deadlines=None) -> List[np.ndarray]:
        b = self.sc.max_batch
        sched = self._make_sched(requests, max_new_tokens, priorities,
                                 deadlines)
        cache = self.api.init_cache(b, self.sc.max_len, self.mc)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))

        def _write_slot(c, o, slot):
            # caches are stacked [n_blocks, batch, ...]: batch is axis 1
            return c.at[:, slot].set(o[:, 0])

        def assign(slot: int):
            """Prefill the next queued request into `slot`. The prefill's
            sampled token is output token 0 (same as `generate`); a
            resumed request's effective prompt replays its pre-preemption
            tokens (recompute-on-resume). Requests that complete
            immediately are finalized and the next is taken. An injected
            fault is isolated to the request in hand: it re-queues (or
            goes FAILED) and the next head is tried — the live neighbors
            never notice."""
            nonlocal cache, tok, pos
            while (req := sched.take_head()) is not None:
                toks = req.tokens
                self._check_len(req.rid, len(req.prompt), max_new_tokens)
                try:
                    self._inj("kernel_dispatch", req.rid)
                    one_cache = self.api.init_cache(1, self.sc.max_len, self.mc)
                    logits, one_cache = self._prefill_bucketed(toks, one_cache)
                    self._inj("device_step", req.rid)
                    self._key, k = jax.random.split(self._key)
                    t0 = int(self._sync(
                        sample_token(logits, k, self.sc), rid=req.rid
                    )[0])
                except InjectedFault as e:
                    self._note_fault(e)
                    sched.retry_request(req)
                    continue
                self._clear_fault_streak()
                if not sched.admit_request(slot, req, t0):
                    continue
                cache = jax.tree.map(
                    lambda c, o: _write_slot(c, o, slot), cache, one_cache
                )
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(len(toks))
                return

        def preempt_for_priority():
            """A queued request of strictly higher priority than a live
            slot evicts that slot (lowest-priority, youngest first): the
            victim re-queues with recompute-on-resume, the arrival takes
            its place. Slot-array pressure is the contiguous engine's
            analogue of page pressure."""
            if not self.sc.preemption:
                return
            while (req := sched.head()) is not None and sched.free_slot() is None:
                v = sched.victim_slot(below=req.priority)
                if v is None:
                    return
                sched.preempt(v)
                assign(v)

        def refill():
            for s in range(b):
                if not sched.slots[s].live:
                    assign(s)
            preempt_for_priority()

        try:
            refill()
            self.peak_active = sched.note_peak()
            while sched.has_active() or sched.queue:
                for s in sched.expire_overdue():
                    sched.retire(s)  # the slot's cache region just goes stale
                if not sched.has_active():
                    if not self._await_backoff(sched):
                        break
                    refill()
                    continue
                self._watchdog.start_step()
                self._key, k = jax.random.split(self._key)
                try:
                    self._inj("kernel_dispatch")
                    cache2, tok2, pos2, toks = self._chunk(
                        self.params, cache, tok, pos, k, chunk_n
                    )
                    self._inj("device_step")
                    toks_np = self._sync(toks)  # one sync per chunk
                except InjectedFault as e:
                    # discard the uncommitted step and retry it — exact,
                    # because committed host state never advanced
                    self._on_step_fault(sched, e, sched.fault_slot)
                    continue
                self._watchdog.end_step(self._bump_step())
                self._clear_fault_streak()
                cache, tok, pos = cache2, tok2, pos2
                for s in sched.absorb_chunk(toks_np):
                    sched.retire(s)
                    assign(s)  # refill overwrites the slot's cache / tok / pos
                preempt_for_priority()
                self.peak_active = sched.note_peak()
        except Exception:
            # crash recovery: fold live slots back into the queue so a
            # snapshot() sees every unfinished request (contiguous KV has
            # no engine-lifetime state to preserve)
            for s, sl in enumerate(sched.slots):
                if sl.live:
                    sched.preempt(s)
            raise
        self._finish_serve(sched)
        return sched.results_list()

    # ---- paged-pool shared machinery (DESIGN.md §3.4 + §3.6) ----
    def _paged_state(self):
        """Engine-lifetime paged state: allocator (radix tree included)
        and the device page pool, created lazily and reused across
        serve() calls so cached prefixes persist between request batches."""
        if self._alloc is None:
            from repro.kernels.tuning import choose_cache_policy
            from repro.runtime.kvcache import CachePolicy, PagedKVAllocator

            lay = self._page_layout
            if self._cache_on:
                policy = choose_cache_policy(
                    lay.n_pages, lay.page_size,
                    min_free_pages=(
                        None if self.sc.cache_min_free_pages < 0
                        else self.sc.cache_min_free_pages
                    ),
                    max_cached_pages=(
                        None if self.sc.cache_max_pages < 0
                        else self.sc.cache_max_pages
                    ),
                )
            else:
                policy = CachePolicy(max_cached_pages=0)
            self._alloc = PagedKVAllocator(
                lay.n_pages, lay.page_size, cache_policy=policy
            )
            self._paged_cache = self.api.init_cache(
                self.sc.max_batch, self.sc.max_len, self.mc,
                layout="paged", page_size=lay.page_size, n_pages=lay.n_pages,
                kv_dtype=self.sc.kv_dtype,
            )
        return self._alloc, self._paged_cache

    def _recover_paged(self, sched: Scheduler, alloc, ctx: _PoolCtx) -> None:
        """Crash recovery (DESIGN.md §3.7): roll every live slot back into
        the queue — pages donated, their KV is committed state — free any
        orphaned admissions, zero every device table row, and KEEP the
        allocator, radix tree, and page pool. The pre-PR-6 behavior
        (dropping the whole pool) killed every in-flight neighbor of one
        poisoned request and restarted the cache cold; now the escaping
        exception still reports the crash, but a retry — or a
        `snapshot()`/`restore()`d successor — resumes warm, and the next
        serve() admits against an intact pool (`alloc.check()` holds)."""
        for s, sl in enumerate(sched.slots):
            if sl.live and s in ctx.seq_of:
                try:
                    self._pool_preempt(sched, alloc, ctx, s)
                except Exception:  # unstructured damage: sweep below
                    ctx.seq_of.pop(s, None)
            elif sl.live:
                sched.preempt(s)  # admitted to the slot but not the pool
        # sequences admitted but never slot-bound (crash mid-admission)
        alloc.reset_live()
        # every slot is dead now: park all table rows on the garbage page
        for s in range(len(sched.slots)):
            ctx.cache = self._set_tbl_row(ctx.cache, s, [])
        self._paged_cache = ctx.cache

    def _pool_fault_slot(self, sched: Scheduler, alloc, ctx: _PoolCtx,
                         s: int) -> None:
        """Fault-retry rollback of a live slot: the same memory motion as
        `_pool_preempt` (donate the valid-KV pages, zero the table row)
        but the requeue is charged against the request's retry budget —
        and goes terminal-FAILED when that budget is out."""
        stream = sched.slots[s].cache_tokens()
        seq = ctx.seq_of.pop(s)
        ctx.inserted.discard(s)
        sched.fault_slot(s)
        if self._cache_on:
            alloc.donate(seq, stream)
        else:
            alloc.free(seq)
        ctx.cache = self._set_tbl_row(ctx.cache, s, [])

    def _copy_pages(self, cache, cows):
        if not cows:
            return cache
        # one jitted gather-scatter for ALL owed copies per leaf, with
        # the pool buffer donated: XLA updates the pages in place
        # instead of rewriting a pool-sized array per CowCopy
        srcs = jnp.asarray([cw.src for cw in cows], jnp.int32)
        dsts = jnp.asarray([cw.dst for cw in cows], jnp.int32)
        return _map_paged(cache, pool=lambda x: _copy_pool_pages(x, srcs, dsts))

    def _pool_retire(self, sched: Scheduler, alloc, ctx: _PoolCtx, s: int) -> None:
        """Retire a finished slot: donate its clean token stream's pages
        to the radix tree (or plain-free them with the cache off) and
        point the dead slot's table row at the garbage page before the
        freed pages can be reassigned."""
        stream = sched.slots[s].cache_tokens()
        seq = ctx.seq_of.pop(s)
        ctx.inserted.discard(s)
        if self._cache_on:
            alloc.donate(seq, stream)
        else:
            alloc.free(seq)
        sched.retire(s)
        ctx.cache = self._set_tbl_row(ctx.cache, s, [])

    def _pool_preempt(self, sched: Scheduler, alloc, ctx: _PoolCtx, s: int) -> None:
        """Victim preemption: donate the slot's pages (a resumed match
        makes recompute-on-resume nearly free — FLASH-D's (O, Λ) carry
        needs no state beyond the cached pages to continue from a page
        boundary) and re-queue the request."""
        stream = sched.slots[s].cache_tokens()
        seq = ctx.seq_of.pop(s)
        ctx.inserted.discard(s)
        sched.preempt(s)
        if self._cache_on:
            alloc.donate(seq, stream)
        else:
            alloc.free(seq)
        ctx.cache = self._set_tbl_row(ctx.cache, s, [])

    def _pool_grow(self, sched: Scheduler, alloc, ctx: _PoolCtx, s: int,
                   want: int) -> bool:
        """Materialize pages so slot `s` can write up to `want` positions,
        preempting victims under page pressure (optimistic per-chunk
        allocation). Returns False when `s` itself was the victim."""
        from repro.runtime.kvcache import PageError

        while True:
            seq = ctx.seq_of[s]
            before = len(alloc.table(seq))
            try:
                cows = alloc.extend(seq, want)
            except PageError:
                v = sched.victim_slot() if self.sc.preemption else None
                if v is None or sched.active_count() == 1:
                    raise
                self._pool_preempt(sched, alloc, ctx, v)
                if v == s:
                    return False
                continue
            ctx.cache = self._copy_pages(ctx.cache, cows)
            if cows or len(alloc.table(seq)) != before:
                ctx.cache = self._set_tbl_row(ctx.cache, s, alloc.table(seq))
            return True

    def _pool_reserve(self, req: Request, max_new_tokens: int,
                      chunk_n: int) -> int:
        """Admission reservation: just the prompt under preemption
        (optimistic per-chunk allocation, DESIGN.md §3.6) or the worst
        case (prompt + remaining new tokens + speculative chunk slack,
        clamped to max_len — writes past it hit the garbage page) without.
        With speculative decoding the slack must also cover a full K+1-row
        verify segment: rejected rows return their pages to the seq's
        reservation (`alloc.rollback`), so the worst case never compounds
        across rounds — one verify's overhang is enough."""
        n = len(req.tokens)
        if self.sc.preemption:
            return n
        slack = max(chunk_n, self._spec.k + 1 if self._spec else 0)
        remaining = max_new_tokens - len(req.out)
        return min(n + remaining + slack, self.sc.max_len)

    def _pool_match(self, alloc, toks: np.ndarray):
        """Radix lookup for an admission, capped so ≥ 1 token prefills."""
        if not self._cache_on:
            return None
        return alloc.match_prefix(toks, max_tokens=len(toks) - 1)

    def _preempting_could_admit(self, sched: Scheduler, alloc, ctx: _PoolCtx,
                                req: Request, reserve: int, cached) -> bool:
        """Upper bound on admission-pressure preemption: even rolling back
        EVERY strictly-lower-priority victim frees at most their table
        pages — if that still cannot cover the arrival, preempting would
        discard running work for nothing, so the head waits instead."""
        from repro.runtime.kvcache import pages_for

        need = pages_for(reserve, alloc.page_size)
        if cached is not None:
            need -= len(cached.pages)
        bound = alloc.free_pages + alloc.evictable_pages
        for s, sl in enumerate(sched.slots):
            if sl.live and sl.priority < req.priority:
                bound += len(alloc.table(ctx.seq_of[s]))
        return need <= bound

    def _note_admission(self, toks, cached) -> None:
        self._stats["prefix_lookups"] += 1
        self._stats["prompt_tokens"] += len(toks)
        if cached is not None and cached.n_tokens > 0:
            self._stats["prefix_hits"] += 1
            self._stats["hit_tokens"] += cached.n_tokens

    # ---- speculative decoding rounds (DESIGN.md §3.9) ----
    def _plan_grown(self, sched: Scheduler, alloc, ctx: _PoolCtx,
                    budget: int, pchunk: int, drafts=None) -> StepPlan:
        """Plan a packed step and materialize its pages; any slot
        rollback — victim preemption, growth-fault requeue, or a retry
        budget running out — invalidates the plan (a dead slot's segment
        must not dispatch), so re-plan until a whole growth pass stays
        stable. `drafts` adds speculative draft rows (plan_step funds
        them from leftover budget only)."""
        while True:
            plan = sched.plan_step(budget, pchunk, drafts=drafts)
            r0 = sched.rollbacks
            for seg in plan.segments:
                end = min(seg.start + len(seg.tokens), self.sc.max_len)
                try:
                    if end > alloc.seq_len(ctx.seq_of[seg.slot]):
                        self._inj("page_alloc", sched.slots[seg.slot].rid)
                        self._pool_grow(sched, alloc, ctx, seg.slot, end)
                except InjectedFault as e:
                    self._note_fault(e)
                    self._pool_fault_slot(sched, alloc, ctx, seg.slot)
                if sched.rollbacks != r0:
                    break
            if sched.rollbacks == r0:
                return plan

    def _spec_round(self, sched: Scheduler, alloc, ctx: _PoolCtx, *,
                    budget: int, pchunk: int, block_q: int) -> List[int]:
        """One speculative serving round: draft-propose K tokens per
        decoding slot, verify them ALL (plus any prefill chunks in
        flight) in ONE packed varlen dispatch, commit the longest
        accepted prefix of each chain, and roll rejected rows' pages back
        through the allocator. One host sync per round, exactly like a
        plain mixed step — acceptance is pure throughput.

        Memory soundness (DESIGN.md §3.9): `commit` leaves each slot's
        `kv` at its accepted length, so `alloc.rollback(seq, kv)` frees
        every page wholly past it — those pages are never donated to the
        radix tree, and every retirement/donation path reads the stream
        truncated to `kv`, so cached bytes stay a pure function of the
        committed token stream (prefix caching and the int8 slot-0 scale
        rule both survive speculation). Stale rejected KV inside the
        boundary page sits at positions ≥ kv_len — masked by every
        kernel, and overwritten by the next round's writes before any row
        can attend to it."""
        from repro.kernels.tuning import bucket_pow2, padded_rows
        from repro.serve.speculative import DraftModel

        spec = self._spec
        K, R = spec.k, spec.k + 1
        b = self.sc.max_batch
        # 1. per-slot draft quota, deadline-clamped (the expire_overdue
        #    bugfix: deadlines are only checked BETWEEN steps, so the
        #    quota shrinks near one instead of overshooting it by K rows)
        quota = {
            s: sched.draft_quota(s, K, max_len=self.sc.max_len,
                                 per_row_s=spec.row_ewma)
            for s, sl in enumerate(sched.slots)
            if sl.live and not sl.prefilling
        }
        # 2. propose
        dev_drafts = None
        drafts: Dict[int, np.ndarray] = {}
        if isinstance(spec.draft, DraftModel):
            spec.draft.sync(sched)
            dev_drafts = spec.draft.propose(sched, K)  # [B, K], on device
            # placeholder rows — the verify jit scatters the device ids
            drafts = {s: np.zeros((q,), np.int32)
                      for s, q in quota.items() if q > 0}
        else:
            for s, q in quota.items():
                if q <= 0:
                    continue
                sl = sched.slots[s]
                stream = np.concatenate([
                    np.asarray(sl.prompt, np.int64),
                    np.asarray(sl.out[sl.resumed:], np.int64),
                ])
                prop = np.asarray(spec.draft(sl.rid, stream, q), np.int32)
                if len(prop):
                    drafts[s] = prop[:q]
        # 3. plan + grow (re-plan on any slot rollback)
        plan = self._plan_grown(sched, alloc, ctx, budget, pchunk,
                                drafts=drafts)
        if not plan.segments:
            return []
        # 4. pack + ONE verify dispatch + ONE sync
        t0 = time.monotonic()
        off, spans = 0, []
        for seg in plan.segments:
            spans.append(off)
            off += padded_rows(len(seg.tokens), block_q)
        total = bucket_pow2(max(off, 1), lo=block_q)
        tokens = np.zeros((total,), np.int32)
        seq_ids = np.full((total,), -1, np.int32)
        positions = np.full((total,), -1, np.int32)
        kv_len = np.zeros((b,), np.int32)
        rows = np.full((b, R), -1, np.int32)
        dmat = np.full((b, K), -1, np.int32)
        for seg, o in zip(plan.segments, spans):
            n = len(seg.tokens)
            tokens[o:o + n] = seg.tokens
            seq_ids[o:o + n] = seg.slot
            positions[o:o + n] = np.arange(seg.start, seg.start + n)
            kv_len[seg.slot] = seg.start + n
            if not seg.emits:
                continue
            if sched.slots[seg.slot].prefilling:
                rows[seg.slot, 0] = o + n - 1  # prefill-final: last row only
            else:
                rows[seg.slot, :n] = np.arange(o, o + n)
                dmat[seg.slot, :n - 1] = seg.tokens[1:]
        draft_arg = (
            jnp.where(jnp.asarray(dmat) >= 0, dev_drafts, -1)
            if dev_drafts is not None else jnp.asarray(dmat)
        )
        self._inj("kernel_dispatch")
        cache2, out = self._verify(
            self.params, ctx.cache,
            jnp.asarray(tokens), jnp.asarray(seq_ids),
            jnp.asarray(positions), jnp.asarray(kv_len),
            jnp.asarray(rows), draft_arg, block_q,
        )
        self._inj("device_step")
        out_np = self._sync(out)  # one sync per speculative round
        # commit the device cache only past the sync: a step fault above
        # discards the round entirely, so its retry is exact
        ctx.cache = cache2
        g, n_acc = out_np[:, :R], out_np[:, R]
        # 5. commit the accepted prefixes, then roll the allocator back
        #    past every rejected row (freed, never donated)
        finished = sched.commit(plan, g, n_acc=n_acc)
        if isinstance(spec.draft, DraftModel):
            spec.draft.committed(sched)
        for seg in plan.segments:
            sl = sched.slots[seg.slot]
            if not sl.live or seg.slot not in ctx.seq_of:
                continue
            seq = ctx.seq_of[seg.slot]
            if alloc.seq_len(seq) > sl.kv:
                alloc.rollback(seq, sl.kv)
        per_row = (time.monotonic() - t0) / max(plan.n_tokens, 1)
        spec.row_ewma = (per_row if spec.row_ewma is None
                         else 0.7 * spec.row_ewma + 0.3 * per_row)
        return finished

    # ---- paged continuous batching (DESIGN.md §3.4 + §3.6) ----
    def _serve_paged(self, requests, max_new_tokens: int,
                     priorities=None, deadlines=None) -> List[np.ndarray]:
        """Sequential continuous batching over a page-pool KV cache.

        Differences from the contiguous loop:

          * admission is by FREE PAGES, not slot count: with preemption, a
            request is admitted as soon as its PROMPT fits (growth is
            optimistic and backed by victim preemption); without, the
            worst case is reserved up front and a blocked head waits for
            frees. Priority order is respected either way, and a
            higher-priority arrival may preempt a lower-priority victim;
          * prompts walk the radix prefix cache: matched full pages are
            aliased into the block table and only the tail is prefilled
            (`start_pos`), so a warm shared prefix costs O(new tokens);
          * before every chunk the allocator materializes pages covering
            the chunk's writes (preempting under pressure) and the engine
            mirrors grown block tables to the device; finished slots
            donate their pages to the cache and point their table row at
            the garbage page, so lockstep speculative writes from dead
            slots stay harmless.
        """
        from repro.runtime.kvcache import PageError, pages_for

        lay = self._page_layout
        page = lay.page_size
        b = self.sc.max_batch
        sched = self._make_sched(requests, max_new_tokens, priorities,
                                 deadlines)
        alloc, cache0 = self._paged_state()
        ctx = _PoolCtx(cache0)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))
        spec_block_q = spec_budget = 0
        if self._spec is not None:
            from repro.kernels.tuning import bucket_pow2, choose_varlen_blocks

            spec_budget = b * (self._spec.k + 1)
            spec_block_q = choose_varlen_blocks(
                bucket_pow2(spec_budget, lo=8),
                self.mc.head_dim_, self.mc.head_dim_,
                group=self.mc.n_heads // self.mc.n_kv_heads, page=page,
                segment_hint=self._spec.k + 1,
            ).block_q

        def assign(slot: int) -> bool:
            """Admit the highest-priority queued request into `slot` if
            the pool can cover it (evicting cached pages, then preempting
            strictly-lower-priority victims, as needed). Returns False
            (and leaves the queue intact) when it cannot — the request
            waits. Head-of-line order within the priority order is
            preserved: later requests never jump a blocked head."""
            nonlocal tok, pos
            while (req := sched.head()) is not None:
                toks = req.tokens
                n = len(toks)
                self._check_len(req.rid, len(req.prompt), max_new_tokens)
                reserve = self._pool_reserve(req, max_new_tokens, chunk_n)
                cached = self._pool_match(alloc, toks)
                if not alloc.can_admit(reserve, cached=cached):
                    if self.sc.preemption and self._preempting_could_admit(
                        sched, alloc, ctx, req, reserve, cached
                    ) and (
                        v := sched.victim_slot(below=req.priority)
                    ) is not None:
                        self._pool_preempt(sched, alloc, ctx, v)
                        continue  # re-match: donation may extend the prefix
                    if sched.has_active():
                        return False  # live sequences will free pages
                    raise PageError(
                        f"request {req.rid} needs {pages_for(reserve, page)}"
                        f" pages but the pool holds {lay.n_pages - 1}"
                    )
                sched.take_head()
                try:
                    self._inj("page_alloc", req.rid)
                except InjectedFault as e:
                    # fault isolation: only the request in hand rolls back
                    self._note_fault(e)
                    sched.retry_request(req)
                    continue
                seq = self._seq_base
                self._seq_base += 1
                alloc.admit(seq, prompt_len=n, reserve_tokens=reserve,
                            cached=cached)
                start = cached.n_tokens if cached is not None else 0
                ctx.cache = self._set_tbl_row(ctx.cache, slot, alloc.table(seq))
                try:
                    self._inj("kernel_dispatch", req.rid)
                    # tail-only prefill: cached pages already hold [0, start)
                    view = _map_paged(
                        ctx.cache, batch=lambda x: x[:, slot:slot + 1]
                    )
                    logits, view = self._prefill_bucketed(
                        toks, view, start_pos=start
                    )
                    self._inj("device_step", req.rid)
                    ctx.cache = _map_paged(
                        ctx.cache, view,
                        pool=lambda x, o: o,  # updated pool (slot's pages only)
                        batch=lambda x, o: x.at[:, slot].set(o[:, 0]),
                    )
                    self._key, k = jax.random.split(self._key)
                    t0 = int(self._sync(
                        sample_token(logits, k, self.sc), rid=req.rid
                    )[0])
                except InjectedFault as e:
                    # the faulted prefill may have left garbage KV in the
                    # pages — FREE them (donating would poison the radix
                    # tree's content addressing), zero the row, retry
                    self._note_fault(e)
                    alloc.free(seq)
                    ctx.cache = self._set_tbl_row(ctx.cache, slot, [])
                    sched.retry_request(req)
                    continue
                self._clear_fault_streak()
                self._note_admission(toks, cached)
                if not sched.admit_request(slot, req, t0):
                    # finished on its first token: its pages already hold
                    # the whole prompt's KV — donate them
                    if self._cache_on:
                        alloc.donate(seq, toks)
                    else:
                        alloc.free(seq)
                    ctx.cache = self._set_tbl_row(ctx.cache, slot, [])
                    continue
                ctx.seq_of[slot] = seq
                if self._cache_on:  # index the live prompt (its KV is valid now)
                    alloc.insert(seq, toks)
                    ctx.inserted.add(slot)
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(n)
                return True
            return False

        def refill():
            for s in range(b):
                if not sched.slots[s].live and sched.head() is not None:
                    if not assign(s):
                        break
            if not self.sc.preemption:
                return
            # a higher-priority arrival may evict a lower-priority victim
            while (req := sched.head()) is not None and sched.free_slot() is None:
                v = sched.victim_slot(below=req.priority)
                if v is None:
                    return
                self._pool_preempt(sched, alloc, ctx, v)
                if not assign(v):
                    return

        try:
            refill()
            self.peak_active = sched.note_peak()
            while sched.has_active() or sched.queue:
                for s in sched.expire_overdue():
                    self._pool_retire(sched, alloc, ctx, s)
                if not sched.has_active():
                    if not self._await_backoff(sched):
                        break
                    refill()
                    self.peak_active = sched.note_peak()
                    continue
                if self._spec is not None:
                    # speculative round replaces the per-token chunk loop:
                    # growth happens inside _plan_grown, per-slot
                    self._watchdog.start_step()
                    try:
                        finished = self._spec_round(
                            sched, alloc, ctx, budget=spec_budget,
                            pchunk=1, block_q=spec_block_q,
                        )
                    except InjectedFault as e:
                        self._on_step_fault(
                            sched, e,
                            lambda v: self._pool_fault_slot(
                                sched, alloc, ctx, v
                            ),
                        )
                        continue
                    self._watchdog.end_step(self._bump_step())
                    self._clear_fault_streak()
                    for s in finished:
                        self._pool_retire(sched, alloc, ctx, s)
                    refill()
                    self.peak_active = sched.note_peak()
                    continue
                # materialize pages for this chunk's writes (clamped to
                # max_len: the table is ⌈max_len/page⌉ wide and writes past
                # it clamp to the garbage page in _paged_attn_step). A
                # growth fault is rid-scoped: only that slot rolls back.
                for s in range(b):
                    sl = sched.slots[s]
                    if not sl.live:
                        continue
                    try:
                        self._inj("page_alloc", sl.rid)
                        self._pool_grow(
                            sched, alloc, ctx, s,
                            min(sl.kv + chunk_n, self.sc.max_len),
                        )
                    except InjectedFault as e:
                        self._note_fault(e)
                        self._pool_fault_slot(sched, alloc, ctx, s)
                if not sched.has_active():
                    continue
                self._watchdog.start_step()
                self._key, k = jax.random.split(self._key)
                try:
                    self._inj("kernel_dispatch")
                    cache2, tok2, pos2, toks = self._chunk(
                        self.params, ctx.cache, tok, pos, k, chunk_n
                    )
                    self._inj("device_step")
                    toks_np = self._sync(toks)  # one sync per chunk
                except InjectedFault as e:
                    # discard the uncommitted step and retry it — exact,
                    # because committed host state never advanced
                    self._on_step_fault(
                        sched, e,
                        lambda v: self._pool_fault_slot(sched, alloc, ctx, v),
                    )
                    continue
                self._watchdog.end_step(self._bump_step())
                self._clear_fault_streak()
                ctx.cache, tok, pos = cache2, tok2, pos2
                for s in sched.absorb_chunk(toks_np):
                    self._pool_retire(sched, alloc, ctx, s)
                refill()
                self.peak_active = sched.note_peak()
        except Exception:
            self._recover_paged(sched, alloc, ctx)
            raise
        self._paged_cache = ctx.cache
        self._finish_serve(sched)
        return sched.results_list()

    # ---- mixed varlen continuous batching (DESIGN.md §3.5 + §3.6) ----
    def _serve_mixed(self, requests, max_new_tokens: int,
                     priorities=None, deadlines=None) -> List[np.ndarray]:
        """Chunked-prefill continuous batching: ONE jitted packed varlen
        step per iteration, carrying every decoding slot's pending token
        and the next prefill chunks of admitted prompts.

        vs. the sequential loops: a newly admitted long prompt no longer
        runs a whole-prompt prefill dispatch that stalls every decoding
        sequence — its prompt drips in `prefill_chunk`-token pieces
        interleaved with decode rows, so time-to-first-token of everything
        behind it drops (BENCH_serve.json tracks this). Iterations with NO
        prefill in flight take the decode fast path instead: the same
        jitted `decode_chunk`-token loop as the sequential engines (one
        dispatch + one sync per chunk, not per token), so steady-state
        decode throughput is the sequential engine's — the packed step
        only pays its per-step sync while it is actually buying prefill
        interleaving. Admission is by free pages like `_serve_paged`, and
        the radix prefix cache applies here too: chunked prefill starts at
        the first UNCACHED token (`fed0`), so a warm shared prefix skips
        its chunks entirely."""
        from repro.kernels.tuning import bucket_pow2, choose_varlen_blocks
        from repro.runtime.kvcache import PageError, pages_for

        lay = self._page_layout
        page = lay.page_size
        b = self.sc.max_batch
        sched = self._make_sched(requests, max_new_tokens, priorities,
                                 deadlines)
        alloc, cache0 = self._paged_state()
        ctx = _PoolCtx(cache0)
        budget = self.sc.token_budget or (b + self.sc.prefill_chunk)
        if self._spec is not None and not self.sc.token_budget:
            # default budget must fund every slot's K+1-row verify chain
            # on top of a prefill chunk, or drafts would never be planned
            budget = b * (self._spec.k + 1) + self.sc.prefill_chunk
        pchunk = max(1, min(self.sc.prefill_chunk, budget))
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))
        hd = self.mc.head_dim_
        # segment hint: with >1 slot the pack mixes 1-token decode rows
        # into every prefill step, and each pads to block_q — keep the
        # tile at the sublane minimum; a lone slot packs one prefill
        # chunk per step, so the chunk itself is the segment. With
        # speculation on, decode segments are (K+1)-row verify chains.
        seg_hint = (self._spec.k + 1 if self._spec is not None
                    else (1 if b > 1 else pchunk))
        block_q = choose_varlen_blocks(
            bucket_pow2(budget, lo=8), hd, hd,
            group=self.mc.n_heads // self.mc.n_kv_heads, page=page,
            segment_hint=seg_hint,
        ).block_q

        def try_admit():
            while (req := sched.head()) is not None:
                slot = sched.free_slot()
                if slot is None:
                    if self.sc.preemption and (
                        v := sched.victim_slot(below=req.priority)
                    ) is not None:
                        self._pool_preempt(sched, alloc, ctx, v)
                        slot = v
                    else:
                        return
                toks = req.tokens
                n = len(toks)
                self._check_len(req.rid, len(req.prompt), max_new_tokens)
                reserve = self._pool_reserve(req, max_new_tokens, chunk_n)
                cached = self._pool_match(alloc, toks)
                if not alloc.can_admit(reserve, cached=cached):
                    if self.sc.preemption and self._preempting_could_admit(
                        sched, alloc, ctx, req, reserve, cached
                    ) and (
                        v := sched.victim_slot(below=req.priority)
                    ) is not None:
                        self._pool_preempt(sched, alloc, ctx, v)
                        continue  # re-match: donation may extend the prefix
                    if sched.has_active():
                        return  # live sequences will free pages
                    raise PageError(
                        f"request {req.rid} needs {pages_for(reserve, page)}"
                        f" pages but the pool holds {lay.n_pages - 1}"
                    )
                sched.take_head()
                try:
                    self._inj("page_alloc", req.rid)
                except InjectedFault as e:
                    # fault isolation: only the request in hand rolls back
                    self._note_fault(e)
                    sched.retry_request(req)
                    continue
                seq = self._seq_base
                self._seq_base += 1
                alloc.admit(seq, prompt_len=n, reserve_tokens=reserve,
                            cached=cached)
                self._note_admission(toks, cached)
                fed0 = cached.n_tokens if cached is not None else 0
                ctx.cache = self._set_tbl_row(ctx.cache, slot, alloc.table(seq))
                sched.admit_request_prefilling(slot, req, fed0=fed0)
                ctx.seq_of[slot] = seq

        def note_prefilled():
            """Index prompts whose prefill just completed (their pages
            hold valid KV from here on) so concurrent admissions match."""
            if not self._cache_on:
                return
            for s, sl in enumerate(sched.slots):
                if sl.live and not sl.prefilling and s not in ctx.inserted:
                    alloc.insert(ctx.seq_of[s], sl.prompt)
                    ctx.inserted.add(s)

        def dispatch(plan: StepPlan) -> np.ndarray:
            """Pack the plan into flat block_q-aligned arrays (bucketed to
            a power of two) and run the jitted mixed step."""
            off = 0
            spans = []
            for seg in plan.segments:
                spans.append(off)
                off += -(-len(seg.tokens) // block_q) * block_q
            total = bucket_pow2(max(off, 1), lo=block_q)
            tokens = np.zeros((total,), np.int32)
            seq_ids = np.full((total,), -1, np.int32)
            positions = np.full((total,), -1, np.int32)
            kv_len = np.zeros((b,), np.int32)
            last_rows = np.full((b,), -1, np.int32)
            for seg, o in zip(plan.segments, spans):
                n = len(seg.tokens)
                tokens[o:o + n] = seg.tokens
                seq_ids[o:o + n] = seg.slot
                positions[o:o + n] = np.arange(seg.start, seg.start + n)
                kv_len[seg.slot] = seg.start + n
                if seg.emits:
                    last_rows[seg.slot] = o + n - 1
            self._key, k = jax.random.split(self._key)
            self._inj("kernel_dispatch")
            cache2, toks = self._mixed(
                self.params, ctx.cache,
                jnp.asarray(tokens), jnp.asarray(seq_ids),
                jnp.asarray(positions), jnp.asarray(kv_len),
                jnp.asarray(last_rows), k, block_q,
            )
            self._inj("device_step")
            toks_np = self._sync(toks)  # one sync per mixed step
            # commit the device cache only past the sync: a step fault
            # above discards the step entirely, so its retry is exact
            ctx.cache = cache2
            return toks_np

        def decode_chunk_phase():
            """No prefill in flight: the sequential engines' jitted
            multi-token decode loop (one dispatch + one sync per
            `decode_chunk` tokens). Device tok/pos are rebuilt from the
            scheduler's host state, so packed steps and chunk phases
            interleave freely; dead slots carry zeroed table rows, so
            their lockstep writes land on the garbage page. Returns None
            when growth faults emptied the batch (nothing to step).
            Growth faults are rid-scoped (only that slot rolls back);
            dispatch/sync faults are step-wide and propagate to the
            caller's retry handler."""
            for s in range(b):
                sl = sched.slots[s]
                if not sl.live:
                    continue
                try:
                    self._inj("page_alloc", sl.rid)
                    self._pool_grow(sched, alloc, ctx, s,
                                    min(sl.kv + chunk_n, self.sc.max_len))
                except InjectedFault as e:
                    self._note_fault(e)
                    self._pool_fault_slot(sched, alloc, ctx, s)
            if not sched.has_active():
                return None
            tok = jnp.asarray([sl.pending for sl in sched.slots], jnp.int32)
            pos = jnp.asarray([sl.kv for sl in sched.slots], jnp.int32)
            self._key, k = jax.random.split(self._key)
            self._inj("kernel_dispatch")
            cache2, _, _, toks = self._chunk(
                self.params, ctx.cache, tok, pos, k, chunk_n
            )
            self._inj("device_step")
            toks_np = self._sync(toks)  # one sync per chunk
            ctx.cache = cache2  # commit past the sync (see dispatch)
            return toks_np

        try:
            try_admit()
            self.peak_active = sched.note_peak()
            while sched.has_active() or sched.queue:
                for s in sched.expire_overdue():
                    self._pool_retire(sched, alloc, ctx, s)
                if not sched.has_active():
                    if not self._await_backoff(sched):
                        break
                    try_admit()
                    self.peak_active = sched.note_peak()
                    continue
                self._watchdog.start_step()
                try:
                    if self._spec is not None:
                        # speculative rounds subsume both phases: verify
                        # chains AND prefill chunks ride one packed step
                        finished = self._spec_round(
                            sched, alloc, ctx, budget=budget,
                            pchunk=pchunk, block_q=block_q,
                        )
                    elif not any(sl.prefilling for sl in sched.slots):
                        toks_np = decode_chunk_phase()
                        finished = (sched.absorb_chunk(toks_np)
                                    if toks_np is not None else [])
                    else:
                        plan = self._plan_grown(sched, alloc, ctx,
                                                budget, pchunk)
                        finished = (sched.commit(plan, dispatch(plan))
                                    if plan.segments else [])
                except InjectedFault as e:
                    # discard the uncommitted step and retry it — exact,
                    # because committed host state never advanced
                    self._on_step_fault(
                        sched, e,
                        lambda v: self._pool_fault_slot(sched, alloc, ctx, v),
                    )
                    continue
                self._watchdog.end_step(self._bump_step())
                self._clear_fault_streak()
                note_prefilled()
                for s in finished:
                    self._pool_retire(sched, alloc, ctx, s)
                try_admit()
                self.peak_active = sched.note_peak()
        except Exception:
            self._recover_paged(sched, alloc, ctx)
            raise
        self._paged_cache = ctx.cache
        self._finish_serve(sched)
        return sched.results_list()

    # ---- crash recovery: serve-state snapshot / restore (DESIGN.md §3.7) ----
    def snapshot(self, ckpt_dir: str, *, step: int = 0) -> str:
        """Serialize the last serve() call's surviving state as a
        metadata-only checkpoint (runtime/checkpoint.py, `tree=None`): the
        scheduler's unfinished requests (live slots fold in exactly like a
        preemption — prompt + tokens generated so far), finished results
        and statuses, the radix cache's content as token chains, and the
        pool geometry. No KV arrays are saved: FLASH-D's (O, Λ) carry
        makes KV a pure function of the token stream, so `restore()`
        recomputes it exactly. Call after a crash (serve() folds live
        slots into the queue before re-raising) or between serves."""
        from repro.runtime import checkpoint as ckpt

        sched = self._sched
        if sched is None:
            raise RuntimeError("snapshot() before any serve()")
        now = sched.now()
        pending = [
            Request(rid=sl.rid, prompt=np.asarray(sl.orig_prompt),
                    out=list(sl.out), priority=sl.priority,
                    deadline=sl.deadline, retries=sl.retries)
            for sl in sched.slots if sl.live
        ] + list(sched.queue)
        state = {
            "pending": sorted((
                {"rid": int(r.rid),
                 "prompt": np.asarray(r.prompt).astype(int).tolist(),
                 "out": [int(t) for t in r.out],
                 "priority": int(r.priority),
                 # deadlines persist as REMAINING seconds: the restored
                 # scheduler's clock starts at zero
                 "deadline": (max(0.0, float(r.deadline) - now)
                              if r.deadline is not None else None),
                 # retry-backoff gates rebase the same way — a snapshot
                 # taken mid-backoff restores with the REMAINING backoff,
                 # not a stale absolute clock value
                 "not_before": max(0.0, float(r.not_before) - now),
                 "retries": int(r.retries)}
                for r in pending), key=lambda d: d["rid"]),
            "done": {str(i): np.asarray(r).astype(int).tolist()
                     for i, r in enumerate(sched.results) if r is not None},
            "status": {str(k): v for k, v in sched.status.items()},
            "max_new_tokens": int(sched.max_new_tokens),
            "seq_base": int(self._seq_base),
            "chains": (self._alloc.cached_chains()
                       if self._alloc is not None and self._cache_on else []),
            "pool": ({"page_size": self._page_layout.page_size,
                      "n_pages": self._page_layout.n_pages}
                     if self._page_layout is not None else None),
        }
        return ckpt.save(ckpt_dir, step, None, extra={"engine_serve": state})

    def restore(self, ckpt_dir: str, *, step: Optional[int] = None) -> dict:
        """Load a `snapshot()` into THIS engine (typically a fresh one
        after a crash): stashes the pending requests for `resume()` and
        re-warms the radix prefix cache by replaying the snapshot's token
        chains through prefill — recompute, not array restore, so the
        rebuilt pages are exact. Chains are only replayed onto a matching
        pool geometry (same page_size). Returns the raw state dict."""
        from repro.runtime import checkpoint as ckpt

        _, extra = ckpt.restore(ckpt_dir, None, step=step)
        state = extra["engine_serve"]
        self._seq_base = max(self._seq_base, int(state.get("seq_base", 0)))
        pool = state.get("pool")
        chains = state.get("chains") or []
        if (chains and self._cache_on and self._page_layout is not None
                and pool is not None
                and int(pool["page_size"]) == self._page_layout.page_size):
            with self._scope():
                self._rewarm(chains)
        self._resume_state = state
        return state

    def resume(self) -> Dict[int, np.ndarray]:
        """Finish the restored snapshot's pending requests (one serve()
        call, deadlines/retry budgets carried over) and return ALL results
        keyed by the ORIGINAL request ids — already-finished requests come
        straight from the snapshot. Token-identical to the uninterrupted
        run: resumed requests re-enter through recompute-on-resume."""
        state = self._resume_state
        if state is None:
            raise RuntimeError("resume() before restore()")
        self._resume_state = None
        done = {int(k): np.asarray(v, np.int32)
                for k, v in state["done"].items()}
        pending = state["pending"]
        if pending:
            reqs = [Request(rid=i, prompt=np.asarray(p["prompt"], np.int32),
                            out=list(p["out"]), priority=int(p["priority"]),
                            deadline=p["deadline"], retries=int(p["retries"]),
                            not_before=float(p.get("not_before", 0.0)))
                    for i, p in enumerate(pending)]
            outs = self.serve(reqs, int(state["max_new_tokens"]),
                              deadlines=[p["deadline"] for p in pending])
            for p, o in zip(pending, outs):
                done[int(p["rid"])] = o
        return done

    def _rewarm(self, chains: List[List[int]]) -> None:
        """Replay radix-tree token chains into the (fresh) page pool:
        admit a scratch sequence over batch slot 0, prefill the chain's
        full pages, donate them back to the tree. Longest chains first so
        shorter ones ride their cached prefixes; chains that no longer fit
        (smaller pool) are skipped — the cache is a performance artifact,
        not correctness state."""
        alloc, cache = self._paged_state()
        page = self._page_layout.page_size
        cap = (self.sc.max_len // page) * page
        for chain in sorted(chains, key=len, reverse=True):
            toks = np.asarray(chain[:cap], np.int32)
            n = (len(toks) // page) * page
            if n < page:
                continue
            toks = toks[:n]
            m = alloc.match_prefix(toks)
            if m.n_tokens >= n:
                continue  # covered by a longer chain's replay
            cached = m if m.n_tokens > 0 else None
            if not alloc.can_admit(n, cached=cached):
                continue
            seq = self._seq_base
            self._seq_base += 1
            alloc.admit(seq, prompt_len=n, reserve_tokens=n, cached=cached)
            start = cached.n_tokens if cached is not None else 0
            cache = self._set_tbl_row(cache, 0, alloc.table(seq))
            view = _map_paged(cache, batch=lambda x: x[:, 0:1])
            _, view = self._prefill_bucketed(toks, view, start_pos=start)
            cache = _map_paged(
                cache, view,
                pool=lambda x, o: o,
                batch=lambda x, o: x.at[:, 0].set(o[:, 0]),
            )
            alloc.donate(seq, toks)
            cache = self._set_tbl_row(cache, 0, [])
        self._paged_cache = cache
