"""Batched serving engine: continuous batching over contiguous, paged, or
packed-varlen KV memory.

Requests join a slot array; finished slots are refilled from a FIFO queue.
Slot lifecycle (queue, per-slot outputs, EOS/max-token completion, refill,
peak-concurrency accounting) lives in `repro.serve.scheduler.Scheduler` —
shared by every path below; this module owns memory admission and device
dispatch only. Sampling: greedy / temperature / top-k.

Three serving modes (ServeConfig.kv_layout × ServeConfig.step_mode):

  contiguous (default) — each slot owns a fixed max_len-wide cache region;
    memory commits max_batch × max_len tokens up front.
  paged (DESIGN.md §3.4) — KV lives in a global page pool with
    per-sequence block tables (runtime/kvcache.py); admission is by FREE
    PAGES, prompts sharing a page-aligned prefix with a live sequence
    reuse its pages (CoW boundary copy) and prefill only the tail, and
    decode runs the block-table scalar-prefetch kernel under `*_pallas`.
  mixed (step_mode="mixed", DESIGN.md §3.5) — chunked-prefill continuous
    batching over the paged pool: every step packs each decoding slot's
    one pending token TOGETHER WITH the next prefill chunks of admitted
    prompts into one flat varlen batch and dispatches ONE jitted
    `forward_packed` step — prefill and decode are the same kernel
    (`kernels/flashd_varlen`), so a long prompt no longer stalls decoding
    sequences for a whole-prompt prefill dispatch. Iterations with no
    prefill in flight use the sequential chunked decode fast path, so
    steady-state decode costs what the paged engine's does. Requires a
    pure global-attention stack (`transformer.packed_mixers_ok`); other
    stacks fall back to the sequential paged/contiguous loops.

Static-shape bucketing (DESIGN.md §3.5): prompt lengths and packed-batch
sizes are padded to powers of two (`tuning.bucket_pow2`) before they reach
a jitted program — prefills run with per-row `lengths` masking
(`prefill_lm`), packs carry −1 padding rows — so `serve()` compiles
O(log max_len) programs instead of one per distinct length (pinned by
tests/test_scheduler.py).

The decode hot loop is fully on-device (DESIGN.md §3.3): `generate` is one
jitted prefill + `lax.scan` (exactly ONE device→host sync, counted in
`self.host_syncs`); the sequential `serve` loops decode in jitted
`decode_chunk`-token chunks (one sync per chunk); the mixed loop syncs
once per packed step.

Sharded serving: pass a `repro.distributed.sharding.ShardingCtx` and the
engine activates it (plus the ambient mesh) around every trace/dispatch;
seq-sharded KV caches route decode through the cross-device FLASH-D merge
(`repro.distributed.context.cp_decode`, DESIGN.md §4.1).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.models.transformer import forward_packed, packed_mixers_ok, prefill_lm
from repro.serve.scheduler import Scheduler, StepPlan

__all__ = ["ServeConfig", "Engine", "sample_token"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0
    eos_id: int = -1  # <0: run to max_new_tokens
    seed: int = 0
    decode_chunk: int = 8  # tokens per device dispatch in sequential `serve`
    # ---- paged KV cache (DESIGN.md §3.4) ----
    kv_layout: str = "contiguous"  # "paged": page-pool KV in `serve`
    page_size: int = 0  # 0 → repro.kernels.tuning heuristic
    kv_pool_tokens: int = 0  # pool size in tokens; 0 → max_batch·max_len
    prefix_sharing: bool = True  # share common prompt-prefix pages (CoW)
    # ---- mixed varlen step (DESIGN.md §3.5) ----
    step_mode: str = "sequential"  # "mixed": chunked-prefill packed steps
    token_budget: int = 0  # packed tokens per mixed step; 0 → heuristic
    prefill_chunk: int = 16  # max prompt tokens one sequence feeds per step


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_pages(pages, srcs, dsts):
    """pages[:, d] ← pages[:, s] for every owed CoW copy, in one update.
    The pool is donated so backends that support donation do it in place
    (O(pages copied), not O(pool))."""
    return pages.at[:, dsts].set(pages[:, srcs])


def _map_paged(cache, *rest, pool=None, tbl=None, batch=None):
    """Tree-map over a (possibly paged) cache with per-leaf-kind functions.

    Leaf kinds by dict key: `k_pages`/`v_pages` are POOL leaves (global
    page arrays, no batch axis — [n_blocks, P, page, Hkv, hd]); everything
    else — including the block table `tbl` — is a PER-BATCH leaf (batch on
    axis 1 after block stacking). `tbl=` overrides the per-batch handler
    for table leaves (engine table mirroring); a missing handler leaves the
    leaf unchanged. Extra cache trees in `rest` are zipped leaf-wise."""
    from jax import tree_util as jtu

    def leaf_name(path):
        for e in reversed(path):
            if isinstance(e, jtu.DictKey):
                return e.key
        return None

    def apply(path, x, *xs):
        name = leaf_name(path)
        if name in ("k_pages", "v_pages"):
            fn = pool
        elif name == "tbl":
            fn = tbl if tbl is not None else batch
        else:
            fn = batch
        return x if fn is None else fn(x, *xs)

    return jtu.tree_map_with_path(apply, cache, *rest)


def sample_token(logits: jax.Array, key, cfg: ServeConfig) -> jax.Array:
    """logits [B, V] → token [B]."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Engine:
    def __init__(self, params, model_cfg: ModelConfig, serve_cfg: ServeConfig,
                 *, sharding_ctx=None):
        self.params = params
        self.mc = model_cfg
        self.sc = serve_cfg
        self.ctx = sharding_ctx  # Optional[repro.distributed.sharding.ShardingCtx]
        self.api = get_model(model_cfg)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, c, t, pos, model_cfg)
        )
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self.host_syncs = 0  # device→host transfers issued by this engine
        self.peak_active = 0  # max concurrent sequences observed by `serve`
        self.ttft = {}  # rid → time-to-first-token of the last serve() call
        self._gen = jax.jit(self._gen_fn, static_argnums=(5,))
        self._chunk = jax.jit(self._chunk_fn, static_argnums=(5,))
        # bucketed prefill: one program per power-of-two prompt bucket;
        # start_pos rides as a traced scalar so shared-prefix tails of any
        # length reuse the same program
        self._prefill = jax.jit(
            lambda p, t, c, sp, ln: prefill_lm(
                p, t, c, self.mc, start_pos=sp, lengths=ln
            )
        )
        self._mixed = jax.jit(self._mixed_fn, static_argnums=(8,))
        self._page_layout = None
        if serve_cfg.kv_layout == "paged" or serve_cfg.step_mode == "mixed":
            from repro.kernels.tuning import choose_page_layout  # lazy
            from repro.models.transformer import paged_mixers

            if getattr(model_cfg, "is_encdec", False) or not paged_mixers(model_cfg):
                # no global-attention layer to page (pure SSM/ring stacks,
                # enc-dec) — serve falls back to the contiguous layout
                pass
            else:
                self._page_layout = choose_page_layout(
                    serve_cfg.max_len,
                    model_cfg.head_dim_,
                    model_cfg.head_dim_,
                    group=model_cfg.n_heads // model_cfg.n_kv_heads,
                    pool_tokens=serve_cfg.kv_pool_tokens
                    or serve_cfg.max_batch * serve_cfg.max_len,
                    page_size=serve_cfg.page_size or None,
                )
        # the mixed varlen step runs every layer on flat packed tokens
        # through the paged pool — global-attention-only stacks
        self._mixed_ok = (
            serve_cfg.step_mode == "mixed"
            and self._page_layout is not None
            and packed_mixers_ok(model_cfg)
        )
        # prefix sharing skips the shared positions' prefill steps, which is
        # only sound when EVERY mixer reads the paged cache: ring
        # (local/chunked) and SSM/RG-LRU layers carry state those steps
        # would have produced (see prefill_lm's start_pos contract)
        self._can_share_prefix = (
            self._page_layout is not None
            and serve_cfg.prefix_sharing
            and all(
                m in ("attn", "attn_nope", "attn_bidir")
                for m, _ in (*model_cfg.pattern, *model_cfg.remainder)
            )
        )

    def _scope(self):
        """Sharding scope for traces/dispatches: activates the ctx and the
        ambient mesh so logical constraints (and context-parallel routing)
        resolve inside the jitted loops. No-op without a sharding_ctx."""
        if self.ctx is None:
            return contextlib.nullcontext()
        from repro.distributed import sharding as shd  # lazy: optional dep

        stack = contextlib.ExitStack()
        stack.enter_context(shd.activate(self.ctx))
        mctx = shd.mesh_ctx(self.ctx.mesh)
        if hasattr(mctx, "__enter__"):
            stack.enter_context(mctx)
        return stack

    def _to_host(self, x) -> np.ndarray:
        """The engine's ONLY device→host sync point (counted for tests)."""
        self.host_syncs += 1
        return np.asarray(x)

    def _bucket(self, n: int) -> int:
        from repro.kernels.tuning import bucket_pow2  # lazy: no cycle

        return bucket_pow2(n, lo=8, hi=self.sc.max_len)

    # ---- jitted device loops ----
    def _gen_fn(self, params, prompts, cache, key, real_len, max_new_tokens: int):
        """Prefill + full decode loop as one device program → tokens [B, T].

        `prompts` may be padded past the real prompt to a power-of-two
        bucket; `real_len` (traced i32 scalar) is the shared true length —
        prefill_lm masks the padding steps, so the bucket only decides
        which compiled program runs, never the result.

        Early-EOS masking: once a sequence has emitted eos_id, subsequent
        positions emit eos_id (the decode steps still run — a lax.scan has
        static trip count — but their tokens are masked in the output)."""
        b, _ = prompts.shape
        logits, cache = prefill_lm(
            params, prompts, cache, self.mc,
            lengths=jnp.full((b,), real_len, jnp.int32),
        )
        pos0 = jnp.full((b,), real_len, jnp.int32)
        done0 = jnp.zeros((b,), bool)
        eos = self.sc.eos_id

        def body(carry, k_i):
            logits, cache, pos, done = carry
            tok = sample_token(logits, k_i, self.sc)
            if eos >= 0:
                emit = jnp.where(done, jnp.int32(eos), tok)
                done = jnp.logical_or(done, tok == eos)
            else:
                emit = tok
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            return (logits, cache, pos + 1, done), emit

        keys = jax.random.split(key, max_new_tokens)
        _, toks = jax.lax.scan(body, (logits, cache, pos0, done0), keys)
        return toks.T  # [B, T]

    def _chunk_fn(self, params, cache, tok, pos, key, n: int):
        """`n` decode+sample steps as one device program (continuous batching)."""

        def body(carry, k_i):
            cache, tok, pos = carry
            logits, cache = self.api.decode_step(params, cache, tok, pos, self.mc)
            nxt = sample_token(logits, k_i, self.sc)
            return (cache, nxt, pos + 1), nxt

        keys = jax.random.split(key, n)
        (cache, tok, pos), toks = jax.lax.scan(body, (cache, tok, pos), keys)
        return cache, tok, pos, toks  # toks [n, B]

    def _mixed_fn(self, params, cache, tokens, seq_ids, positions, kv_len,
                  last_rows, key, block_q: int):
        """ONE mixed prefill/decode step (DESIGN.md §3.5): the packed
        varlen forward over the whole stack + sampling at each emitting
        sequence's last row. Retraces only per packed-length bucket.
        `block_q` is the packer's alignment granularity (static)."""
        logits, cache = forward_packed(
            params, tokens, seq_ids, positions, kv_len, cache, self.mc,
            last_rows, block_q=block_q,
        )
        return cache, sample_token(logits, key, self.sc)

    # ---- single-prompt-batch generation (prefill + n decode steps) ----
    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts [B, S_prompt] int32 (right-aligned, no padding support in
        this minimal path) → generated tokens [B, max_new_tokens].

        Both the prompt length and the decode-step count are bucketed to
        powers of two (excess steps run masked, excess output is sliced
        off), so repeated calls at drifting lengths reuse O(log max_len)
        compiled programs."""
        b, s = prompts.shape
        if s + max_new_tokens > self.sc.max_len:
            raise ValueError(
                f"prompt {s} + {max_new_tokens} exceeds max_len {self.sc.max_len}"
            )
        from repro.kernels.tuning import bucket_pow2  # lazy: no cycle

        sb = self._bucket(s)
        nb = bucket_pow2(max_new_tokens, lo=1)
        padded = np.zeros((b, sb), np.int32)
        padded[:, :s] = prompts
        with self._scope():
            cache = self.api.init_cache(b, self.sc.max_len, self.mc)
            self._key, k = jax.random.split(self._key)
            toks = self._gen(
                self.params, jnp.asarray(padded), cache, k, jnp.int32(s),
                int(nb),
            )
        return self._to_host(toks)[:, :max_new_tokens]

    # ---- continuous batching over a request queue ----
    def serve(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Each request: 1-D prompt array. Returns generated arrays, in order.

        Routing: `step_mode="mixed"` (and a packed-capable stack) runs the
        chunked-prefill mixed varlen loop; otherwise the paged or
        contiguous sequential loop. All three share the Scheduler's slot
        lifecycle and are token-identical under greedy sampling."""
        with self._scope():
            if self._mixed_ok:
                return self._serve_mixed(requests, max_new_tokens)
            # fall back along the CONFIGURED memory model: a mixed request
            # on a non-packed-capable stack must not silently switch an
            # explicitly contiguous engine onto the page pool
            if self._page_layout is not None and self.sc.kv_layout == "paged":
                return self._serve_paged(requests, max_new_tokens)
            return self._serve_impl(requests, max_new_tokens)

    def _check_len(self, rid: int, n_prompt: int, max_new_tokens: int) -> None:
        if n_prompt + max_new_tokens > self.sc.max_len:
            raise ValueError(
                f"request {rid}: prompt {n_prompt} + {max_new_tokens}"
                f" exceeds max_len {self.sc.max_len}"
            )

    def _set_tbl_row(self, cache, slot: int, table: List[int]):
        """Mirror one slot's allocator block table into every layer's
        device `tbl` leaf (zero-padded: unmapped logical pages point at
        the garbage page). Shared by the paged and mixed loops."""
        row = np.zeros((self._page_layout.pages_per_seq,), np.int32)
        row[: len(table)] = table
        row_j = jnp.asarray(row)
        return _map_paged(cache, tbl=lambda x: x.at[:, slot].set(row_j[None]))

    def _prefill_bucketed(self, prompt: np.ndarray, cache, *, start_pos: int = 0):
        """Prefill `prompt[start_pos:]` into a batch-1 cache view with the
        token axis padded to a power-of-two bucket (prefill_lm masks the
        padding rows), so distinct prompt lengths share compiled programs."""
        tail = np.asarray(prompt[start_pos:])
        n = len(tail)
        nb = self._bucket(n)
        padded = np.zeros((1, nb), np.int32)
        padded[0, :n] = tail
        return self._prefill(
            self.params, jnp.asarray(padded), cache,
            jnp.int32(start_pos), jnp.asarray([n], jnp.int32),
        )

    def _serve_impl(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        b = self.sc.max_batch
        sched = Scheduler(requests, max_new_tokens, b, self.sc.eos_id)
        cache = self.api.init_cache(b, self.sc.max_len, self.mc)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))

        def _write_slot(c, o, slot):
            # caches are stacked [n_blocks, batch, ...]: batch is axis 1
            return c.at[:, slot].set(o[:, 0])

        def assign(slot: int):
            """Prefill the next queued request into `slot`. The prefill's
            sampled token is output token 0 (same as `generate`); requests
            that complete immediately are finalized and the next is taken."""
            nonlocal cache, tok, pos
            while (head := sched.take_head()) is not None:
                rid, prompt = head
                self._check_len(rid, len(prompt), max_new_tokens)
                one_cache = self.api.init_cache(1, self.sc.max_len, self.mc)
                logits, one_cache = self._prefill_bucketed(prompt, one_cache)
                self._key, k = jax.random.split(self._key)
                t0 = int(self._to_host(sample_token(logits, k, self.sc))[0])
                if not sched.admit_or_finish(slot, rid, prompt, t0):
                    continue
                cache = jax.tree.map(
                    lambda c, o: _write_slot(c, o, slot), cache, one_cache
                )
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(len(prompt))
                return

        for s in range(b):
            assign(s)

        self.peak_active = sched.note_peak()
        while sched.has_active():
            self._key, k = jax.random.split(self._key)
            cache, tok, pos, toks = self._chunk(
                self.params, cache, tok, pos, k, chunk_n
            )
            toks_np = self._to_host(toks)  # one sync per chunk
            for s in sched.absorb_chunk(toks_np):
                sched.retire(s)
                assign(s)  # refill overwrites the slot's cache / tok / pos
            self.peak_active = sched.note_peak()
        self.ttft = dict(sched.first_token_at)
        return sched.results_list()

    # ---- paged continuous batching (DESIGN.md §3.4) ----
    def _serve_paged(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Sequential continuous batching over a page-pool KV cache.

        Differences from the contiguous loop:

          * admission is by FREE PAGES, not slot count: a request is
            admitted when the pool can cover its worst case
            (prompt + max_new_tokens + one decode chunk of speculative
            slack, minus shared prefix pages); a blocked head-of-line
            request waits for frees, so short sequences pack the pool far
            denser than `max_batch × max_len` slots would;
          * prompts sharing a page-aligned-or-longer prefix with a live
            sequence reuse its KV pages (full pages by reference, the
            boundary page as a CoW copy) and prefill only the tail;
          * before every chunk the allocator materializes pages covering
            the chunk's writes and the engine mirrors grown block tables
            to the device; finished slots free their pages and point
            their table row at the garbage page, so lockstep speculative
            writes from dead slots stay harmless.
        """
        from repro.runtime.kvcache import PagedKVAllocator, PageError, pages_for

        lay = self._page_layout
        page = lay.page_size
        b = self.sc.max_batch
        sched = Scheduler(requests, max_new_tokens, b, self.sc.eos_id)
        alloc = PagedKVAllocator(lay.n_pages, page)
        cache = self.api.init_cache(
            b, self.sc.max_len, self.mc,
            layout="paged", page_size=page, n_pages=lay.n_pages,
        )
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))

        def best_prefix(prompt: np.ndarray):
            """Longest common prompt prefix with a live sequence — the
            prefix-sharing candidate. Worth taking only when it covers at
            least one full page (a shorter match saves nothing: the
            boundary CoW copy costs the same page a fresh alloc would)."""
            if not self._can_share_prefix:
                return -1, 0
            best_s, best_n = -1, 0
            for s, sl in enumerate(sched.slots):
                if not sl.live or sl.prompt is None:
                    continue
                other = sl.prompt
                m = min(len(prompt), len(other))
                n = int(np.argmin(np.equal(prompt[:m], other[:m]))) \
                    if not np.array_equal(prompt[:m], other[:m]) else m
                if n > best_n:
                    best_s, best_n = s, n
            best_n = min(best_n, len(prompt) - 1)  # the tail must run ≥ 1 token
            if best_n < page:
                return -1, 0
            return best_s, best_n

        def copy_pages(c, cows):
            if not cows:
                return c
            # one jitted gather-scatter for ALL owed copies per leaf, with
            # the pool buffer donated: XLA updates the pages in place
            # instead of rewriting a pool-sized array per CowCopy
            srcs = jnp.asarray([cw.src for cw in cows], jnp.int32)
            dsts = jnp.asarray([cw.dst for cw in cows], jnp.int32)
            return _map_paged(c, pool=lambda x: _copy_pool_pages(x, srcs, dsts))

        def assign(slot: int) -> bool:
            """Admit the head-of-line request into `slot` if the pool can
            cover it. Returns False (and leaves the queue intact) when it
            cannot — the request waits for pages to free. FIFO order is
            preserved: later requests never jump a blocked head."""
            nonlocal cache, tok, pos
            while (head := sched.head()) is not None:
                rid, prompt = head
                n_prompt = len(prompt)
                self._check_len(rid, n_prompt, max_new_tokens)
                # speculative post-EOS chunk steps need slack, but tables
                # are only ⌈max_len/page⌉ wide — writes past max_len land
                # on the garbage page instead (the in-table clamp), so the
                # reservation never needs to exceed max_len
                reserve = min(n_prompt + max_new_tokens + chunk_n,
                              self.sc.max_len)
                parent_slot, shared = best_prefix(np.asarray(prompt))
                if not alloc.can_admit(reserve, shared_tokens=shared):
                    # sharing never costs more pages than an unshared admit,
                    # so there is no cheaper retry — wait for frees
                    if sched.has_active():
                        return False  # live sequences will free pages
                    raise PageError(
                        f"request {rid} needs {pages_for(reserve, page)} pages"
                        f" but the pool holds {lay.n_pages - 1}"
                    )
                sched.take_head()
                cows = alloc.admit(
                    rid, prompt_len=n_prompt, reserve_tokens=reserve,
                    share_from=(
                        sched.slots[parent_slot].rid if parent_slot >= 0 else None
                    ),
                    shared_tokens=shared,
                )
                cache = copy_pages(cache, cows)
                cache = self._set_tbl_row(cache, slot, alloc.table(rid))
                # tail-only prefill: shared pages already hold [0, shared)
                view = _map_paged(
                    cache, batch=lambda x: x[:, slot:slot + 1]
                )
                logits, view = self._prefill_bucketed(
                    np.asarray(prompt), view, start_pos=shared
                )
                cache = _map_paged(
                    cache, view,
                    pool=lambda x, o: o,  # updated pool (slot's pages only)
                    batch=lambda x, o: x.at[:, slot].set(o[:, 0]),
                )
                self._key, k = jax.random.split(self._key)
                t0 = int(self._to_host(sample_token(logits, k, self.sc))[0])
                if not sched.admit_or_finish(slot, rid, prompt, t0):
                    alloc.free(rid)
                    cache = self._set_tbl_row(cache, slot, [])
                    continue
                tok = tok.at[slot].set(t0)
                pos = pos.at[slot].set(n_prompt)
                return True
            return False

        for s in range(b):
            assign(s)

        self.peak_active = sched.note_peak()
        while sched.has_active():
            # materialize pages for this chunk's writes; mirror grown tables
            for s, sl in enumerate(sched.slots):
                if not sl.live:
                    continue
                before = len(alloc.table(sl.rid))
                # clamp to max_len: table width is ⌈max_len/page⌉ and writes
                # past it clamp to the garbage page in _paged_attn_step
                cows = alloc.extend(
                    sl.rid, min(sl.kv + chunk_n, self.sc.max_len)
                )
                cache = copy_pages(cache, cows)
                if cows or len(alloc.table(sl.rid)) != before:
                    cache = self._set_tbl_row(cache, s, alloc.table(sl.rid))
            self._key, k = jax.random.split(self._key)
            cache, tok, pos, toks = self._chunk(
                self.params, cache, tok, pos, k, chunk_n
            )
            toks_np = self._to_host(toks)  # one sync per chunk
            finished = sched.absorb_chunk(toks_np)
            for s in finished:
                alloc.free(sched.retire(s))
                # the freed pages may be reassigned immediately — point the
                # dead slot's table at the garbage page before that happens
                cache = self._set_tbl_row(cache, s, [])
            for s, sl in enumerate(sched.slots):  # refill what the pool admits
                if not sl.live and sched.head() is not None:
                    if not assign(s):
                        break
            self.peak_active = sched.note_peak()
        self.ttft = dict(sched.first_token_at)
        return sched.results_list()

    # ---- mixed varlen continuous batching (DESIGN.md §3.5) ----
    def _serve_mixed(self, requests: List[np.ndarray], max_new_tokens: int) -> List[np.ndarray]:
        """Chunked-prefill continuous batching: ONE jitted packed varlen
        step per iteration, carrying every decoding slot's pending token
        and the next prefill chunks of admitted prompts.

        vs. the sequential loops: a newly admitted long prompt no longer
        runs a whole-prompt prefill dispatch that stalls every decoding
        sequence — its prompt drips in `prefill_chunk`-token pieces
        interleaved with decode rows, so time-to-first-token of everything
        behind it drops (BENCH_serve.json tracks this). Iterations with NO
        prefill in flight take the decode fast path instead: the same
        jitted `decode_chunk`-token loop as the sequential engines (one
        dispatch + one sync per chunk, not per token), so steady-state
        decode throughput is the sequential engine's — the packed step
        only pays its per-step sync while it is actually buying prefill
        interleaving. Admission is by free pages like `_serve_paged` (no
        prefix sharing here: chunks already amortize prefill, and the
        packer stays simple)."""
        from repro.kernels.tuning import bucket_pow2, choose_varlen_blocks
        from repro.runtime.kvcache import PagedKVAllocator, PageError, pages_for

        lay = self._page_layout
        page = lay.page_size
        b = self.sc.max_batch
        sched = Scheduler(requests, max_new_tokens, b, self.sc.eos_id)
        alloc = PagedKVAllocator(lay.n_pages, page)
        cache = self.api.init_cache(
            b, self.sc.max_len, self.mc,
            layout="paged", page_size=page, n_pages=lay.n_pages,
        )
        budget = self.sc.token_budget or (b + self.sc.prefill_chunk)
        pchunk = max(1, min(self.sc.prefill_chunk, budget))
        chunk_n = max(1, min(self.sc.decode_chunk, max_new_tokens))
        hd = self.mc.head_dim_
        # segment hint: with >1 slot the pack mixes 1-token decode rows
        # into every prefill step, and each pads to block_q — keep the
        # tile at the sublane minimum; a lone slot packs one prefill
        # chunk per step, so the chunk itself is the segment
        block_q = choose_varlen_blocks(
            bucket_pow2(budget, lo=8), hd, hd,
            group=self.mc.n_heads // self.mc.n_kv_heads, page=page,
            segment_hint=1 if b > 1 else pchunk,
        ).block_q

        def try_admit():
            nonlocal cache
            while (slot := sched.free_slot()) is not None and sched.head():
                rid, prompt = sched.head()
                n_prompt = len(prompt)
                self._check_len(rid, n_prompt, max_new_tokens)
                # chunk_n slack: decode-only phases run `decode_chunk`
                # lockstep steps whose post-EOS tail writes speculatively,
                # exactly like _serve_paged (clamped to max_len — the
                # in-table garbage-page clamp absorbs the rest)
                reserve = min(n_prompt + max_new_tokens + chunk_n,
                              self.sc.max_len)
                if not alloc.can_admit(reserve):
                    if sched.has_active():
                        return  # live sequences will free pages
                    raise PageError(
                        f"request {rid} needs {pages_for(reserve, page)} pages"
                        f" but the pool holds {lay.n_pages - 1}"
                    )
                sched.take_head()
                alloc.admit(rid, prompt_len=n_prompt, reserve_tokens=reserve)
                cache = self._set_tbl_row(cache, slot, alloc.table(rid))
                sched.admit_prefilling(slot, rid, prompt)

        def dispatch(plan: StepPlan) -> np.ndarray:
            """Pack the plan into flat block_q-aligned arrays (bucketed to
            a power of two) and run the jitted mixed step."""
            nonlocal cache
            off = 0
            spans = []
            for seg in plan.segments:
                spans.append(off)
                off += -(-len(seg.tokens) // block_q) * block_q
            total = bucket_pow2(max(off, 1), lo=block_q)
            tokens = np.zeros((total,), np.int32)
            seq_ids = np.full((total,), -1, np.int32)
            positions = np.full((total,), -1, np.int32)
            kv_len = np.zeros((b,), np.int32)
            last_rows = np.full((b,), -1, np.int32)
            for seg, o in zip(plan.segments, spans):
                n = len(seg.tokens)
                tokens[o:o + n] = seg.tokens
                seq_ids[o:o + n] = seg.slot
                positions[o:o + n] = np.arange(seg.start, seg.start + n)
                kv_len[seg.slot] = seg.start + n
                if seg.emits:
                    last_rows[seg.slot] = o + n - 1
            self._key, k = jax.random.split(self._key)
            cache, toks = self._mixed(
                self.params, cache,
                jnp.asarray(tokens), jnp.asarray(seq_ids),
                jnp.asarray(positions), jnp.asarray(kv_len),
                jnp.asarray(last_rows), k, block_q,
            )
            return self._to_host(toks)  # one sync per mixed step

        def decode_chunk_phase():
            """No prefill in flight: the sequential engines' jitted
            multi-token decode loop (one dispatch + one sync per
            `decode_chunk` tokens). Device tok/pos are rebuilt from the
            scheduler's host state, so packed steps and chunk phases
            interleave freely; dead slots carry zeroed table rows, so
            their lockstep writes land on the garbage page."""
            nonlocal cache
            for s, sl in enumerate(sched.slots):
                if not sl.live:
                    continue
                before = len(alloc.table(sl.rid))
                alloc.extend(sl.rid, min(sl.kv + chunk_n, self.sc.max_len))
                if len(alloc.table(sl.rid)) != before:
                    cache = self._set_tbl_row(cache, s, alloc.table(sl.rid))
            tok = jnp.asarray([sl.pending for sl in sched.slots], jnp.int32)
            pos = jnp.asarray([sl.kv for sl in sched.slots], jnp.int32)
            self._key, k = jax.random.split(self._key)
            cache, _, _, toks = self._chunk(
                self.params, cache, tok, pos, k, chunk_n
            )
            return self._to_host(toks)  # one sync per chunk

        try_admit()
        self.peak_active = sched.note_peak()
        while sched.has_active():
            if not any(sl.prefilling for sl in sched.slots):
                finished = sched.absorb_chunk(decode_chunk_phase())
            else:
                plan = sched.plan_step(budget, pchunk)
                # materialize pages for the step's writes; mirror tables
                for seg in plan.segments:
                    rid = sched.slots[seg.slot].rid
                    before = len(alloc.table(rid))
                    end = min(seg.start + len(seg.tokens), self.sc.max_len)
                    if end > alloc.seq_len(rid):
                        alloc.extend(rid, end)  # no sharing → never CoWs
                    if len(alloc.table(rid)) != before:
                        cache = self._set_tbl_row(cache, seg.slot, alloc.table(rid))
                finished = sched.commit(plan, dispatch(plan))
            for s in finished:
                alloc.free(sched.retire(s))
                cache = self._set_tbl_row(cache, s, [])
            try_admit()
            self.peak_active = sched.note_peak()
        self.ttft = dict(sched.first_token_at)
        return sched.results_list()
