"""Speculative decoding draft proposers (DESIGN.md §3.9).

The engine verifies K proposed tokens per target step through ONE packed
varlen dispatch (`Engine._verify_fn`): each speculation is a packed
segment with explicit per-row `q_pos`, which the FLASH-D varlen kernel
already supports — a draft chain is just a mid-sequence chunk. This
module owns the OTHER half of the loop: where the K proposals come from.

Two proposer kinds, selected by what `Engine(draft=...)` receives:

  * `DraftModel` — a small model (e.g. `configs/qwen3_0_6b.py`) with its
    own CONTIGUOUS KV cache, one slot per engine slot. Proposals are K
    greedy decode steps under one jitted `lax.scan` and STAY ON DEVICE:
    the engine scatters them into the verify pack inside the jitted step,
    so a speculative round still costs exactly one host sync. The draft
    cache needs no rollback machinery: positions past a slot's committed
    length are simply stale (never read — the decode mask stops at the
    tracked position), and accepted drafts ARE the committed tokens, so
    after a round the draft KV below `min(kv, old + K)` is already
    correct; `sync()` re-feeds whatever tail is missing and fully
    re-prefills on slot reuse (rid change) or after a preemption rewind.

  * any callable `fn(rid, tokens, k) -> np.ndarray` — a host-side
    proposer fed the request's full visible stream (effective prompt +
    every generated token, the last being the pending one). `OracleDraft`
    is the benchmark/test instance: it proposes the known reference
    continuation with a seeded per-token corruption rate, giving an
    exactly controlled acceptance rate — greedy verify output is
    token-identical at ANY accuracy, so benches can sweep acceptance
    without training a real draft model.

Either way the proposals are only ever *hints*: the target model's greedy
argmax at every verify row decides what commits, so serving output is
token-identical to non-speculative greedy decoding by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.models.transformer import prefill_lm

__all__ = ["DraftModel", "OracleDraft", "SpecState"]


@dataclasses.dataclass
class SpecState:
    """Engine-side speculative-decoding state: the draft proposer, the
    static draft length K, and the measured per-verify-row wall time
    (EWMA) that feeds the scheduler's deadline clamp (`draft_quota`)."""

    k: int
    draft: object  # DraftModel | callable(rid, tokens, k) -> np.ndarray
    row_ewma: Optional[float] = None


class DraftModel:
    """Draft proposer backed by a small model with a contiguous KV cache.

    Per-slot host state: `pos[s]` — how many leading positions of slot
    `s`'s draft cache hold KV for the target's committed stream — and
    `rid[s]`, the request the cache content belongs to. The protocol per
    speculative round is `sync()` (catch every decoding slot's draft KV
    up to the target's committed length), `propose()` (K greedy steps,
    tokens stay on device), then after the engine commits the verify
    results, `committed()` (advance `pos` past the accepted prefix —
    those positions were written by `propose` with exactly the tokens
    that committed)."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int,
                 max_len: int):
        self.params = params
        self.cfg = cfg
        self.api = get_model(cfg)
        self.b = max_batch
        self.max_len = max_len
        self.cache = self.api.init_cache(max_batch, max_len, cfg)
        self.pos = np.zeros(max_batch, np.int64)  # committed-valid KV length
        self.rid = np.full(max_batch, -1, np.int64)  # cache content owner
        self._last_k = 0
        self._prefill = jax.jit(
            lambda p, t, c, sp, ln: prefill_lm(
                p, t, c, self.cfg, start_pos=sp, lengths=ln
            )
        )
        self._propose_j = jax.jit(self._propose_fn, static_argnums=(4,))

    def _propose_fn(self, params, cache, tok, pos, k: int):
        """K greedy decode steps as one device program → drafts [B, K]."""

        def body(carry, _):
            cache, tok, pos = carry
            logits, cache = self.api.decode_step(
                params, cache, tok, pos, self.cfg
            )
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (cache, nxt, pos + 1), nxt

        (cache, _, _), toks = jax.lax.scan(
            body, (cache, tok, pos), None, length=k
        )
        return cache, toks.T  # [B, K]

    def _write_slot(self, one_cache, slot: int) -> None:
        # contiguous caches are stacked [n_blocks, batch, ...]: batch axis 1
        self.cache = jax.tree.map(
            lambda c, o: c.at[:, slot].set(o[:, 0]), self.cache, one_cache
        )

    def sync(self, sched) -> None:
        """Bring every decoding slot's draft KV up to the target's
        committed length. A slot serving a new request (or rewound past
        the draft's valid length by a preemption resume) re-prefills its
        whole committed stream; otherwise only the missing tail is fed
        (`prefill_lm(start_pos=...)`). Device work only — no host sync."""
        from repro.kernels.tuning import bucket_pow2  # lazy: no cycle

        for s, sl in enumerate(sched.slots):
            if not sl.live or sl.prefilling:
                continue
            fresh = self.rid[s] != sl.rid or self.pos[s] > sl.kv
            start = 0 if fresh else int(self.pos[s])
            if start == sl.kv:
                self.rid[s] = sl.rid
                continue
            stream = sl.cache_tokens()  # token ids at positions [0, kv)
            n = len(stream)
            nb = bucket_pow2(max(n - start, 1), lo=8, hi=self.max_len)
            padded = np.zeros((1, nb), np.int32)
            padded[0, : n - start] = stream[start:]
            view = (
                self.api.init_cache(1, self.max_len, self.cfg)
                if fresh
                else jax.tree.map(lambda c: c[:, s : s + 1], self.cache)
            )
            _, view = self._prefill(
                self.params, jnp.asarray(padded), view,
                jnp.int32(start), jnp.asarray([n - start], jnp.int32),
            )
            self._write_slot(view, s)
            self.pos[s] = n
            self.rid[s] = sl.rid

    def propose(self, sched, k: int) -> jax.Array:
        """Greedy-propose `k` tokens for every decoding slot from its
        pending token at its committed position. Returns a DEVICE [B, k]
        array — the engine's verify step scatters it into the pack, so
        draft tokens never round-trip through the host. Dead/prefilling
        slots run masked garbage steps (their writes land at positions a
        future occupant re-prefills over before ever reading)."""
        tok = np.zeros((self.b,), np.int32)
        pos = np.zeros((self.b,), np.int32)
        for s, sl in enumerate(sched.slots):
            if sl.live and not sl.prefilling:
                tok[s] = sl.pending
                pos[s] = sl.kv
        self.cache, drafts = self._propose_j(
            self.params, self.cache, jnp.asarray(tok), jnp.asarray(pos), int(k)
        )
        self._last_k = int(k)
        return drafts

    def committed(self, sched) -> None:
        """Advance each synced slot's valid length past the round's
        accepted prefix: `propose` wrote draft KV at positions
        [old, old + K), and the accepted drafts ARE the committed tokens,
        so positions below min(kv, old + K) already hold correct KV. The
        bonus token's position (kv when a full K chain accepts) was never
        fed to the draft — `sync` feeds that tail next round."""
        for s, sl in enumerate(sched.slots):
            if sl.live and not sl.prefilling and self.rid[s] == sl.rid:
                self.pos[s] = min(sl.kv, int(self.pos[s]) + self._last_k)


class OracleDraft:
    """Host-callable proposer with an exactly controlled acceptance rate.

    Proposes the known reference continuation of each request (the
    non-speculative greedy output, computed once by the caller),
    corrupting each token independently with probability `1 - accuracy`
    to a guaranteed-wrong id (seeded). Acceptance then tracks `accuracy`
    directly, and greedy verify output stays token-identical at any
    setting — the harness for BENCH_spec.json's acceptance sweep and the
    rollback-heavy property tests."""

    def __init__(self, prompts: Sequence[np.ndarray],
                 refs: Sequence[np.ndarray], vocab_size: int, *,
                 accuracy: float = 1.0, seed: int = 0):
        self.plen = {i: len(p) for i, p in enumerate(prompts)}
        self.refs = {i: np.asarray(r, np.int64) for i, r in enumerate(refs)}
        self.vocab = int(vocab_size)
        self.accuracy = float(accuracy)
        self.seed = int(seed)

    def __call__(self, rid: int, tokens: np.ndarray, k: int) -> np.ndarray:
        done = len(tokens) - self.plen[rid]  # output tokens emitted so far
        ref = self.refs[rid]
        prop = np.array(ref[done : done + k], np.int64)
        if self.accuracy < 1.0 and len(prop):
            # corruption is a pure function of (seed, rid, position): a
            # re-proposal after rejection or preemption corrupts the same
            # positions the same way, and a warm-up serve leaves the
            # acceptance pattern of the next serve unchanged (benches
            # time the SECOND run — it must replay the first exactly)
            rng = np.random.default_rng((self.seed, rid, done))
            flip = rng.random(len(prop)) >= self.accuracy
            junk = rng.integers(0, self.vocab, len(prop))
            junk = np.where(junk == prop, (junk + 1) % self.vocab, junk)
            prop = np.where(flip, junk, prop)
        return prop.astype(np.int32)
