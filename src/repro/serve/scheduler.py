"""Continuous-batching scheduler: slot lifecycle + token-budget step plans.

The host-side state machine shared by EVERY serve path (DESIGN.md §3.5).
The engine's three loops — contiguous chunked decode, paged chunked decode,
and the mixed varlen step — used to each carry their own copy of the same
bookkeeping (request queue, per-slot output accumulation, EOS / max-token
completion, FIFO refill, peak-concurrency tracking). That now lives here
exactly once; the engine keeps only what actually differs per path: how
memory is admitted (slot width vs free pages) and what gets dispatched.

Two consumption styles:

  * chunked (`absorb_chunk`) — the sequential engines decode
    `decode_chunk` tokens per dispatch in slot lockstep; the scheduler
    walks the [chunk, n_slots] token block, appends per slot until its
    completion condition fires (later tokens in the chunk are speculative
    garbage, exactly the old engines' convention) and reports finished
    slots for refill.

  * mixed (`plan_step` / `commit`) — chunked-prefill continuous batching:
    each step packs every DECODING slot's one pending token (decode slots
    are planned first and the budget floor is the decoding-slot count, so
    decode can never starve behind a long prompt) plus up to
    `token_budget` remaining tokens of PREFILLING slots' prompts in FIFO
    order, split into `prefill_chunk`-sized pieces. A segment whose chunk
    consumes the last prompt token emits that sequence's first sampled
    token; decode segments emit always; mid-prompt segments emit nothing.
    `commit` applies the sampled tokens and returns finished slots.

FIFO is preserved throughout: admission is strictly head-of-line (the
caller asks for `head()` and either admits it or waits — later requests
never jump a blocked head), and prefill budget is granted in request-id
order.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Scheduler", "Segment", "StepPlan", "Slot"]


@dataclasses.dataclass
class Slot:
    """One batch slot's host-side state."""

    rid: int = -1  # request id (−1 = free)
    prompt: Optional[np.ndarray] = None
    out: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0  # prompt tokens consumed by prefill chunks (mixed path)
    kv: int = 0  # KV positions materialized in the cache
    pending: int = 0  # next decode input token (mixed path)

    @property
    def live(self) -> bool:
        return self.rid >= 0

    @property
    def prefilling(self) -> bool:
        return self.live and self.prompt is not None and self.fed < len(self.prompt)


@dataclasses.dataclass(frozen=True)
class Segment:
    """One slot's contribution to a mixed step's packed batch."""

    slot: int
    tokens: np.ndarray  # token ids fed this step
    start: int  # absolute KV position of tokens[0]
    emits: bool  # does this segment's last row get sampled?


@dataclasses.dataclass(frozen=True)
class StepPlan:
    segments: Tuple[Segment, ...]
    n_tokens: int  # Σ len(seg.tokens) — the test-pinned budget accounting


class Scheduler:
    def __init__(self, requests: Sequence[np.ndarray], max_new_tokens: int,
                 n_slots: int, eos_id: int):
        self.results: List[Optional[np.ndarray]] = [None] * len(requests)
        self.queue: List[Tuple[int, np.ndarray]] = list(enumerate(requests))
        self.slots = [Slot() for _ in range(n_slots)]
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.peak_active = 0
        # time-to-first-token per request, seconds since construction —
        # the serving-latency signal BENCH_serve.json tracks
        self.first_token_at: Dict[int, float] = {}
        self._t0 = time.monotonic()

    def _mark_first_token(self, rid: int) -> None:
        if rid not in self.first_token_at:
            self.first_token_at[rid] = time.monotonic() - self._t0

    # ---- queue / admission (FIFO: head-of-line only) ----
    def head(self) -> Optional[Tuple[int, np.ndarray]]:
        return self.queue[0] if self.queue else None

    def take_head(self) -> Optional[Tuple[int, np.ndarray]]:
        return self.queue.pop(0) if self.queue else None

    def free_slot(self) -> Optional[int]:
        for s, slot in enumerate(self.slots):
            if not slot.live:
                return s
        return None

    def active_count(self) -> int:
        return sum(slot.live for slot in self.slots)

    def has_active(self) -> bool:
        return any(slot.live for slot in self.slots)

    def note_peak(self) -> int:
        self.peak_active = max(self.peak_active, self.active_count())
        return self.peak_active

    # ---- completion ----
    def _done(self, out: List[int]) -> bool:
        return len(out) >= self.max_new_tokens or (
            self.eos_id >= 0 and out[-1] == self.eos_id
        )

    def finish(self, rid: int, out: List[int]) -> None:
        self.results[rid] = np.asarray(out, np.int32)

    def admit_or_finish(self, slot: int, rid: int, prompt: np.ndarray,
                        first_token: int) -> bool:
        """Sequential-path admission: the prompt is already prefilled and
        its first token sampled. Requests that complete immediately
        (max_new_tokens ≤ 1 or instant EOS) are finalized without taking
        the slot; returns True when the slot was taken."""
        self._mark_first_token(rid)
        if self._done([first_token]):
            self.finish(rid, [first_token])
            return False
        sl = self.slots[slot]
        sl.rid, sl.prompt, sl.out = rid, np.asarray(prompt), [first_token]
        sl.fed = sl.kv = len(prompt)
        sl.pending = first_token
        return True

    def admit_prefilling(self, slot: int, rid: int, prompt: np.ndarray) -> None:
        """Mixed-path admission: the prompt will be fed in chunks."""
        sl = self.slots[slot]
        sl.rid, sl.prompt, sl.out = rid, np.asarray(prompt), []
        sl.fed = sl.kv = 0
        sl.pending = 0

    def retire(self, slot: int) -> int:
        """Free a slot (results must already be recorded); returns its rid."""
        rid = self.slots[slot].rid
        self.slots[slot] = Slot()
        return rid

    # ---- chunked consumption (contiguous + paged sequential loops) ----
    def absorb_chunk(self, toks_np: np.ndarray) -> List[int]:
        """Walk a [chunk, n_slots] sampled-token block in slot lockstep;
        tokens after a slot's completion are speculative garbage and are
        discarded. Records finished results and returns finished slots
        (NOT yet retired — the engine frees memory first)."""
        finished: List[int] = []
        for s, sl in enumerate(self.slots):
            if not sl.live:
                continue
            for step in range(toks_np.shape[0]):
                t = int(toks_np[step, s])
                sl.out.append(t)
                sl.kv += 1
                sl.pending = t  # next decode input if a packed step follows
                if self._done(sl.out):
                    self.finish(sl.rid, sl.out)
                    finished.append(s)
                    break
        return finished

    # ---- mixed-step planning (chunked-prefill continuous batching) ----
    def plan_step(self, token_budget: int, prefill_chunk: int) -> StepPlan:
        """One mixed step's packed work list.

        Decode slots first — every decoding slot contributes its pending
        token, and the effective budget is floored at that count, so a
        wall of prefill can never starve decode. Remaining budget goes to
        prefilling slots' next prompt chunks in request-id (FIFO) order.
        """
        segs: List[Segment] = []
        decoding = [
            s for s, sl in enumerate(self.slots)
            if sl.live and not sl.prefilling
        ]
        budget = max(int(token_budget), len(decoding))
        for s in decoding:
            sl = self.slots[s]
            segs.append(Segment(
                slot=s, tokens=np.asarray([sl.pending], np.int32),
                start=sl.kv, emits=True,
            ))
            budget -= 1
        prefilling = sorted(
            (s for s, sl in enumerate(self.slots) if sl.prefilling),
            key=lambda s: self.slots[s].rid,
        )
        for s in prefilling:
            if budget <= 0:
                break
            sl = self.slots[s]
            # ≥ 1: budget > 0 here, prefill_chunk ≥ 1, and a prefilling
            # slot always has unfed prompt left
            n = min(prefill_chunk, len(sl.prompt) - sl.fed, budget)
            segs.append(Segment(
                slot=s,
                tokens=np.asarray(sl.prompt[sl.fed:sl.fed + n], np.int32),
                start=sl.fed,
                emits=sl.fed + n == len(sl.prompt),
            ))
            budget -= n
        return StepPlan(
            segments=tuple(segs), n_tokens=sum(len(g.tokens) for g in segs)
        )

    def commit(self, plan: StepPlan, sampled: np.ndarray) -> List[int]:
        """Apply one mixed step's sampled tokens ([n_slots], garbage at
        non-emitting slots). Returns finished slots (engine retires them
        after freeing their memory)."""
        finished: List[int] = []
        for seg in plan.segments:
            sl = self.slots[seg.slot]
            n = len(seg.tokens)
            sl.kv += n
            if sl.prefilling:
                sl.fed += n
            if not seg.emits:
                continue
            t = int(sampled[seg.slot])
            sl.out.append(t)
            sl.pending = t
            if len(sl.out) == 1:
                self._mark_first_token(sl.rid)
            if self._done(sl.out):
                self.finish(sl.rid, sl.out)
                finished.append(seg.slot)
        return finished

    # ---- results ----
    def results_list(self) -> List[np.ndarray]:
        return [
            r if r is not None else np.zeros((0,), np.int32)
            for r in self.results
        ]
