"""Continuous-batching scheduler: slot lifecycle, priority classes,
preemption, and token-budget step plans.

The host-side state machine shared by EVERY serve path (DESIGN.md §3.5,
§3.6). The engine's three loops — contiguous chunked decode, paged chunked
decode, and the mixed varlen step — used to each carry their own copy of
the same bookkeeping (request queue, per-slot output accumulation, EOS /
max-token completion, refill, peak-concurrency tracking). That now lives
here exactly once; the engine keeps only what actually differs per path:
how memory is admitted (slot width vs free pages) and what gets
dispatched.

Priority + preemption (DESIGN.md §3.6):

  * every request carries a priority class (higher value = more urgent;
    default 0 for all = pure FIFO). `head()` returns the highest-priority
    queued request, FIFO (arrival order) within a class — admission is
    still strictly head-of-line *per the priority order*: later requests
    never jump an equal-or-higher-priority blocked head.
  * `victim_slot()` implements victim selection: the lowest-priority live
    slot, decoding slots before prefilling ones (a decoding slot holds
    more reclaimable KV), youngest admission first — so the oldest
    highest-priority work is never the one rolled back.
  * `preempt(slot)` rolls a live slot back into the queue with
    *recompute-on-resume*: its already-generated tokens are appended to
    its prompt, so the resumed prefill replays exactly the token stream
    greedy decoding would have produced and the final outputs are
    token-identical to an unpreempted run (the engine frees / donates the
    slot's memory). `Request.tokens` is that effective prefill input.

Two consumption styles:

  * chunked (`absorb_chunk`) — the sequential engines decode
    `decode_chunk` tokens per dispatch in slot lockstep; the scheduler
    walks the [chunk, n_slots] token block, appends per slot until its
    completion condition fires (later tokens in the chunk are speculative
    garbage, exactly the old engines' convention) and reports finished
    slots for refill.

  * mixed (`plan_step` / `commit`) — chunked-prefill continuous batching:
    each step packs every DECODING slot's one pending token (decode slots
    are planned first and the budget floor is the decoding-slot count, so
    decode can never starve behind a long prompt) plus up to
    `token_budget` remaining tokens of PREFILLING slots' prompts in
    priority-then-FIFO order, split into `prefill_chunk`-sized pieces. A
    segment whose chunk consumes the last prompt token emits that
    sequence's first sampled token; decode segments emit always;
    mid-prompt segments emit nothing. `commit` applies the sampled tokens
    and returns finished slots.

Time-to-first-token is tracked per REQUEST ID from enqueue (scheduler
construction — every request is enqueued then) to the first token the
request ever emits; re-admission after preemption never re-arms it, and a
priority-swapped head keeps the waiting time it actually accrued.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Request", "Scheduler", "Segment", "StepPlan", "Slot"]


@dataclasses.dataclass
class Request:
    """One queued unit of work, including preemption resume state."""

    rid: int
    prompt: np.ndarray  # the ORIGINAL prompt
    out: List[int] = dataclasses.field(default_factory=list)  # pre-preemption output
    priority: int = 0

    @property
    def tokens(self) -> np.ndarray:
        """Effective prefill input: original prompt + tokens generated
        before preemption (recompute-on-resume keeps tokens identical)."""
        if not self.out:
            return np.asarray(self.prompt)
        return np.concatenate(
            [np.asarray(self.prompt), np.asarray(self.out, np.int32)]
        )

    def __iter__(self):  # legacy (rid, prompt) unpacking
        return iter((self.rid, self.tokens))


@dataclasses.dataclass
class Slot:
    """One batch slot's host-side state."""

    rid: int = -1  # request id (−1 = free)
    prompt: Optional[np.ndarray] = None  # EFFECTIVE prefill tokens (incl. resume)
    orig_prompt: Optional[np.ndarray] = None  # the request's original prompt
    out: List[int] = dataclasses.field(default_factory=list)
    resumed: int = 0  # len(out) carried in from a preemption
    priority: int = 0
    admit_seq: int = -1  # admission order (victim selection: youngest first)
    fed: int = 0  # prompt tokens consumed by prefill chunks (mixed path)
    kv: int = 0  # KV positions materialized in the cache
    pending: int = 0  # next decode input token (mixed path)

    @property
    def live(self) -> bool:
        return self.rid >= 0

    @property
    def prefilling(self) -> bool:
        return self.live and self.prompt is not None and self.fed < len(self.prompt)

    def cache_tokens(self) -> np.ndarray:
        """Token ids whose KV the slot's cache positions [0, kv) hold: the
        effective prompt followed by post-resume generated tokens. This is
        what retirement donates to the radix prefix cache."""
        new = self.out[self.resumed:]
        stream = np.concatenate(
            [np.asarray(self.prompt, np.int32),
             np.asarray(new, np.int32)]
        ) if new else np.asarray(self.prompt, np.int32)
        return stream[: self.kv]


@dataclasses.dataclass(frozen=True)
class Segment:
    """One slot's contribution to a mixed step's packed batch."""

    slot: int
    tokens: np.ndarray  # token ids fed this step
    start: int  # absolute KV position of tokens[0]
    emits: bool  # does this segment's last row get sampled?


@dataclasses.dataclass(frozen=True)
class StepPlan:
    segments: Tuple[Segment, ...]
    n_tokens: int  # Σ len(seg.tokens) — the test-pinned budget accounting


class Scheduler:
    def __init__(self, requests: Sequence[np.ndarray], max_new_tokens: int,
                 n_slots: int, eos_id: int,
                 priorities: Optional[Sequence[int]] = None):
        if priorities is not None and len(priorities) != len(requests):
            raise ValueError("priorities must match requests 1:1")
        self.results: List[Optional[np.ndarray]] = [None] * len(requests)
        self.queue: List[Request] = [
            Request(rid=i, prompt=np.asarray(r),
                    priority=int(priorities[i]) if priorities is not None else 0)
            for i, r in enumerate(requests)
        ]
        self.slots = [Slot() for _ in range(n_slots)]
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.peak_active = 0
        self.preemptions = 0
        self._admit_counter = 0
        # time-to-first-token per request id, seconds from enqueue (every
        # request enqueues at construction) to the first token the request
        # EVER emits — recorded once, never re-armed by a preemption
        # resume; the serving-latency signal BENCH_serve.json /
        # BENCH_prefix.json track
        self.first_token_at: Dict[int, float] = {}
        self._t0 = time.monotonic()

    def _mark_first_token(self, rid: int) -> None:
        if rid not in self.first_token_at:
            self.first_token_at[rid] = time.monotonic() - self._t0

    # ---- queue / admission (priority head-of-line) ----
    def _head_index(self) -> Optional[int]:
        if not self.queue:
            return None
        return min(range(len(self.queue)),
                   key=lambda i: (-self.queue[i].priority, self.queue[i].rid))

    def head(self) -> Optional[Request]:
        i = self._head_index()
        return self.queue[i] if i is not None else None

    def take_head(self) -> Optional[Request]:
        i = self._head_index()
        return self.queue.pop(i) if i is not None else None

    def free_slot(self) -> Optional[int]:
        for s, slot in enumerate(self.slots):
            if not slot.live:
                return s
        return None

    def active_count(self) -> int:
        return sum(slot.live for slot in self.slots)

    def has_active(self) -> bool:
        return any(slot.live for slot in self.slots)

    def note_peak(self) -> int:
        self.peak_active = max(self.peak_active, self.active_count())
        return self.peak_active

    # ---- preemption ----
    def victim_slot(self, *, below: Optional[int] = None,
                    exclude: Tuple[int, ...] = ()) -> Optional[int]:
        """The slot to roll back under pressure: lowest priority first
        (optionally strictly below `below` — admission preemption never
        preempts an equal-priority peer), decoding before prefilling
        (decoding slots hold more reclaimable KV), youngest admission
        first. None when no live slot qualifies."""
        best, best_key = None, None
        for s, sl in enumerate(self.slots):
            if not sl.live or s in exclude:
                continue
            if below is not None and sl.priority >= below:
                continue
            key = (sl.priority, 1 if sl.prefilling else 0, -sl.admit_seq)
            if best_key is None or key < best_key:
                best, best_key = s, key
        return best

    def preempt(self, slot: int) -> Request:
        """Roll `slot` back into the queue with recompute-on-resume: the
        requeued request's prefill input is its original prompt plus every
        token it already generated, so the resumed stream is token-
        identical. The caller releases the slot's memory."""
        sl = self.slots[slot]
        assert sl.live, "preempting a dead slot"
        req = Request(rid=sl.rid, prompt=np.asarray(sl.orig_prompt),
                      out=list(sl.out), priority=sl.priority)
        self.queue.append(req)  # head() orders by (priority, rid)
        self.slots[slot] = Slot()
        self.preemptions += 1
        return req

    # ---- completion ----
    def _done(self, out: List[int]) -> bool:
        return len(out) >= self.max_new_tokens or (
            self.eos_id >= 0 and out[-1] == self.eos_id
        )

    def finish(self, rid: int, out: List[int]) -> None:
        self.results[rid] = np.asarray(out, np.int32)

    def admit_request(self, slot: int, req: Request, first_token: int) -> bool:
        """Sequential-path admission of a (possibly resumed) request: the
        effective prompt is already prefilled and its next token sampled.
        Requests that complete immediately are finalized without taking
        the slot; returns True when the slot was taken."""
        if not req.out:
            self._mark_first_token(req.rid)
        out = list(req.out) + [first_token]
        if self._done(out):
            self.finish(req.rid, out)
            return False
        sl = self.slots[slot]
        sl.rid, sl.out = req.rid, out
        sl.prompt = req.tokens
        sl.orig_prompt = np.asarray(req.prompt)
        sl.resumed = len(req.out)
        sl.priority = req.priority
        sl.admit_seq = self._admit_counter
        self._admit_counter += 1
        sl.fed = sl.kv = len(sl.prompt)
        sl.pending = first_token
        return True

    def admit_or_finish(self, slot: int, rid: int, prompt: np.ndarray,
                        first_token: int) -> bool:
        """Legacy sequential-path admission (fresh request, priority 0)."""
        return self.admit_request(
            slot, Request(rid=rid, prompt=np.asarray(prompt)), first_token
        )

    def admit_request_prefilling(self, slot: int, req: Request,
                                 *, fed0: int = 0) -> None:
        """Mixed-path admission: the effective prompt will be fed in
        chunks, starting at `fed0` (positions below it are already in the
        cache — the radix prefix hit, DESIGN.md §3.6)."""
        sl = self.slots[slot]
        sl.rid, sl.out = req.rid, list(req.out)
        sl.prompt = req.tokens
        sl.orig_prompt = np.asarray(req.prompt)
        sl.resumed = len(req.out)
        sl.priority = req.priority
        sl.admit_seq = self._admit_counter
        self._admit_counter += 1
        sl.fed = sl.kv = fed0
        sl.pending = 0

    def admit_prefilling(self, slot: int, rid: int, prompt: np.ndarray) -> None:
        """Legacy mixed-path admission (fresh request, priority 0)."""
        self.admit_request_prefilling(
            slot, Request(rid=rid, prompt=np.asarray(prompt))
        )

    def retire(self, slot: int) -> int:
        """Free a slot (results must already be recorded); returns its rid."""
        rid = self.slots[slot].rid
        self.slots[slot] = Slot()
        return rid

    # ---- chunked consumption (contiguous + paged sequential loops) ----
    def absorb_chunk(self, toks_np: np.ndarray) -> List[int]:
        """Walk a [chunk, n_slots] sampled-token block in slot lockstep;
        tokens after a slot's completion are speculative garbage and are
        discarded. Records finished results and returns finished slots
        (NOT yet retired — the engine frees memory first)."""
        finished: List[int] = []
        for s, sl in enumerate(self.slots):
            if not sl.live:
                continue
            for step in range(toks_np.shape[0]):
                t = int(toks_np[step, s])
                sl.out.append(t)
                sl.kv += 1
                sl.pending = t  # next decode input if a packed step follows
                if self._done(sl.out):
                    self.finish(sl.rid, sl.out)
                    finished.append(s)
                    break
        return finished

    # ---- mixed-step planning (chunked-prefill continuous batching) ----
    def plan_step(self, token_budget: int, prefill_chunk: int) -> StepPlan:
        """One mixed step's packed work list.

        Decode slots first — every decoding slot contributes its pending
        token, and the effective budget is floored at that count, so a
        wall of prefill can never starve decode. Remaining budget goes to
        prefilling slots' next prompt chunks in priority-then-request-id
        (FIFO within a class) order.
        """
        segs: List[Segment] = []
        decoding = [
            s for s, sl in enumerate(self.slots)
            if sl.live and not sl.prefilling
        ]
        budget = max(int(token_budget), len(decoding))
        for s in decoding:
            sl = self.slots[s]
            segs.append(Segment(
                slot=s, tokens=np.asarray([sl.pending], np.int32),
                start=sl.kv, emits=True,
            ))
            budget -= 1
        prefilling = sorted(
            (s for s, sl in enumerate(self.slots) if sl.prefilling),
            key=lambda s: (-self.slots[s].priority, self.slots[s].rid),
        )
        for s in prefilling:
            if budget <= 0:
                break
            sl = self.slots[s]
            # ≥ 1: budget > 0 here, prefill_chunk ≥ 1, and a prefilling
            # slot always has unfed prompt left
            n = min(prefill_chunk, len(sl.prompt) - sl.fed, budget)
            segs.append(Segment(
                slot=s,
                tokens=np.asarray(sl.prompt[sl.fed:sl.fed + n], np.int32),
                start=sl.fed,
                emits=sl.fed + n == len(sl.prompt),
            ))
            budget -= n
        return StepPlan(
            segments=tuple(segs), n_tokens=sum(len(g.tokens) for g in segs)
        )

    def commit(self, plan: StepPlan, sampled: np.ndarray) -> List[int]:
        """Apply one mixed step's sampled tokens ([n_slots], garbage at
        non-emitting slots). Returns finished slots (engine retires them
        after freeing their memory)."""
        finished: List[int] = []
        for seg in plan.segments:
            sl = self.slots[seg.slot]
            if not sl.live:  # preempted after planning (engine re-plans, but stay safe)
                continue
            n = len(seg.tokens)
            sl.kv += n
            if sl.prefilling:
                sl.fed += n
            if not seg.emits:
                continue
            t = int(sampled[seg.slot])
            sl.out.append(t)
            sl.pending = t
            if len(sl.out) == sl.resumed + 1 and sl.resumed == 0:
                self._mark_first_token(sl.rid)
            if self._done(sl.out):
                self.finish(sl.rid, sl.out)
                finished.append(seg.slot)
        return finished

    # ---- results ----
    def results_list(self) -> List[np.ndarray]:
        return [
            r if r is not None else np.zeros((0,), np.int32)
            for r in self.results
        ]
