"""Continuous-batching scheduler: slot lifecycle, priority classes,
preemption, and token-budget step plans.

The host-side state machine shared by EVERY serve path (DESIGN.md §3.5,
§3.6). The engine's three loops — contiguous chunked decode, paged chunked
decode, and the mixed varlen step — used to each carry their own copy of
the same bookkeeping (request queue, per-slot output accumulation, EOS /
max-token completion, refill, peak-concurrency tracking). That now lives
here exactly once; the engine keeps only what actually differs per path:
how memory is admitted (slot width vs free pages) and what gets
dispatched.

Priority + preemption (DESIGN.md §3.6):

  * every request carries a priority class (higher value = more urgent;
    default 0 for all = pure FIFO). `head()` returns the highest-priority
    queued request, FIFO (arrival order) within a class — admission is
    still strictly head-of-line *per the priority order*: later requests
    never jump an equal-or-higher-priority blocked head.
  * `victim_slot()` implements victim selection: the lowest-priority live
    slot, decoding slots before prefilling ones (a decoding slot holds
    more reclaimable KV), youngest admission first — so the oldest
    highest-priority work is never the one rolled back.
  * `preempt(slot)` rolls a live slot back into the queue with
    *recompute-on-resume*: its already-generated tokens are appended to
    its prompt, so the resumed prefill replays exactly the token stream
    greedy decoding would have produced and the final outputs are
    token-identical to an unpreempted run (the engine frees / donates the
    slot's memory). `Request.tokens` is that effective prefill input.

Two consumption styles:

  * chunked (`absorb_chunk`) — the sequential engines decode
    `decode_chunk` tokens per dispatch in slot lockstep; the scheduler
    walks the [chunk, n_slots] token block, appends per slot until its
    completion condition fires (later tokens in the chunk are speculative
    garbage, exactly the old engines' convention) and reports finished
    slots for refill.

  * mixed (`plan_step` / `commit`) — chunked-prefill continuous batching:
    each step packs every DECODING slot's one pending token (decode slots
    are planned first and the budget floor is the decoding-slot count, so
    decode can never starve behind a long prompt) plus up to
    `token_budget` remaining tokens of PREFILLING slots' prompts in
    priority-then-FIFO order, split into `prefill_chunk`-sized pieces. A
    segment whose chunk consumes the last prompt token emits that
    sequence's first sampled token; decode segments emit always;
    mid-prompt segments emit nothing. `commit` applies the sampled tokens
    and returns finished slots.

Time-to-first-token is tracked per REQUEST ID from enqueue (scheduler
construction — every request is enqueued then) to the first token the
request ever emits; re-admission after preemption never re-arms it, and a
priority-swapped head keeps the waiting time it actually accrued.

Request lifecycle (DESIGN.md §3.7): every request moves through
QUEUED → RUNNING → one terminal status — DONE (EOS / max tokens),
EXPIRED (deadline passed; cancelled exactly like EOS, with whatever it
generated so far as its result), or FAILED (fault-retry budget
exhausted). Faulted requests re-queue through the same recompute-on-
resume path preemption uses (`retry_request` / `fault_slot`), charged
against a per-request retry budget and deferred by `not_before`
exponential backoff; within a priority class retried requests sort after
fresh ones. Nothing is ever silently dropped: `results_list()` has an
entry and `status` a terminal state for every rid once serving ends.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "Request", "Scheduler", "Segment", "StepPlan", "Slot",
    "QUEUED", "RUNNING", "DONE", "FAILED", "EXPIRED", "TERMINAL",
]

# ---- request lifecycle states ----
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
EXPIRED = "expired"
TERMINAL = frozenset({DONE, FAILED, EXPIRED})


@dataclasses.dataclass
class Request:
    """One queued unit of work, including preemption/retry resume state."""

    rid: int
    prompt: np.ndarray  # the ORIGINAL prompt
    out: List[int] = dataclasses.field(default_factory=list)  # pre-preemption output
    priority: int = 0
    deadline: Optional[float] = None  # scheduler-clock time after which it expires
    retries: int = 0  # fault retries consumed so far
    not_before: float = 0.0  # backoff gate: ineligible for admission before this

    @property
    def tokens(self) -> np.ndarray:
        """Effective prefill input: original prompt + tokens generated
        before preemption (recompute-on-resume keeps tokens identical)."""
        if not self.out:
            return np.asarray(self.prompt)
        return np.concatenate(
            [np.asarray(self.prompt), np.asarray(self.out, np.int32)]
        )

    def __iter__(self):  # legacy (rid, prompt) unpacking
        return iter((self.rid, self.tokens))


@dataclasses.dataclass
class Slot:
    """One batch slot's host-side state."""

    rid: int = -1  # request id (−1 = free)
    prompt: Optional[np.ndarray] = None  # EFFECTIVE prefill tokens (incl. resume)
    orig_prompt: Optional[np.ndarray] = None  # the request's original prompt
    out: List[int] = dataclasses.field(default_factory=list)
    resumed: int = 0  # len(out) carried in from a preemption
    priority: int = 0
    admit_seq: int = -1  # admission order (victim selection: youngest first)
    fed: int = 0  # prompt tokens consumed by prefill chunks (mixed path)
    kv: int = 0  # KV positions materialized in the cache
    pending: int = 0  # next decode input token (mixed path)
    deadline: Optional[float] = None  # scheduler-clock expiry (None = none)
    retries: int = 0  # fault retries the request has consumed

    @property
    def live(self) -> bool:
        return self.rid >= 0

    @property
    def prefilling(self) -> bool:
        return self.live and self.prompt is not None and self.fed < len(self.prompt)

    def cache_tokens(self) -> np.ndarray:
        """Token ids whose KV the slot's cache positions [0, kv) hold: the
        effective prompt followed by post-resume generated tokens. This is
        what retirement donates to the radix prefix cache."""
        new = self.out[self.resumed:]
        stream = np.concatenate(
            [np.asarray(self.prompt, np.int32),
             np.asarray(new, np.int32)]
        ) if new else np.asarray(self.prompt, np.int32)
        return stream[: self.kv]


@dataclasses.dataclass(frozen=True)
class Segment:
    """One slot's contribution to a mixed step's packed batch."""

    slot: int
    tokens: np.ndarray  # token ids fed this step
    start: int  # absolute KV position of tokens[0]
    emits: bool  # does this segment's last row get sampled?
    n_draft: int = 0  # trailing speculative rows: tokens[1:] are draft
    # proposals to VERIFY (tokens[0] is the committed pending token);
    # commit() keeps the longest accepted prefix and rolls kv back past
    # the rest (DESIGN.md §3.9)


@dataclasses.dataclass(frozen=True)
class StepPlan:
    segments: Tuple[Segment, ...]
    n_tokens: int  # Σ len(seg.tokens) — the test-pinned budget accounting


class Scheduler:
    def __init__(self, requests: Sequence[Union[np.ndarray, Request]],
                 max_new_tokens: int, n_slots: int, eos_id: int,
                 priorities: Optional[Sequence[int]] = None,
                 deadlines: Optional[Sequence[Optional[float]]] = None,
                 max_retries: int = 3, retry_backoff_s: float = 0.0):
        """`requests` items are prompts (np arrays) or `Request` objects —
        the latter carry resume state (out/priority/deadline/retries) from
        a snapshot restore; either way rids are re-assigned to index order.
        `deadlines` are seconds from enqueue (None = no deadline);
        `max_retries`/`retry_backoff_s` parameterize the fault-retry path
        (`RetryPolicy` semantics, see runtime/resilience.py)."""
        if priorities is not None and len(priorities) != len(requests):
            raise ValueError("priorities must match requests 1:1")
        if deadlines is not None and len(deadlines) != len(requests):
            raise ValueError("deadlines must match requests 1:1")
        self.results: List[Optional[np.ndarray]] = [None] * len(requests)
        self.queue: List[Request] = []
        for i, r in enumerate(requests):
            if isinstance(r, Request):
                pr = int(priorities[i]) if priorities is not None else r.priority
                dl = r.deadline if deadlines is None else deadlines[i]
                self.queue.append(Request(
                    rid=i, prompt=np.asarray(r.prompt), out=list(r.out),
                    priority=pr, deadline=dl, retries=r.retries,
                    # a restored mid-backoff request keeps its gate: the
                    # caller rebased it to this scheduler's clock (seconds
                    # from construction), same convention as deadlines
                    not_before=r.not_before,
                ))
            else:
                self.queue.append(Request(
                    rid=i, prompt=np.asarray(r),
                    priority=int(priorities[i]) if priorities is not None else 0,
                    deadline=deadlines[i] if deadlines is not None else None,
                ))
        self.status: Dict[int, str] = {i: QUEUED for i in range(len(requests))}
        self.slots = [Slot() for _ in range(n_slots)]
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.peak_active = 0
        self.preemptions = 0
        self.retried = 0  # fault retries charged (requeues)
        self.failed = 0  # requests terminal-FAILED (budget exhausted)
        self.expired = 0  # requests terminal-EXPIRED (deadline passed)
        self.rollbacks = 0  # preemptions + fault requeues (re-plan signal)
        # speculative-decoding bookkeeping (DESIGN.md §3.9): aggregate and
        # per-request drafted/accepted counters, filled by verify commits
        self.spec_rounds = 0  # verify segments committed with n_draft > 0
        self.spec_drafted = 0  # draft tokens proposed to the target
        self.spec_accepted = 0  # draft tokens the target confirmed
        self.spec_by_rid: Dict[int, Tuple[int, int]] = {}  # rid → (drafted, accepted)
        self._admit_counter = 0
        # time-to-first-token per request id, seconds from enqueue (every
        # request enqueues at construction) to the first token the request
        # EVER emits — recorded once, never re-armed by a preemption
        # resume; the serving-latency signal BENCH_serve.json /
        # BENCH_prefix.json track
        self.first_token_at: Dict[int, float] = {}
        self._t0 = time.monotonic()

    def now(self) -> float:
        """Scheduler-clock time (seconds since construction/enqueue)."""
        return time.monotonic() - self._t0

    def _mark_first_token(self, rid: int) -> None:
        if rid not in self.first_token_at:
            self.first_token_at[rid] = self.now()

    # ---- queue / admission (priority head-of-line) ----
    def _head_index(self) -> Optional[int]:
        now = self.now()
        ready = [i for i, q in enumerate(self.queue) if q.not_before <= now]
        if not ready:
            return None
        # retried requests sort AFTER fresh ones of the same priority —
        # the "exponential backoff ordering" half of the retry contract
        # (the not_before gate above is the other half)
        return min(ready, key=lambda i: (-self.queue[i].priority,
                                         self.queue[i].retries,
                                         self.queue[i].rid))

    def head(self) -> Optional[Request]:
        i = self._head_index()
        return self.queue[i] if i is not None else None

    def take_head(self) -> Optional[Request]:
        i = self._head_index()
        return self.queue.pop(i) if i is not None else None

    def next_ready_in(self) -> Optional[float]:
        """Seconds until the earliest backing-off queued request becomes
        eligible; None when nothing is waiting on backoff."""
        now = self.now()
        waits = [q.not_before - now for q in self.queue if q.not_before > now]
        return min(waits) if waits else None

    def free_slot(self) -> Optional[int]:
        for s, slot in enumerate(self.slots):
            if not slot.live:
                return s
        return None

    def active_count(self) -> int:
        return sum(slot.live for slot in self.slots)

    def has_active(self) -> bool:
        return any(slot.live for slot in self.slots)

    def note_peak(self) -> int:
        self.peak_active = max(self.peak_active, self.active_count())
        return self.peak_active

    # ---- preemption ----
    def victim_slot(self, *, below: Optional[int] = None,
                    exclude: Tuple[int, ...] = ()) -> Optional[int]:
        """The slot to roll back under pressure: lowest priority first
        (optionally strictly below `below` — admission preemption never
        preempts an equal-priority peer), decoding before prefilling
        (decoding slots hold more reclaimable KV), youngest admission
        first. None when no live slot qualifies."""
        best, best_key = None, None
        for s, sl in enumerate(self.slots):
            if not sl.live or s in exclude:
                continue
            if below is not None and sl.priority >= below:
                continue
            key = (sl.priority, 1 if sl.prefilling else 0, -sl.admit_seq)
            if best_key is None or key < best_key:
                best, best_key = s, key
        return best

    def preempt(self, slot: int) -> Request:
        """Roll `slot` back into the queue with recompute-on-resume: the
        requeued request's prefill input is its original prompt plus every
        token it already generated, so the resumed stream is token-
        identical. The caller releases the slot's memory."""
        sl = self.slots[slot]
        assert sl.live, "preempting a dead slot"
        req = Request(rid=sl.rid, prompt=np.asarray(sl.orig_prompt),
                      out=list(sl.out), priority=sl.priority,
                      deadline=sl.deadline, retries=sl.retries)
        self.queue.append(req)  # head() orders by (priority, retries, rid)
        self.status[sl.rid] = QUEUED
        self.slots[slot] = Slot()
        self.preemptions += 1
        self.rollbacks += 1
        return req

    # ---- fault retries (DESIGN.md §3.7) ----
    def retry_request(self, req: Request, *, backoff_s: Optional[float] = None) -> bool:
        """Charge a faulted request (held by the caller, not slot-resident)
        one retry and re-queue it behind an exponential-backoff gate.
        Returns False — and records the terminal FAILED result (tokens
        generated so far, like EOS does) — when the budget is exhausted."""
        req.retries += 1
        self.rollbacks += 1  # FAILED invalidates a step plan like a requeue
        if req.retries > self.max_retries:
            self.finish(req.rid, list(req.out), status=FAILED)
            return False
        base = self.retry_backoff_s if backoff_s is None else backoff_s
        req.not_before = self.now() + base * (2 ** (req.retries - 1))
        self.status[req.rid] = QUEUED
        self.queue.append(req)
        self.retried += 1
        return True

    def fault_slot(self, slot: int, *, backoff_s: Optional[float] = None) -> bool:
        """Roll a faulted LIVE slot back like `preempt`, but charged as a
        retry: its committed tokens ride along (recompute-on-resume keeps
        the stream token-identical), its budget is debited, and re-
        admission waits out the backoff. Returns False when the request
        went terminal-FAILED instead. Caller releases the slot's memory
        either way."""
        sl = self.slots[slot]
        assert sl.live, "faulting a dead slot"
        req = Request(rid=sl.rid, prompt=np.asarray(sl.orig_prompt),
                      out=list(sl.out), priority=sl.priority,
                      deadline=sl.deadline, retries=sl.retries)
        self.slots[slot] = Slot()
        return self.retry_request(req, backoff_s=backoff_s)

    # ---- deadlines ----
    def expire_overdue(self) -> List[int]:
        """Cancel every queued or live request whose deadline has passed —
        exactly like EOS: whatever it generated so far is its result,
        status EXPIRED. Returns the newly expired LIVE slots (the engine
        releases their memory, then `retire`s them)."""
        now = self.now()
        expired_slots: List[int] = []
        for i in reversed(range(len(self.queue))):
            q = self.queue[i]
            if q.deadline is not None and now > q.deadline:
                self.queue.pop(i)
                self.finish(q.rid, list(q.out), status=EXPIRED)
        for s, sl in enumerate(self.slots):
            if sl.live and sl.deadline is not None and now > sl.deadline:
                self.finish(sl.rid, list(sl.out), status=EXPIRED)
                expired_slots.append(s)
        return expired_slots

    # ---- completion ----
    def _done(self, out: List[int]) -> bool:
        return len(out) >= self.max_new_tokens or (
            self.eos_id >= 0 and out[-1] == self.eos_id
        )

    def finish(self, rid: int, out: List[int], status: str = DONE) -> None:
        assert status in TERMINAL, f"finish with non-terminal status {status!r}"
        self.results[rid] = np.asarray(out, np.int32)
        self.status[rid] = status
        if status == FAILED:
            self.failed += 1
        elif status == EXPIRED:
            self.expired += 1

    def all_terminal(self) -> bool:
        """Lifecycle guarantee: every request reached a terminal status."""
        return all(s in TERMINAL for s in self.status.values())

    def admit_request(self, slot: int, req: Request, first_token: int) -> bool:
        """Sequential-path admission of a (possibly resumed) request: the
        effective prompt is already prefilled and its next token sampled.
        Requests that complete immediately are finalized without taking
        the slot; returns True when the slot was taken."""
        if not req.out:
            self._mark_first_token(req.rid)
        out = list(req.out) + [first_token]
        if self._done(out):
            self.finish(req.rid, out)
            return False
        sl = self.slots[slot]
        sl.rid, sl.out = req.rid, out
        sl.prompt = req.tokens
        sl.orig_prompt = np.asarray(req.prompt)
        sl.resumed = len(req.out)
        sl.priority = req.priority
        sl.deadline = req.deadline
        sl.retries = req.retries
        sl.admit_seq = self._admit_counter
        self._admit_counter += 1
        sl.fed = sl.kv = len(sl.prompt)
        sl.pending = first_token
        self.status[req.rid] = RUNNING
        return True

    def admit_or_finish(self, slot: int, rid: int, prompt: np.ndarray,
                        first_token: int) -> bool:
        """Legacy sequential-path admission (fresh request, priority 0)."""
        return self.admit_request(
            slot, Request(rid=rid, prompt=np.asarray(prompt)), first_token
        )

    def admit_request_prefilling(self, slot: int, req: Request,
                                 *, fed0: int = 0) -> None:
        """Mixed-path admission: the effective prompt will be fed in
        chunks, starting at `fed0` (positions below it are already in the
        cache — the radix prefix hit, DESIGN.md §3.6)."""
        sl = self.slots[slot]
        sl.rid, sl.out = req.rid, list(req.out)
        sl.prompt = req.tokens
        sl.orig_prompt = np.asarray(req.prompt)
        sl.resumed = len(req.out)
        sl.priority = req.priority
        sl.deadline = req.deadline
        sl.retries = req.retries
        sl.admit_seq = self._admit_counter
        self._admit_counter += 1
        sl.fed = sl.kv = fed0
        sl.pending = 0
        self.status[req.rid] = RUNNING

    def admit_prefilling(self, slot: int, rid: int, prompt: np.ndarray) -> None:
        """Legacy mixed-path admission (fresh request, priority 0)."""
        self.admit_request_prefilling(
            slot, Request(rid=rid, prompt=np.asarray(prompt))
        )

    def retire(self, slot: int) -> int:
        """Free a slot (results must already be recorded); returns its rid."""
        rid = self.slots[slot].rid
        self.slots[slot] = Slot()
        return rid

    # ---- chunked consumption (contiguous + paged sequential loops) ----
    def absorb_chunk(self, toks_np: np.ndarray) -> List[int]:
        """Walk a [chunk, n_slots] sampled-token block in slot lockstep;
        tokens after a slot's completion are speculative garbage and are
        discarded. Records finished results and returns finished slots
        (NOT yet retired — the engine frees memory first)."""
        finished: List[int] = []
        for s, sl in enumerate(self.slots):
            if not sl.live:
                continue
            for step in range(toks_np.shape[0]):
                t = int(toks_np[step, s])
                sl.out.append(t)
                sl.kv += 1
                sl.pending = t  # next decode input if a packed step follows
                if self._done(sl.out):
                    self.finish(sl.rid, sl.out)
                    finished.append(s)
                    break
        return finished

    # ---- speculative draft budgeting (DESIGN.md §3.9) ----
    def draft_quota(self, slot: int, k_max: int, *, max_len: int,
                    per_row_s: Optional[float] = None) -> int:
        """How many draft tokens `slot` may verify this step. Clamped so
        the accepted prefix plus the bonus token can never exceed the
        request's `max_new_tokens` or the cache's `max_len`, and — the
        deadline bugfix — so a K-row verify step cannot overshoot a
        deadline by K rows' worth of work: `expire_overdue` only runs
        BETWEEN engine steps, so near the deadline the quota shrinks with
        the remaining slack (`per_row_s` is the engine's measured
        per-verify-row wall time)."""
        sl = self.slots[slot]
        if not sl.live or sl.prefilling:
            return 0
        k = min(int(k_max),
                self.max_new_tokens - len(sl.out) - 1,
                max_len - sl.kv - 1)
        if k <= 0:
            return 0
        if sl.deadline is not None and per_row_s and per_row_s > 0:
            slack = sl.deadline - self.now()
            if slack <= 0:
                return 0
            k = min(k, max(0, int(slack / per_row_s) - 1))
        return max(0, k)

    # ---- mixed-step planning (chunked-prefill continuous batching) ----
    def plan_step(self, token_budget: int, prefill_chunk: int,
                  drafts: Optional[Dict[int, np.ndarray]] = None) -> StepPlan:
        """One mixed step's packed work list.

        Decode slots first — every decoding slot contributes its pending
        token, and the effective budget is floored at that count, so a
        wall of prefill can never starve decode. Remaining budget goes to
        prefilling slots' next prompt chunks in priority-then-request-id
        (FIFO within a class) order.

        `drafts` (speculative decoding, DESIGN.md §3.9) maps decode slots
        to proposed draft tokens. Draft rows are funded LAST, round-robin
        across decode slots, from whatever budget prefill chunks left
        over — draft rows count against `token_budget` but can never
        starve a prefill chunk (acceptance is a throughput bonus, TTFT is
        a latency promise). Values may be placeholders when the real
        draft tokens live on device (the verify dispatch scatters them);
        only the per-slot COUNT is planned here.
        """
        decoding = [
            s for s, sl in enumerate(self.slots)
            if sl.live and not sl.prefilling
        ]
        budget = max(int(token_budget), len(decoding)) - len(decoding)
        pre_segs: List[Segment] = []
        prefilling = sorted(
            (s for s, sl in enumerate(self.slots) if sl.prefilling),
            key=lambda s: (-self.slots[s].priority, self.slots[s].rid),
        )
        for s in prefilling:
            if budget <= 0:
                break
            sl = self.slots[s]
            # ≥ 1: budget > 0 here, prefill_chunk ≥ 1, and a prefilling
            # slot always has unfed prompt left
            n = min(prefill_chunk, len(sl.prompt) - sl.fed, budget)
            pre_segs.append(Segment(
                slot=s,
                tokens=np.asarray(sl.prompt[sl.fed:sl.fed + n], np.int32),
                start=sl.fed,
                emits=sl.fed + n == len(sl.prompt),
            ))
            budget -= n
        extra: Dict[int, int] = {s: 0 for s in decoding}
        if drafts:
            gave = True
            while budget > 0 and gave:
                gave = False
                for s in decoding:
                    if budget <= 0:
                        break
                    if extra[s] < len(drafts.get(s, ())):
                        extra[s] += 1
                        budget -= 1
                        gave = True
        dec_segs: List[Segment] = []
        for s in decoding:
            sl = self.slots[s]
            k = extra[s]
            toks = [sl.pending]
            if k:
                toks.extend(int(t) for t in np.asarray(drafts[s])[:k])
            dec_segs.append(Segment(
                slot=s, tokens=np.asarray(toks, np.int32),
                start=sl.kv, emits=True, n_draft=k,
            ))
        segs = dec_segs + pre_segs
        return StepPlan(
            segments=tuple(segs), n_tokens=sum(len(g.tokens) for g in segs)
        )

    def commit(self, plan: StepPlan, sampled: np.ndarray,
               n_acc: Optional[np.ndarray] = None) -> List[int]:
        """Apply one mixed step's sampled tokens ([n_slots], garbage at
        non-emitting slots). Returns finished slots (engine retires them
        after freeing their memory).

        With `n_acc` (a speculative verify step, DESIGN.md §3.9),
        `sampled` is [n_slots, R]: the target's greedy token at every
        verify row. A decode segment commits the longest accepted prefix —
        row j's token is appended for j = 0..n_acc[slot] (the last one is
        the free "bonus" token from the first rejected row), stopping
        early at EOS/max-tokens — and `kv` advances by exactly the tokens
        committed, so the engine can roll the allocator back to it."""
        finished: List[int] = []
        for seg in plan.segments:
            sl = self.slots[seg.slot]
            if not sl.live:  # preempted after planning (engine re-plans, but stay safe)
                continue
            n = len(seg.tokens)
            if n_acc is not None and not sl.prefilling:
                # verify segment: pending + accepted drafts + bonus token
                k_ok = min(int(n_acc[seg.slot]), seg.n_draft)
                consumed = 0
                for j in range(k_ok + 1):
                    t = int(sampled[seg.slot, j])
                    sl.out.append(t)
                    sl.pending = t
                    consumed += 1
                    if len(sl.out) == sl.resumed + 1 and sl.resumed == 0:
                        self._mark_first_token(sl.rid)
                    if self._done(sl.out):
                        self.finish(sl.rid, sl.out)
                        finished.append(seg.slot)
                        break
                sl.kv = seg.start + consumed  # rejected rows: kv rolls back
                if seg.n_draft:
                    acc = min(consumed, k_ok)
                    self.spec_rounds += 1
                    self.spec_drafted += seg.n_draft
                    self.spec_accepted += acc
                    d, a = self.spec_by_rid.get(sl.rid, (0, 0))
                    self.spec_by_rid[sl.rid] = (d + seg.n_draft, a + acc)
                continue
            sl.kv += n
            if sl.prefilling:
                sl.fed += n
            if not seg.emits:
                continue
            t = int(sampled[seg.slot]) if n_acc is None else int(sampled[seg.slot, 0])
            sl.out.append(t)
            sl.pending = t
            if len(sl.out) == sl.resumed + 1 and sl.resumed == 0:
                self._mark_first_token(sl.rid)
            if self._done(sl.out):
                self.finish(sl.rid, sl.out)
                finished.append(seg.slot)
        return finished

    # ---- results ----
    def results_list(self) -> List[np.ndarray]:
        return [
            r if r is not None else np.zeros((0,), np.int32)
            for r in self.results
        ]
