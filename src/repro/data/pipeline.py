"""Synthetic LM data pipeline: deterministic, sharded, checkpointable.

Generates a structured token stream (a stochastic block-grammar over the
vocab: zipf-distributed unigram base + Markov bigram structure) so a small
model has something non-trivial to learn — loss decreases measurably within
a few hundred steps, which the integration tests assert.

Determinism + fault tolerance: the iterator is a pure function of
(seed, step), so its "state" is one integer; restart-from-checkpoint resumes
the exact stream (test-verified). Per-host sharding slices the global batch
by (host_index, host_count) the way a real multi-host input pipeline would.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.3
    markov_weight: float = 0.7  # bigram structure strength


class SyntheticLM:
    """Deterministic batches: batch(step) is reproducible in isolation."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.host_count:
            raise ValueError("global_batch must divide by host_count")
        self.local_batch = cfg.global_batch // cfg.host_count
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # zipf unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = ranks ** (-cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # sparse Markov structure: each token prefers a few successors
        self._succ = base.integers(0, v, size=(v, 4))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131 + cfg.host_index
        )
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=b, p=self._unigram)
        for t in range(1, s + 1):
            use_markov = rng.random(b) < cfg.markov_weight
            succ_pick = self._succ[toks[:, t - 1], rng.integers(0, 4, size=b)]
            uni_pick = rng.choice(v, size=b, p=self._unigram)
            toks[:, t] = np.where(use_markov, succ_pick, uni_pick)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    # fault-tolerance contract: state == the step counter, nothing else.
    @staticmethod
    def state_at(step: int) -> dict:
        return {"data_step": step}


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    return SyntheticLM(cfg).batch(step)
