from repro.data.pipeline import DataConfig, SyntheticLM, make_batch
__all__ = ["DataConfig", "SyntheticLM", "make_batch"]
