"""Sharding rules engine: logical axes → mesh axes, with divisibility guards.

Strategy (DESIGN.md §4):
  batch               → ('pod', 'data')      data parallel across pods
  seq (residual SP)   → 'model'              Megatron-style sequence parallel
  heads / ff / vocab  → 'model'              tensor parallel
  experts             → 'model'              expert parallel
  fsdp (param in-dim) → 'data'               ZeRO-3 within a pod; parameters
                                             replicate across pods (DCN is
                                             slow; grad all-reduce is
                                             hierarchical: ICI then DCN)

Every rule is divisibility-checked against the active mesh; non-divisible
dims fall back to replication (e.g. qwen2's 12 query heads on a 16-way model
axis). Models call `shard(x, kind)` at activation boundaries; with no active
context this is the identity, so smoke tests and single-device runs never
touch device state.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "activate",
    "active_ctx",
    "shard",
    "spec_for",
    "param_specs",
    "ShardingCtx",
    "mesh_ctx",
    "sharded_jit",
    "cp_axis_for_cache",
    "cp_batch_axes_for_cache",
]

_TL = threading.local()


class ShardingCtx:
    def __init__(self, mesh: Mesh, *, use_sp: bool = True, fsdp_axis="data",
                 use_cp: bool = True, cp_prefill: bool = False):
        """fsdp_axis: 'data' (default — params replicate across pods, grad
        all-reduce is hierarchical ICI→DCN) or ('pod','data') (ZeRO across
        pods too — halves state at the cost of DCN param all-gathers; the
        only way 235B-scale training fits 16 GB/chip HBM).

        use_cp: when the kv_cache rule seq-shards a cache (see
        `cp_axis_for_cache`), route decode attention through the
        cross-device FLASH-D merge (`repro.distributed.context.cp_decode`)
        instead of letting GSPMD gather the cache. cp_prefill additionally
        routes `flash_attention` through the ring-prefill schedule — off by
        default because the ring path is forward-only (serving/prefill);
        training keeps the differentiable GSPMD lowering."""
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.use_sp = use_sp
        if isinstance(fsdp_axis, str):
            fsdp_axis = (fsdp_axis,)
        fsdp_axis = tuple(a for a in (fsdp_axis or ()) if a in self.axis_sizes)
        self.fsdp_axis = fsdp_axis or None
        self.has_pod = "pod" in self.axis_sizes
        # constrain mixer/FFN OUTPUTS to the seq-sharded residual spec so the
        # row-parallel matmuls' partial sums lower to reduce-scatter instead
        # of all-reduce (Megatron-SP placement; §Perf lever, halves that wire)
        self.rs_outputs = True
        # TP the activations (classic Megatron). False = keep weights sharded
        # for memory but let XLA gather them at use and compute full-DP —
        # wins whenever tokens ≫ weights (32k prefill: weights/layer ~270 MB
        # bf16 vs ~1 GiB f32 activation all-reduce; §Perf lever 'notp')
        self.tp_activations = True
        self.use_cp = use_cp
        self.cp_prefill = cp_prefill

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.has_pod else ("data",)

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return self.axis_sizes.get(axes, 1)
        return int(np.prod([self.axis_sizes.get(a, 1) for a in axes]))


def active_ctx() -> Optional[ShardingCtx]:
    return getattr(_TL, "ctx", None)


@contextlib.contextmanager
def activate(ctx: Optional[ShardingCtx]):
    prev = getattr(_TL, "ctx", None)
    _TL.ctx = ctx
    try:
        yield
    finally:
        _TL.ctx = prev


def mesh_ctx(mesh: Mesh):
    """Ambient-mesh context across jax versions.

    jax ≥ 0.6 has `jax.set_mesh`; 0.5.x has `jax.sharding.use_mesh`; older
    releases fall back to the legacy `with mesh:` context (which is what
    lets `with_sharding_constraint` resolve bare PartitionSpecs)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def _resolve_shardings(tree, mesh: Mesh):
    """PartitionSpec / None leaves → NamedSharding on `mesh`.

    None ⇒ fully replicated, matching legacy pjit's in_axis_resources
    semantics — which is what this fallback path targets. Note the
    divergence from modern `jax.set_mesh` jit, where a None leaf stays
    UNSPECIFIED and GSPMD may infer a sharding instead; older jax exposes
    no public UNSPECIFIED sentinel, so replication is the faithful legacy
    behavior."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if isinstance(s, P) else P()),
        tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def sharded_jit(fn, *, in_shardings=None, out_shardings=None, mesh=None, **jit_kwargs):
    """`jax.jit` that accepts PartitionSpec trees for shardings on any jax
    version. Where `jax.set_mesh` exists, specs pass straight through (the
    ambient mesh resolves them); otherwise they are resolved here against
    `mesh` (default: the active ShardingCtx's mesh)."""
    if not hasattr(jax, "set_mesh"):
        if mesh is None:
            ctx = active_ctx()
            if ctx is None or ctx.mesh is None:
                raise RuntimeError(
                    "sharded_jit needs a mesh (argument or active ShardingCtx)"
                )
            mesh = ctx.mesh
        if in_shardings is not None:
            in_shardings = _resolve_shardings(in_shardings, mesh)
        if out_shardings is not None:
            out_shardings = _resolve_shardings(out_shardings, mesh)
    if in_shardings is not None:
        jit_kwargs["in_shardings"] = in_shardings
    if out_shardings is not None:
        jit_kwargs["out_shardings"] = out_shardings
    return jax.jit(fn, **jit_kwargs)


def _fit(ctx: ShardingCtx, dim_size: int, axes):
    """Return axes if dim_size divides by their product, else None.

    Axes absent from the active mesh never shard: their size defaults to 1
    (always divides), but naming them in a spec would be a mesh-resolution
    error — e.g. the kv_cache rule on a ('data',)-only serving mesh."""
    if axes is None:
        return None
    if isinstance(axes, str):
        if axes not in ctx.axis_sizes:
            return None
        return axes if dim_size % ctx.axis_size(axes) == 0 else None
    axes = tuple(a for a in axes if a in ctx.axis_sizes)
    if not axes:
        return None
    if dim_size % ctx.axis_size(axes) == 0:
        return axes
    # try a prefix (e.g. ('pod','data') → ('pod',)) before giving up
    for cut in range(len(axes) - 1, 0, -1):
        sub = axes[:cut]
        if dim_size % ctx.axis_size(sub) == 0:
            return sub
    return None


def _heads_spec(c: "ShardingCtx", s):
    """[B, S, H, hd] attention activations.

    Preferred: heads over 'model' (Megatron TP). When the head count does
    not divide the model axis (yi-34b's 56, qwen2's 12), fall back to
    full-DP attention: batch over as many mesh axes as divide it, remaining
    axes onto the sequence dim — bounding per-device attention memory
    without padding head counts (GSPMD keeps semantics; only collective
    placement changes)."""
    b = _fit(c, s[0], c.batch_axes)
    h = _fit(c, s[2], "model")
    if h is not None:
        return P(b, None, h, None)
    axes_all = c.batch_axes + ("model",)
    b2 = _fit(c, s[0], axes_all)
    used = set(b2) if isinstance(b2, tuple) else ({b2} if b2 else set())
    rest = tuple(a for a in axes_all if a not in used)
    sspec = _fit(c, s[1], rest) if rest else None
    return P(b2, sspec, None, None)


# activation kinds → per-dim logical roles
_ACT_RULES = {
    # [B, S, D] residual stream between layers (SP shards S over model)
    "residual": lambda c, s: P(_fit(c, s[0], c.batch_axes), _fit(c, s[1], "model") if c.use_sp else None, None),
    # [B, S, D] inside a block (seq gathered for attention/mixing)
    "hidden": lambda c, s: P(_fit(c, s[0], c.batch_axes), None, None),
    # [B, S, H, hd] attention activations — heads over model
    "heads": _heads_spec,
    # [B, S, F] ffn hidden — ff over model
    "ff": lambda c, s: P(_fit(c, s[0], c.batch_axes), None, _fit(c, s[2], "model")),
    # [B, S, V] logits — vocab over model
    "logits": lambda c, s: P(_fit(c, s[0], c.batch_axes), None, _fit(c, s[2], "model")),
    # [E, C, D] expert dispatch buffers — experts over model
    "experts": lambda c, s: P(_fit(c, s[0], "model"), None, None),
    # [G, t, D] MoE token groups — groups over the batch axes
    "moe_groups": lambda c, s: P(_fit(c, s[0], c.batch_axes), None, None),
    # [G, E, C, D] group-local dispatch buffers — G over batch, E over model
    "moe_dispatch": lambda c, s: P(
        _fit(c, s[0], c.batch_axes), _fit(c, s[1], "model"), None, None
    ),
    # KV cache [B, S, H, hd]: batch if divisible, else seq (context parallel)
    "kv_cache": lambda c, s: _kv_cache_spec(c, s),
}


def _kv_cache_spec(c: ShardingCtx, s):
    b_axes = _fit(c, s[0], c.batch_axes)
    h = _fit(c, s[2], "model")
    if b_axes is not None:
        if h is not None:
            return P(b_axes, None, h, None)
        # heads don't divide TP: context-parallel the cache sequence over
        # 'model' — decode attention merges seq-sharded partials via LSE
        return P(b_axes, _fit(c, s[1], "model"), None, None)
    # batch too small (long-context, B=1): context-parallel over 'data'
    return P(None, _fit(c, s[1], "data"), h, None)


def cp_axis_for_cache(shape) -> Optional[str]:
    """Mesh axis the kv_cache rule puts on the SEQUENCE dim of a
    [B, S, H, hd] cache (context parallelism), or None.

    This is the selector for the cross-device FLASH-D merge paths
    (`repro.distributed.context`): when the rules engine decides a cache is
    seq-sharded (batch too small, or heads not divisible by TP), attention
    must merge per-shard (O, Λ) partials instead of gathering the cache."""
    ctx = active_ctx()
    if ctx is None or ctx.mesh is None or not getattr(ctx, "use_cp", True):
        return None
    if len(shape) != 4:
        return None
    spec = _kv_cache_spec(ctx, tuple(shape))
    ax = spec[1] if len(spec) > 1 else None
    if isinstance(ax, tuple):
        ax = ax[0] if len(ax) == 1 else None
    if ax is None:
        return None
    n = ctx.axis_size(ax)
    return ax if n > 1 and shape[1] % n == 0 else None


def cp_batch_axes_for_cache(shape) -> Optional[Tuple[str, ...]]:
    """Mesh axes the kv_cache rule puts on the BATCH dim of a [B, S, H, hd]
    cache. The context-parallel paths keep this sharding inside their
    shard_map (heads-not-divisible CP shards batch over data AND seq over
    model — replicating batch there would re-gather the cache)."""
    ctx = active_ctx()
    if ctx is None or ctx.mesh is None or len(shape) != 4:
        return None
    ax = _kv_cache_spec(ctx, tuple(shape))[0]
    if ax is None:
        return None
    return (ax,) if isinstance(ax, str) else tuple(ax)


_TP_KINDS = ("ff", "heads", "logits", "experts", "moe_dispatch")


def spec_for(kind: str, shape: Sequence[int]) -> Optional[P]:
    ctx = active_ctx()
    if ctx is None:
        return None
    if not getattr(ctx, "tp_activations", True) and kind in _TP_KINDS:
        # full-DP activations: batch over every axis that divides
        b = _fit(ctx, shape[0], ctx.batch_axes + ("model",))
        return P(b, *([None] * (len(shape) - 1)))
    return _ACT_RULES[kind](ctx, tuple(shape))


def shard(x: jax.Array, kind: str) -> jax.Array:
    """Apply a logical sharding constraint; identity with no active ctx."""
    spec = spec_for(kind, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter sharding: path-name driven rules
# ---------------------------------------------------------------------------

def _param_rule(ctx: ShardingCtx, path: str, shape: Tuple[int, ...]) -> P:
    """TP dim from the weight's role; FSDP on the largest remaining dim."""
    fsdp = ctx.fsdp_axis
    nd = len(shape)
    tp_dim = None  # which dim gets 'model'

    def last(*names):
        return any(path.endswith(n) or f".{n}." in path or f"/{n}" in path for n in names)

    # embeddings / lm head: vocab over model
    if last("embed", "lm_head"):
        tp_dim = 0 if shape[0] > shape[-1] else nd - 1
    # column-parallel (out-dim sharded): q/k/v/gate/up, moe wi, router
    elif last("wq", "wk", "wv", "wg", "wu", "w_in", "w_gate"):
        tp_dim = nd - 1
    # row-parallel (in-dim sharded): output projections / down proj
    elif last("wo", "wd", "w_out"):
        tp_dim = nd - 2 if nd >= 2 else None
    elif last("router"):
        tp_dim = None  # small; replicate
    # moe expert stacks [E, d, f]: shard E over model
    if last("experts") and nd == 3:
        tp_dim = 0

    spec = [None] * nd
    if tp_dim is not None and nd >= 1 and "model" in ctx.axis_sizes:
        if shape[tp_dim] % ctx.axis_size("model") == 0:
            spec[tp_dim] = "model"
    # FSDP: biggest dim not already sharded (params ≥ 2 dims, skip tiny)
    if fsdp and nd >= 2 and int(np.prod(shape)) >= 2 ** 16:
        cands = sorted(range(nd), key=lambda i: -shape[i])
        for i in cands:
            if spec[i] is None and shape[i] % ctx.axis_size(fsdp) == 0:
                spec[i] = fsdp
                break
    return P(*spec)


def param_specs(params_shapes) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec tree for a params(-shaped) tree. Requires active ctx."""
    ctx = active_ctx()
    if ctx is None:
        raise RuntimeError("param_specs needs an active sharding context")
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        name = "/".join(
            getattr(p, "key", getattr(p, "name", str(getattr(p, "idx", p))))
            for p in path
        )
        shape = tuple(leaf.shape)
        # stacked-layer leading dim [n_blocks, ...]: rule applies to the rest
        if name.startswith("blocks") or "/blocks/" in name or name.startswith("enc_blocks") or name.startswith("dec_blocks"):
            inner = _param_rule(ctx, name, shape[1:])
            specs.append(P(None, *inner))
        else:
            specs.append(_param_rule(ctx, name, shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding_tree(params_shapes):
    ctx = active_ctx()
    specs = param_specs(params_shapes)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / decode-cache sharding
# ---------------------------------------------------------------------------

def batch_specs(batch_shapes):
    """Inputs [B, ...]: batch over ('pod','data') when divisible."""
    ctx = active_ctx()

    def rule(leaf):
        b = _fit(ctx, leaf.shape[0], ctx.batch_axes)
        return P(b, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(rule, batch_shapes)


def cache_specs_tree(cache_shapes):
    """Decode caches: leading [n_blocks] unsharded; KV [nb,B,S,H,hd] shards
    batch (or seq when B=1 — context parallel) + heads; recurrent states
    [nb,B,...] shard batch."""
    ctx = active_ctx()

    def rule(leaf):
        s = leaf.shape
        if len(s) == 5:  # [nb, B, S, H, hd] attention KV
            inner = _kv_cache_spec(ctx, s[1:])
            return P(None, *inner)
        if len(s) >= 2:  # recurrent state [nb, B, ...]
            b = _fit(ctx, s[1], ctx.batch_axes)
            return P(None, b, *([None] * (len(s) - 2)))
        return P(*([None] * len(s)))

    return jax.tree.map(rule, cache_shapes)


def to_named(spec_tree):
    ctx = active_ctx()
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
