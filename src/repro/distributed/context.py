"""Context-parallel attention: cross-device FLASH-D sigmoid merge.

Long-context prefill and decode on a sequence-sharded KV cache, built from
two primitives (DESIGN.md §4.1):

`ring_prefill` — shard_map over a seq-sharded Q/K/V with a `ppermute` ring
  schedule. Each device keeps its q shard (and its (O, Λ) carry) resident;
  KV shards rotate one neighbor per hop, each hop running the per-shard
  forward kernel and folding the hop's (O, Λ) into the carry with the §2.2
  sigmoid blend. No running-max exchange, no rescale pass, no final
  division — the wire carries exactly one KV shard per hop and nothing
  else. The canonical +1 rotation puts every device's KV shard exactly
  `h` shards behind its q shard at hop h, so the hop's mask offset is the
  *static* value h·shard and structured masks prune hops at trace time
  (a sliding window only needs ⌈window/shard⌉ + 1 hops of the full ring);
  wrapped shards (device i < h) are strictly future under causal-family
  masks and skip the kernel launch behind a `lax.cond`.

`cp_decode` — each device computes its shard's decode partial (o_p, λ_p)
  with the split-K kernel (`return_lam=True` exposes the merged Λ; the
  `start` bound clips globally-windowed live regions to the shard), then a
  log-depth cross-device butterfly of `ppermute`s merges partials with the
  same blend — the blend is associative AND commutative in (O, Λ), so the
  XOR-partner reduction is exact. log₂(n) hops of (O, Λ)-sized messages
  ([B, Hq, dv] + [B, Hq]) replace any gather of cache- or score-sized
  tensors. Non-power-of-two device counts fall back to one all_gather of
  the partials + the log-depth tree merge.

Both run on a simulated host-device mesh (CPU, Pallas interpret mode) and
unmodified on a real TPU ring. `repro.core.attention` routes here when the
active `ShardingCtx` seq-shards the cache (see `sharding.cp_axis_for_cache`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.blockwise import (
    MaskSpec,
    NEG_INF,
    blockwise_fa2,
    blockwise_flashd,
    merge_pair,
    merge_partials,
)

__all__ = [
    "ring_prefill",
    "cp_decode",
    "maybe_ring_prefill",
    "maybe_cp_decode",
    "ring_applicable",
    "cp_decode_applicable",
]

_CAUSAL_FAMILY = ("causal", "local", "chunked")


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (replication checks off: the pallas
    calls and collectives inside have no registered replication rules)."""
    if hasattr(jax, "shard_map"):  # jax ≥ 0.6
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
            )
        except TypeError:  # pragma: no cover — kwarg drift
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def _resolve_mesh(mesh: Optional[Mesh]) -> Mesh:
    if mesh is not None:
        return mesh
    from repro.distributed.sharding import active_ctx  # lazy: no cycle

    ctx = active_ctx()
    if ctx is None or ctx.mesh is None:
        raise ValueError("context-parallel attention needs a mesh "
                         "(argument or active ShardingCtx)")
    return ctx.mesh


def _axis_size(mesh: Mesh, axis: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def ring_applicable(q_shape, k_shape, mask: MaskSpec, n_shards: int) -> bool:
    """Can ring_prefill handle these operands? (Static check — used by
    `core.attention.flash_attention` before routing.)"""
    sq, skv = q_shape[1], k_shape[1]
    if n_shards <= 1 or sq % n_shards or skv % n_shards:
        return False
    if mask.kind in _CAUSAL_FAMILY:
        # shard-offset algebra needs aligned q/kv shards (self-attention)
        if sq != skv:
            return False
        if mask.kind == "chunked" and (skv // n_shards) % max(mask.chunk, 1):
            return False  # hop offsets shift chunk boundaries
    return True


def cp_decode_applicable(cache_shape, n_shards: int) -> bool:
    return n_shards > 1 and cache_shape[1] % n_shards == 0


# ---------------------------------------------------------------------------
# per-shard forward (one ring hop's local attention)
# ---------------------------------------------------------------------------

def _shard_fwd(q, k, v, *, mask, scale, impl, block_q, block_k, skip, interpret):
    """Kernel-layout forward on one KV shard → (o [B,Hq,S,dv] f32, Λ f32)."""
    if impl in ("flashd_pallas", "fa2_pallas"):
        from repro.kernels.fa2_fwd import fa2_fwd_pallas  # lazy: no cycle
        from repro.kernels.flashd_fwd import flashd_fwd_pallas

        fn = flashd_fwd_pallas if impl == "flashd_pallas" else fa2_fwd_pallas
        kw = dict(mask=mask, scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interpret)
        if impl == "flashd_pallas":
            kw["skip"] = skip
        o, lam = fn(q, k, v, **kw)
        return o.astype(jnp.float32), lam
    if impl == "naive":
        from repro.kernels.ref import attention_ref  # lazy: no cycle

        o, lam = attention_ref(q, k, v, mask=mask, scale=scale)
        return o.astype(jnp.float32), lam
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    base = blockwise_flashd if impl == "flashd" else blockwise_fa2
    fn = functools.partial(base, mask=mask, scale=scale,
                           block_q=block_q, block_k=block_k)
    if impl == "flashd":
        fn = functools.partial(fn, skip=skip)
    fn = jax.vmap(fn, in_axes=(0, None, None))  # over G
    fn = jax.vmap(fn, in_axes=(0, 0, 0))  # over Hkv
    fn = jax.vmap(fn, in_axes=(0, 0, 0))  # over B
    o, lam = fn(q.reshape(b, hkv, g, sq, d), k, v)
    dv_ = o.shape[-1]
    return o.reshape(b, hq, sq, dv_), lam.reshape(b, hq, sq)


# ---------------------------------------------------------------------------
# ring prefill
# ---------------------------------------------------------------------------

def ring_prefill(
    q: jax.Array,  # [B, Sq, Hq, d]   (model layout, like flash_attention)
    k: jax.Array,  # [B, Skv, Hkv, d]
    v: jax.Array,  # [B, Skv, Hkv, dv]
    *,
    axis: str,
    mesh: Optional[Mesh] = None,
    mask: MaskSpec = MaskSpec("causal"),
    scale: Optional[float] = None,
    impl: str = "flashd_pallas",
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    skip: bool = False,
    batch_axes: Optional[Tuple[str, ...]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Context-parallel prefill: per-shard kernels + cross-device Λ-merge.

    Returns o [B, Sq, Hq, dv], sequence-sharded over `axis` like q (and
    batch-sharded over `batch_axes` when given — a batch+seq-sharded
    operand set must keep its batch sharding inside the shard_map, or the
    unmentioned dims would be gathered). Wire per hop = one KV shard
    (ppermute); the (O, Λ) carry never moves — it stays with its q shard.
    Forward-only (serving/prefill path): the ring schedule has no
    registered VJP.
    """
    mesh = _resolve_mesh(mesh)
    n = _axis_size(mesh, axis)
    if not ring_applicable(q.shape, k.shape, mask, n):
        raise ValueError(
            f"ring_prefill: {q.shape}/{k.shape} with {mask.kind!r} mask not "
            f"context-parallelizable over {n} shards"
        )
    if scale is None:
        scale = float(1.0 / (q.shape[-1] ** 0.5))
    if interpret is None:
        from repro.kernels.ops import on_tpu  # lazy: no cycle

        interpret = not on_tpu()
    sq_sh, skv_sh = q.shape[1] // n, k.shape[1] // n
    if block_q is None or block_k is None:
        from repro.kernels.tuning import choose_ring_schedule  # lazy: no cycle

        sched = choose_ring_schedule(
            sq_sh, skv_sh, q.shape[-1], v.shape[-1], n_devices=n, mask=mask
        )
        block_q = sched.block_q if block_q is None else block_q
        block_k = sched.block_k if block_k is None else block_k
        n_hops = sched.n_hops
    else:
        from repro.kernels.tuning import choose_ring_schedule

        n_hops = choose_ring_schedule(
            sq_sh, skv_sh, q.shape[-1], v.shape[-1], n_devices=n, mask=mask
        ).n_hops
    block_q = min(block_q, sq_sh)
    block_k = min(block_k, skv_sh)
    causal_family = mask.kind in _CAUSAL_FAMILY
    perm = [(j, (j + 1) % n) for j in range(n)]

    def local_fn(q_l, k_l, v_l):
        # kernel layout for the per-shard attention
        qk = q_l.transpose(0, 2, 1, 3)  # [B, Hq, sq_sh, d]
        kk = k_l.transpose(0, 2, 1, 3)
        vk = v_l.transpose(0, 2, 1, 3)
        idx = jax.lax.axis_index(axis)
        b, hq = qk.shape[0], qk.shape[1]

        o = lam = None  # hop 0 seeds the carry (always live everywhere)
        for h in range(n_hops):
            # hop h: resident KV shard is h shards behind the q shard, so
            # every position offset is the static h·skv_sh (wrapped shards
            # are strictly future under causal-family masks — dead below)
            hop_mask = dataclasses.replace(
                mask,
                kind=("full" if _hop_fully_visible(mask, h, sq_sh, skv_sh)
                      else mask.kind),
                q_offset=mask.q_offset + h * skv_sh,
            )
            run = functools.partial(
                _shard_fwd, mask=hop_mask, scale=scale, impl=impl,
                block_q=block_q, block_k=block_k, skip=skip,
                interpret=interpret,
            )
            if causal_family and h > 0:
                # devices i < h hold a wrapped (future) shard: skip the
                # kernel launch entirely, contribute a dead partial
                def _dead(kv, _b=b, _hq=hq, _dv=vk.shape[-1]):
                    return (
                        jnp.zeros((_b, _hq, sq_sh, _dv), jnp.float32),
                        jnp.full((_b, _hq, sq_sh), NEG_INF, jnp.float32),
                    )

                o_p, lam_p = jax.lax.cond(
                    idx >= h, lambda kv: run(qk, kv[0], kv[1]), _dead, (kk, vk)
                )
            else:
                o_p, lam_p = run(qk, kk, vk)
            o, lam = (o_p, lam_p) if o is None else merge_pair((o, lam), (o_p, lam_p))
            if h < n_hops - 1:  # rotate the KV shard one neighbor over
                kk = jax.lax.ppermute(kk, axis, perm)
                vk = jax.lax.ppermute(vk, axis, perm)
        return o.transpose(0, 2, 1, 3).astype(q_l.dtype)

    seq_spec = P(batch_axes, axis, None, None)
    return _shard_map(
        local_fn, mesh,
        in_specs=(seq_spec, seq_spec, seq_spec),
        out_specs=seq_spec,
    )(q, k, v)


def _hop_fully_visible(mask: MaskSpec, h: int, sq_sh: int, skv_sh: int) -> bool:
    """Static: is hop h's whole shard-vs-shard block inside the mask (for
    non-wrapped devices)? Then the kernel runs mask-free ('full')."""
    if h == 0 or mask.kind not in _CAUSAL_FAMILY:
        return False
    hop = dataclasses.replace(mask, q_offset=mask.q_offset + h * skv_sh)
    return hop.block_fully_visible(0, sq_sh, 0, skv_sh)


# ---------------------------------------------------------------------------
# context-parallel decode
# ---------------------------------------------------------------------------

def maybe_cp_decode(q, k_cache, v_cache, cache_len, *, scale=None, window=0,
                    chunk=0, n_splits=None, use_kernel=True):
    """The one selection point for context-parallel decode: returns
    `cp_decode(...)` iff the active ShardingCtx's kv_cache rule seq-shards
    this cache (`sharding.cp_axis_for_cache`), else None — callers fall
    through to their single-device path. Keeps the routing decision out of
    `core.attention` / `models.transformer`, which would otherwise each
    re-implement it. The cache's batch sharding (if any) is preserved
    inside the shard_map."""
    from repro.distributed.sharding import (
        active_ctx, cp_axis_for_cache, cp_batch_axes_for_cache,
    )

    ctx = active_ctx()
    if ctx is None:
        return None
    axis = cp_axis_for_cache(k_cache.shape)
    if axis is None:
        return None
    return cp_decode(
        q, k_cache, v_cache, cache_len, axis=axis, mesh=ctx.mesh, scale=scale,
        window=window, chunk=chunk, n_splits=n_splits, use_kernel=use_kernel,
        batch_axes=cp_batch_axes_for_cache(k_cache.shape),
    )


def maybe_ring_prefill(q, k, v, *, mask, scale=None, impl="flashd",
                       block_q=None, block_k=None, skip=False):
    """Selection point for context-parallel prefill, the `maybe_cp_decode`
    counterpart: returns `ring_prefill(...)` iff the active ShardingCtx
    opts in (`cp_prefill=True`), its kv_cache rule seq-shards these
    operands, and the ring schedule applies (divisible shards, aligned
    causal-family masks) — else None."""
    from repro.distributed.sharding import (
        active_ctx, cp_axis_for_cache, cp_batch_axes_for_cache,
    )

    ctx = active_ctx()
    if ctx is None or not getattr(ctx, "cp_prefill", False):
        return None
    axis = cp_axis_for_cache(k.shape)
    if axis is None or not ring_applicable(q.shape, k.shape, mask, ctx.axis_size(axis)):
        return None
    return ring_prefill(
        q, k, v, axis=axis, mesh=ctx.mesh, mask=mask, scale=scale, impl=impl,
        block_q=block_q, block_k=block_k, skip=skip,
        batch_axes=cp_batch_axes_for_cache(k.shape),
    )


def _jnp_shard_partial(q, k_sh, v_sh, hi, start, scale):
    """Pure-jnp per-shard decode partial (o_p [B,Hq,dv] f32, λ_p [B,Hq]) —
    the kernel-free analogue of `flashd_decode._split_partial` for the
    einsum decode path."""
    b, hq, d = q.shape
    hkv, s_sh = k_sh.shape[2], k_sh.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_sh.astype(jnp.float32)) * scale
    pos = jnp.arange(s_sh)
    keep = (pos[None, :] >= start[:, None]) & (pos[None, :] < hi[:, None])
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[..., None])
    l = jnp.sum(p, axis=-1)
    lam = jnp.where(
        l > 0, m_safe + jnp.log(jnp.maximum(l, jnp.finfo(jnp.float32).tiny)),
        NEG_INF,
    )
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_sh.astype(jnp.float32))
    o = o * jnp.where(l > 0, jnp.exp(m_safe - lam), 0.0)[..., None]
    return o.reshape(b, hq, -1), lam.reshape(b, hq)


def cp_decode(
    q: jax.Array,  # [B, 1, Hq, d] or [B, Hq, d]
    k_cache: jax.Array,  # [B, S, Hkv, d]  — sequence-sharded over `axis`
    v_cache: jax.Array,  # [B, S, Hkv, dv]
    cache_len: jax.Array,  # [B] or scalar — GLOBAL valid length
    *,
    axis: str,
    mesh: Optional[Mesh] = None,
    scale: Optional[float] = None,
    window: int = 0,
    chunk: int = 0,
    n_splits: Optional[int] = None,
    use_kernel: bool = True,
    fused: bool = True,
    batch_axes: Optional[Tuple[str, ...]] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-token decode against a seq-sharded cache; partials merged
    with a log-depth cross-device butterfly of the FLASH-D blend.

    Each shard clips the global live region [lo_bound, cache_len) (window/
    chunk masks shrink lo_bound) to its own range — shard-empty shards
    produce dead partials (Λ = NEG_INF) that merge as identities, so
    ragged `cache_len` needs no special casing. Returns o shaped like q.

    `batch_axes` carries the cache's batch sharding (heads-not-divisible
    CP shards batch over data AND seq over model) through the shard_map —
    leaving those dims unspecified would gather the cache's batch dim,
    exactly the wire cost this path exists to avoid. The butterfly only
    reduces over `axis`; the output stays batch-sharded.
    """
    squeezed = q.ndim == 3
    if squeezed:
        q = q[:, None]
    b, _, hq, d = q.shape
    s_max = k_cache.shape[1]
    mesh = _resolve_mesh(mesh)
    n = _axis_size(mesh, axis)
    if not cp_decode_applicable(k_cache.shape, n):
        raise ValueError(f"cp_decode: cache seq {s_max} not shardable over {n}")
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if interpret is None:
        from repro.kernels.ops import on_tpu  # lazy: no cycle

        interpret = not on_tpu()
    s_sh = s_max // n
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))

    from repro.kernels.flashd_decode import _lo_bound  # lazy: no cycle

    def local_fn(q_g, k_sh, v_sh, cl):
        idx = jax.lax.axis_index(axis)
        shard_lo = idx * s_sh
        lo_g = jnp.broadcast_to(
            _lo_bound(cl, jnp.int32(0), window=window, chunk=chunk), cl.shape
        )
        start_l = jnp.clip(lo_g - shard_lo, 0, s_sh)
        hi_l = jnp.clip(cl - shard_lo, 0, s_sh)
        qk = q_g[:, 0]  # [B, Hq, d]
        if use_kernel:
            from repro.kernels.flashd_decode import flashd_decode_pallas

            o_p, lam_p = flashd_decode_pallas(
                qk, k_sh.transpose(0, 2, 1, 3), v_sh.transpose(0, 2, 1, 3),
                hi_l, start=start_l, scale=scale, n_splits=n_splits,
                fused=fused, return_lam=True, interpret=interpret,
            )
            o_p = o_p.astype(jnp.float32)
        else:
            o_p, lam_p = _jnp_shard_partial(qk, k_sh, v_sh, hi_l, start_l, scale)

        # log-depth cross-device tree: the blend is associative and
        # commutative, so XOR-partner butterflies all-reduce it exactly
        if n & (n - 1) == 0:
            step = 1
            while step < n:
                bp = [(j, j ^ step) for j in range(n)]
                o_r = jax.lax.ppermute(o_p, axis, bp)
                lam_r = jax.lax.ppermute(lam_p, axis, bp)
                o_p, lam_p = merge_pair((o_p, lam_p), (o_r, lam_r))
                step *= 2
        else:  # non-power-of-two ring: gather partials, tree-merge locally
            o_all = jax.lax.all_gather(o_p, axis)
            lam_all = jax.lax.all_gather(lam_p, axis)
            o_p, lam_p = merge_partials(o_all, lam_all)
        return o_p.astype(q_g.dtype)

    q_spec = P(batch_axes, None, None, None)
    kv_spec = P(batch_axes, axis, None, None)
    o = _shard_map(
        local_fn, mesh,
        in_specs=(q_spec, kv_spec, kv_spec, P(batch_axes)),
        out_specs=P(batch_axes, None, None),
    )(q, k_cache, v_cache, cache_len)
    o = o[:, None]  # [B, 1, Hq, dv]
    return o[:, 0] if squeezed else o
