"""GPipe pipeline parallelism over a named mesh axis (shard_map + ppermute).

`pipeline_apply(block_fn, stage_params, x, axis_name)` runs S pipeline
stages (S = mesh axis size) over M microbatches with the classic GPipe
schedule: M + S − 1 ticks, activations hop stage→stage via
`lax.ppermute` each tick. Differentiable — `jax.grad` through the tick
scan yields the GPipe backward (all-forward-then-all-backward) with
reverse ppermutes, so PP training needs no hand-written backward.

Layout contract: `stage_params` leaves have leading dim S sharded over
`axis_name`; inside shard_map each stage sees its slice. `x` is
[M, microbatch, ...] and is consumed by stage 0; outputs are emitted by the
last stage and gathered. Bubble fraction = (S−1)/(M+S−1) — the launcher
picks M ≥ 4·S so the bubble stays under ~20% (flagged in EXPERIMENTS.md).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_apply", "split_stages"]


def split_stages(stacked_layer_params, n_stages: int):
    """[L, ...] stacked layer params → [S, L/S, ...] per-stage stacks."""

    def re(x):
        l = x.shape[0]
        assert l % n_stages == 0, f"layers {l} % stages {n_stages} != 0"
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])

    return jax.tree.map(re, stacked_layer_params)


def pipeline_apply(
    block_fn: Callable,  # (stage_param_slice, x_mb) -> y_mb
    stage_params,  # leaves [S, ...] sharded over axis_name
    x: jax.Array,  # [M, mb, ...] microbatches
    *,
    mesh: Mesh,
    axis_name: str = "pod",
) -> jax.Array:
    """Returns y [M, mb, ...] = block_fn applied by every stage in sequence."""
    n_stages = mesh.shape[axis_name]
    m = x.shape[0]

    def stage_fn(params, xs):
        # params: [1, ...] this stage's slice; xs: [M, mb, ...] (full copy on
        # stage 0's shard; other stages ignore their input replica)
        params = jax.tree.map(lambda p: p[0], params)
        idx = jax.lax.axis_index(axis_name)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)  # current activation
        outs = jnp.zeros((m,) + mb_shape, xs.dtype)

        def tick(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t (if any) — others keep incoming
            inject = jnp.where(t < m, t, 0)
            state = jnp.where(idx == 0, xs[inject], state)
            y = block_fn(params, state)
            # last stage emits finished microbatch t-(S-1)
            out_t = t - (n_stages - 1)
            emit = jnp.logical_and(idx == n_stages - 1, out_t >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_t, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # hop: stage i → i+1 (ring permute; wraparound value unused)
            state = jax.lax.ppermute(
                y, axis_name, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (state, outs), None

        (state, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(m + n_stages - 1)
        )
        # outs live on the last stage; psum broadcasts (others hold zeros)
        return jax.lax.psum(outs, axis_name)

    param_specs = jax.tree.map(lambda _: P(axis_name), stage_params)
    fn = shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
