from repro.distributed import sharding
from repro.distributed.pipeline import pipeline_apply, split_stages
__all__ = ["sharding", "pipeline_apply", "split_stages"]
