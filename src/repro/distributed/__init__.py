from repro.distributed import context, sharding
from repro.distributed.context import cp_decode, ring_prefill
from repro.distributed.pipeline import pipeline_apply, split_stages
__all__ = [
    "context", "sharding", "pipeline_apply", "split_stages",
    "cp_decode", "ring_prefill",
]
