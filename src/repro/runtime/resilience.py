"""Fault tolerance, straggler mitigation, elastic scaling.

Three cooperating pieces, all exercised by tests:

* `run_resilient` — the restart loop: train inside a supervisor that, on a
  (simulated or real) failure, restores the latest checkpoint — including
  the data-iterator step — and continues. Guarantees: loss curve is
  identical to an uninterrupted run (bitwise, given deterministic data),
  because all step-state lives in the checkpoint.

* `StragglerMonitor` — per-step wall-time EWMA + robust z-score; flags
  slow steps/pods and invokes a callback (in production: exclude the pod
  from the next allocation / re-mesh; here: a recorded decision, so the
  policy is unit-testable without real stragglers).

* `ElasticPlan` — given a new device count, recompute the mesh shape and
  produce (mesh, shardings) so a checkpoint written at one scale restores
  at another (`repro.runtime.checkpoint.restore(..., shardings=...)`).
  Policy: keep 'model' as large as TP divisibility allows, fold the rest
  into 'data' (and 'pod' when >256 devices).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.runtime import checkpoint as ckpt

__all__ = ["run_resilient", "StragglerMonitor", "ElasticPlan", "plan_mesh"]


# ---------------------------------------------------------------------------
# restart-driven fault tolerance
# ---------------------------------------------------------------------------

def run_resilient(
    *,
    ckpt_dir: str,
    init_state_fn: Callable[[], object],
    step_fn: Callable[[object, int], Tuple[object, Dict]],
    total_steps: int,
    ckpt_every: int = 50,
    max_restarts: int = 10,
    fail_at: Optional[Callable[[int], bool]] = None,
) -> Tuple[object, List[Dict]]:
    """Supervised training loop. `step_fn(state, data_step)` returns
    (state, metrics). `fail_at(step)` raising simulates node failure."""
    history: List[Dict] = []
    restarts = 0
    while True:
        # (re)start: restore or init
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            template = init_state_fn()
            state, extra = ckpt.restore(ckpt_dir, template, step=last)
            step = int(extra["data_step"])
        else:
            state = init_state_fn()
            step = 0
        try:
            while step < total_steps:
                if fail_at is not None and fail_at(step):
                    raise RuntimeError(f"simulated node failure at step {step}")
                state, metrics = step_fn(state, step)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % ckpt_every == 0 or step == total_steps:
                    ckpt.save(ckpt_dir, step, state, extra={"data_step": step})
            return state, history
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            # truncate unpersisted history (those steps will be replayed)
            persisted = ckpt.latest_step(ckpt_dir) or 0
            history = [h for h in history if h["step"] < persisted]


# ---------------------------------------------------------------------------
# straggler mitigation
# ---------------------------------------------------------------------------

class StragglerMonitor:
    """EWMA + MAD-based step-time anomaly detector with an action hook."""

    def __init__(
        self,
        *,
        threshold: float = 3.0,
        warmup: int = 5,
        ewma_alpha: float = 0.2,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
    ):
        self.threshold = threshold
        self.warmup = warmup
        self.alpha = ewma_alpha
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.deviations: List[float] = []
        self.flagged: List[int] = []
        self._n = 0
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int, elapsed: Optional[float] = None):
        dt = elapsed if elapsed is not None else time.monotonic() - self._t0
        self.observe(step, dt)

    def observe(self, step: int, dt: float):
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return
        dev = abs(dt - self.ewma)
        self.deviations.append(dev)
        mad = float(np.median(self.deviations[-100:])) if self.deviations else 0.0
        if self._n > self.warmup and mad > 0 and (dt - self.ewma) / (1.4826 * mad) > self.threshold:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        # EWMA updated with clipped sample so one straggler doesn't poison it
        clipped = min(dt, self.ewma * 3)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * clipped


# ---------------------------------------------------------------------------
# elastic scaling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]


def plan_mesh(n_devices: int, *, prefer_model: int = 16, pod_size: int = 256) -> ElasticPlan:
    """Largest power-of-two model axis ≤ prefer_model that divides n_devices;
    remaining factor → data; >1 pod_size multiples get an explicit pod axis."""
    model = prefer_model
    while model > 1 and n_devices % model:
        model //= 2
    rest = n_devices // model
    if n_devices > pod_size and rest % (n_devices // pod_size) == 0:
        pods = n_devices // pod_size
        data = rest // pods
        return ElasticPlan((pods, data, model), ("pod", "data", "model"))
    return ElasticPlan((rest, model), ("data", "model"))
