"""Fault tolerance, straggler mitigation, elastic scaling.

Cooperating pieces (serving chaos: tests/test_chaos.py; training
resilience: tests/test_train_resilience.py, tests/test_lifecycle.py,
tests/test_checkpoint_resilience.py):

* `run_resilient` — the restart loop: train inside a supervisor that, on a
  (simulated or real) failure, restores the latest VERIFIED checkpoint —
  including the data-iterator step — and continues. Guarantees: loss curve
  is identical to an uninterrupted run (bitwise, given deterministic
  data), because all step-state lives in the checkpoint; a corrupted
  newest checkpoint (failed CRC) falls back one interval instead of
  killing the run. `repro.train.resilient.train_resilient` layers the
  training-specific policy (fault-site checks, loss-spike rollback,
  status counters) on top of this supervisor.

* `RetryPolicy` — which exception types are retryable, how many times, and
  how long to back off between attempts (exponential with deterministic
  jitter). Shared by `run_resilient` (training restarts) and the serving
  engine's per-request retry path (DESIGN.md §3.7).

* `FaultInjector` — deterministic, seeded chaos: raises `InjectedFault` at
  named sites, either probabilistically (`rate`) or on an explicit
  per-site occurrence `schedule`. Serving sites (page_alloc /
  kernel_dispatch / device_step / host_sync) are threaded through the
  serve loops; training sites (data_batch / grad_step / optimizer_update /
  ckpt_save / collective) through the resilient train loop (DESIGN.md §6).
  `crash_after_checks` additionally raises one `EngineCrash` — an
  exception the engine does *not* absorb — to exercise crash recovery +
  snapshot/restore.

* `DivergenceRollback` — raised by the train loop's loss-spike detector;
  retryable under the default policy, so the supervisor restores the last
  good checkpoint instead of training through corrupted state.

* `StragglerMonitor` — per-step wall-time EWMA + robust z-score; flags
  slow steps/pods and invokes a callback (in production: exclude the pod
  from the next allocation / re-mesh; here: a recorded decision, so the
  policy is unit-testable without real stragglers). The serving engine
  reuses it as a per-step watchdog (`Engine.stats()["slow_steps"]`).

* `ElasticPlan` — given a new device count, recompute the mesh shape and
  produce (mesh, shardings) so a checkpoint written at one scale restores
  at another (`repro.runtime.checkpoint.restore(..., shardings=...)`).
  Policy: keep 'model' as large as TP divisibility allows, fold the rest
  into 'data' (and 'pod' when >256 devices).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Type

import jax
import numpy as np

from repro.runtime import checkpoint as ckpt

__all__ = [
    "run_resilient",
    "RetryPolicy",
    "FaultInjector",
    "InjectedFault",
    "EngineCrash",
    "DivergenceRollback",
    "StragglerMonitor",
    "ElasticPlan",
    "plan_mesh",
]


# ---------------------------------------------------------------------------
# retry policy (shared by training restarts and the serving retry path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How failures are retried: which exception types, how many attempts,
    and the exponential-backoff/jitter schedule between them.

    Jitter is deterministic (seeded per (seed, attempt)) so retries stay
    reproducible — the same property the FaultInjector relies on.
    """

    max_retries: int = 3
    backoff_base_s: float = 0.0  # 0 → no sleeping (unit-test friendly)
    backoff_max_s: float = 30.0
    jitter: float = 0.0  # ±fraction of the delay
    retryable: Tuple[Type[BaseException], ...] = (RuntimeError,)

    def is_retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, self.retryable)

    def delay_s(self, attempt: int, *, seed: int = 0) -> float:
        """Backoff before retry number `attempt` (1-based)."""
        if self.backoff_base_s <= 0:
            return 0.0
        d = min(self.backoff_base_s * (2 ** max(attempt - 1, 0)), self.backoff_max_s)
        if self.jitter > 0:
            u = float(np.random.default_rng((seed, attempt)).random())
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return d


# ---------------------------------------------------------------------------
# deterministic chaos injection
# ---------------------------------------------------------------------------

class InjectedFault(RuntimeError):
    """A simulated recoverable failure raised by `FaultInjector.check`."""

    def __init__(self, site: str, rid: Optional[int] = None, index: int = -1):
        super().__init__(f"injected fault at {site!r} (occurrence {index}, rid={rid})")
        self.site = site
        self.rid = rid
        self.index = index


class EngineCrash(RuntimeError):
    """A simulated *unrecoverable* failure — the engine must not absorb it.

    Used to exercise the crash-recovery path: the serve loop's exception
    handler rolls live requests back into the queue (pages donated), the
    exception propagates, and `Engine.snapshot()/restore()` resumes warm.
    """


class DivergenceRollback(RuntimeError):
    """Loss-spike divergence detected by the resilient train loop.

    A RuntimeError subclass, so the default `RetryPolicy` treats it as
    retryable: `run_resilient` restores the last good checkpoint and
    replays — rolling back past silently-corrupted state instead of
    training through it (DESIGN.md §6)."""

    def __init__(self, step: int, loss: float, reference: float):
        super().__init__(
            f"loss spike at step {step}: {loss:.4g} vs reference {reference:.4g}"
        )
        self.step = step
        self.loss = loss
        self.reference = reference


class FaultInjector:
    """Deterministic, seeded fault source for the serving AND training
    loops.

    Two triggering modes, composable:

    * `rate` — each `check(site)` call fires with probability `rate`, drawn
      from one seeded stream (deterministic given the call sequence).
    * `schedule` — explicit `(site, occurrence_index)` pairs; the N-th
      `check` at that site fires regardless of `rate`. This is what the
      chaos tests use to target a specific request or step.

    Sites: the first four are the serve-loop sites (PR 6); the train sites
    model where a training-pipeline failure surfaces — the input pipeline
    (`data_batch`), the fwd/bwd dispatch (`grad_step`), the optimizer
    apply (`optimizer_update`), the checkpoint write (`ckpt_save`), and a
    cross-device reduction (`collective`). The resilient train loop checks
    them once per step in that order (repro.train.resilient).

    `crash_after_checks=N` raises `EngineCrash` on the N-th check overall
    (0-based), once — simulating a hard crash mid-serve.
    """

    SITES = (
        "page_alloc", "kernel_dispatch", "device_step", "host_sync",
        "data_batch", "grad_step", "optimizer_update", "ckpt_save",
        "collective",
    )
    TRAIN_SITES = (
        "data_batch", "grad_step", "optimizer_update", "ckpt_save",
        "collective",
    )

    def __init__(
        self,
        rate: float = 0.0,
        seed: int = 0,
        *,
        sites: Optional[Iterable[str]] = None,
        schedule: Iterable[Tuple[str, int]] = (),
        crash_after_checks: Optional[int] = None,
    ):
        self.rate = float(rate)
        self.seed = int(seed)
        self.sites = frozenset(sites) if sites is not None else frozenset(self.SITES)
        unknown = self.sites - set(self.SITES)
        if unknown:
            raise ValueError(f"unknown fault sites: {sorted(unknown)}")
        self._rng = np.random.default_rng(seed)
        self._schedule: Dict[str, set] = {}
        for site, idx in schedule:
            if site not in self.SITES:
                raise ValueError(f"unknown fault site in schedule: {site!r}")
            self._schedule.setdefault(site, set()).add(int(idx))
        self.crash_after_checks = crash_after_checks
        self._crashed = False
        self.calls: Dict[str, int] = {s: 0 for s in self.SITES}
        self.fired: Dict[str, int] = {s: 0 for s in self.SITES}

    @property
    def total_calls(self) -> int:
        return sum(self.calls.values())

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def check(self, site: str, rid: Optional[int] = None) -> None:
        """Raise `InjectedFault` if this occurrence of `site` is faulted."""
        if site not in self.SITES:
            raise ValueError(f"unknown fault site: {site!r}")
        total = self.total_calls
        idx = self.calls[site]
        self.calls[site] += 1
        if (
            self.crash_after_checks is not None
            and not self._crashed
            and total >= self.crash_after_checks
        ):
            self._crashed = True
            raise EngineCrash(f"injected crash at check #{total} (site {site!r})")
        fire = idx in self._schedule.get(site, ())
        if not fire and self.rate > 0.0 and site in self.sites:
            fire = float(self._rng.random()) < self.rate
        if fire:
            self.fired[site] += 1
            raise InjectedFault(site, rid=rid, index=idx)


# ---------------------------------------------------------------------------
# restart-driven fault tolerance
# ---------------------------------------------------------------------------

def run_resilient(
    *,
    ckpt_dir: str,
    init_state_fn: Callable[[], object],
    step_fn: Callable[[object, int], Tuple[object, Dict]],
    total_steps: int,
    ckpt_every: int = 50,
    max_restarts: int = 10,
    fail_at: Optional[Callable[[int], bool]] = None,
    retry: Optional[RetryPolicy] = None,
    keep: Optional[int] = None,
    on_save: Optional[Callable[[int, object], None]] = None,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
) -> Tuple[object, List[Dict]]:
    """Supervised training loop. `step_fn(state, data_step)` returns
    (state, metrics). `fail_at(step)` raising simulates node failure.

    `retry` controls which exception types trigger a restart (default:
    `RuntimeError` only, the historical behavior) and the jittered backoff
    slept between restarts; `max_restarts` still caps the restart count.

    Restores go through checksum verification with fallback: the newest
    checkpoint that VERIFIES wins, so a torn/corrupted save costs at most
    one checkpoint interval. `keep=N` garbage-collects all but the newest
    N checkpoints after each successful save. `on_save(step, state)` runs
    just before each checkpoint write (a fault-injection point: an
    exception there aborts the save and is handled like any step failure);
    `on_restart(restart_index, exc)` observes each supervised restart.
    """
    policy = retry if retry is not None else RetryPolicy()
    history: List[Dict] = []
    restarts = 0
    while True:
        # (re)start: restore the newest VERIFIED checkpoint, or init fresh
        valid = ckpt.valid_steps(ckpt_dir)
        if valid:
            template = init_state_fn()
            state, extra = ckpt.restore(ckpt_dir, template, step=valid[-1])
            step = int(extra["data_step"])
        else:
            state = init_state_fn()
            step = 0
        try:
            while step < total_steps:
                if fail_at is not None and fail_at(step):
                    raise RuntimeError(f"simulated node failure at step {step}")
                state, metrics = step_fn(state, step)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % ckpt_every == 0 or step == total_steps:
                    if on_save is not None:
                        on_save(step, state)
                    ckpt.save(ckpt_dir, step, state, extra={"data_step": step})
                    if keep is not None:
                        for s in ckpt.valid_steps(ckpt_dir)[:-keep]:
                            import shutil as _sh

                            _sh.rmtree(
                                f"{ckpt_dir}/step_{s:08d}", ignore_errors=True
                            )
            return state, history
        except policy.retryable as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            delay = policy.delay_s(restarts)
            if delay > 0:
                time.sleep(delay)
            # truncate unpersisted history (those steps will be replayed)
            persisted = ckpt.valid_steps(ckpt_dir)
            last_good = persisted[-1] if persisted else 0
            history = [h for h in history if h["step"] < last_good]


# ---------------------------------------------------------------------------
# straggler mitigation
# ---------------------------------------------------------------------------

class StragglerMonitor:
    """EWMA + MAD-based step-time anomaly detector with an action hook."""

    def __init__(
        self,
        *,
        threshold: float = 3.0,
        warmup: int = 5,
        ewma_alpha: float = 0.2,
        on_straggler: Optional[Callable[[int, float, float], None]] = None,
    ):
        self.threshold = threshold
        self.warmup = warmup
        self.alpha = ewma_alpha
        self.on_straggler = on_straggler
        self.ewma: Optional[float] = None
        self.deviations: List[float] = []
        self.flagged: List[int] = []
        self._n = 0
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int, elapsed: Optional[float] = None):
        if elapsed is None:
            if self._t0 is None:  # end without start: nothing to measure
                return
            elapsed = time.monotonic() - self._t0
        self._t0 = None
        self.observe(step, elapsed)

    def observe(self, step: int, dt: float):
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return
        dev = abs(dt - self.ewma)
        self.deviations.append(dev)
        mad = float(np.median(self.deviations[-100:])) if self.deviations else 0.0
        if self._n > self.warmup and mad > 0 and (dt - self.ewma) / (1.4826 * mad) > self.threshold:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        # EWMA updated with clipped sample so one straggler doesn't poison it
        clipped = min(dt, self.ewma * 3)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * clipped


# ---------------------------------------------------------------------------
# elastic scaling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]


def plan_mesh(n_devices: int, *, prefer_model: int = 16, pod_size: int = 256) -> ElasticPlan:
    """Largest power-of-two model axis ≤ prefer_model that divides n_devices;
    remaining factor → data; >1 pod_size multiples get an explicit pod axis."""
    model = prefer_model
    while model > 1 and n_devices % model:
        model //= 2
    rest = n_devices // model
    if n_devices > pod_size and rest % (n_devices // pod_size) == 0:
        pods = n_devices // pod_size
        data = rest // pods
        return ElasticPlan((pods, data, model), ("pod", "data", "model"))
    return ElasticPlan((rest, model), ("data", "model"))
