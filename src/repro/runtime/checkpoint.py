"""Sharded checkpointing: per-leaf npz shards + JSON manifest, async save.

Layout:
    <dir>/step_<N>/manifest.json      {step, leaf names, shapes, dtypes,
                                       checksums, data_step, mesh_shape, extra}
    <dir>/step_<N>/shard_<host>.npz   this host's leaves (single-host runs
                                       write shard_0 with everything)

Fault-tolerance contract (tested):
  * atomic publish — writes go to step_<N>.tmp, renamed when complete; a
    crash mid-save never corrupts the latest checkpoint;
  * `latest_step` skips unpublished .tmp dirs and tolerates malformed
    step_* directory names (a stray `step_backup` dir must not take down
    every restore);
  * integrity — the manifest records a CRC32 per shard file; `restore`
    verifies before loading (`verify=False` opts out) and raises
    `CheckpointCorrupt` on a torn or bit-flipped shard. With no explicit
    `step`, restore falls back to the NEWEST checkpoint that verifies, so
    one corrupted save costs one interval, not the run;
  * async mode snapshots to host RAM synchronously (jax.device_get) and
    writes on a worker thread — training resumes immediately; a failed
    async save surfaces on the next `wait()`/`save_async()` and never
    garbage-collects the previous good checkpoint;
  * data-iterator state (a step counter, see repro.data) rides in the
    manifest so restarts resume the exact token stream;
  * `restore` can reshard to a DIFFERENT mesh: leaves are saved unsharded
    (host-gathered) and re-placed with the target sharding on load —
    this is what elastic re-scale uses (repro.runtime.elastic).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "save",
    "restore",
    "latest_step",
    "valid_steps",
    "verify_step",
    "CheckpointCorrupt",
    "CheckpointManager",
]


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed integrity verification (torn write, bit flip,
    missing shard). Raised by `restore`; `run_resilient` treats it like any
    other retryable failure and falls back to an older step."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[name] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(tree_like, flat: Dict[str, np.ndarray]):
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    consumed = set()
    for path, leaf in leaves_with_path:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        consumed.add(name)
        arr = flat[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs expected {leaf.shape}"
            )
        out.append(arr.astype(leaf.dtype))
    extra = sorted(set(flat) - consumed)
    if extra:
        raise ValueError(
            f"checkpoint has {len(extra)} leaves the target structure does not: "
            f"{extra[:5]}{'…' if len(extra) > 5 else ''}"
        )
    return jax.tree_util.tree_unflatten(treedef, out)


def _crc32_file(path: str) -> str:
    crc = 0
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(block, crc)
    return f"{crc & 0xFFFFFFFF:08x}"


def save(
    ckpt_dir: str,
    step: int,
    tree,
    *,
    extra: Optional[Dict[str, Any]] = None,
    host_index: int = 0,
) -> str:
    """Write a checkpoint. `tree=None` writes a metadata-only checkpoint
    (manifest + `extra`, no array shards) — used by the serving engine's
    `snapshot()`, whose state is pure-JSON (token streams, not KV arrays)."""
    flat = _flatten(tree) if tree is not None else {}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    checksums: Dict[str, str] = {}
    if tree is not None:
        shard = f"shard_{host_index}.npz"
        np.savez(os.path.join(tmp, shard), **flat)
        checksums[shard] = _crc32_file(os.path.join(tmp, shard))
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        "checksums": checksums,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _step_dirs(ckpt_dir: str) -> List[int]:
    """Published step numbers under `ckpt_dir`, ascending. Malformed
    `step_*` names (step_backup, step_old…) are skipped, not fatal."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            n = int(d[len("step_"):])
        except ValueError:
            continue
        if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(n)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _step_dirs(ckpt_dir)
    return steps[-1] if steps else None


def verify_step(ckpt_dir: str, step: int) -> bool:
    """True iff step exists and every manifest-listed shard matches its
    recorded CRC32. Pre-checksum checkpoints (no `checksums` key) verify
    as long as the manifest parses."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        return False
    for shard, crc in manifest.get("checksums", {}).items():
        path = os.path.join(d, shard)
        if not os.path.exists(path) or _crc32_file(path) != crc:
            return False
    return True


def valid_steps(ckpt_dir: str) -> List[int]:
    """Published steps that pass integrity verification, ascending."""
    return [s for s in _step_dirs(ckpt_dir) if verify_step(ckpt_dir, s)]


def restore(
    ckpt_dir: str,
    tree_like,
    *,
    step: Optional[int] = None,
    shardings=None,
    verify: bool = True,
) -> Tuple[Any, Dict[str, Any]]:
    """Load into the structure of `tree_like`; optionally re-place with
    `shardings` (a pytree of NamedSharding) for elastic re-meshing.
    `tree_like=None` loads only the manifest `extra` (metadata-only
    checkpoints, see `save`).

    With `verify=True` (default) shard checksums are validated first: an
    explicit `step` that fails raises `CheckpointCorrupt`; `step=None`
    falls back to the newest step that verifies (corruption costs one
    checkpoint interval, never the run)."""
    if step is None:
        candidates = _step_dirs(ckpt_dir)
        if not candidates:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        if verify:
            good = [s for s in candidates if verify_step(ckpt_dir, s)]
            if not good:
                raise CheckpointCorrupt(
                    f"no checkpoint under {ckpt_dir} passes verification "
                    f"(candidates: {candidates})"
                )
            step = good[-1]
        else:
            step = candidates[-1]
    elif verify and not verify_step(ckpt_dir, step):
        raise CheckpointCorrupt(f"checkpoint step {step} under {ckpt_dir} is corrupt")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if tree_like is None:
        return None, manifest["extra"]
    flat: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                flat.update({k: z[k] for k in z.files})
    tree = _unflatten(tree_like, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree, manifest["extra"]


class CheckpointManager:
    """Async saver: snapshot synchronously, write on a daemon thread.

    Error surfacing contract: a failed background save is re-raised on the
    next `wait()` (or the implicit `wait()` at the head of `save_async()`),
    and `_gc` only runs after a SUCCESSFUL save — a failure can never
    garbage-collect the previous good checkpoint."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, host_index: int = 0):
        self.dir = ckpt_dir
        self.keep = keep
        self.host_index = host_index
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, *, extra=None):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.dir, step, host_tree, extra=extra, host_index=self.host_index)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        for s in _step_dirs(self.dir)[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)
