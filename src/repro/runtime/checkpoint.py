"""Sharded checkpointing: per-leaf npz shards + JSON manifest, async save.

Layout:
    <dir>/step_<N>/manifest.json      {step, leaf names, shapes, dtypes,
                                       data_step, mesh_shape, extra}
    <dir>/step_<N>/shard_<host>.npz   this host's leaves (single-host runs
                                       write shard_0 with everything)

Fault-tolerance contract (tested):
  * atomic publish — writes go to step_<N>.tmp, renamed when complete; a
    crash mid-save never corrupts the latest checkpoint;
  * `latest_step` skips unpublished .tmp dirs;
  * async mode snapshots to host RAM synchronously (jax.device_get) and
    writes on a worker thread — training resumes immediately;
  * data-iterator state (a step counter, see repro.data) rides in the
    manifest so restarts resume the exact token stream;
  * `restore` can reshard to a DIFFERENT mesh: leaves are saved unsharded
    (host-gathered) and re-placed with the target sharding on load —
    this is what elastic re-scale uses (repro.runtime.elastic).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[name] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(tree_like, flat: Dict[str, np.ndarray]):
    names = list(_flatten(jax.eval_shape(lambda: tree_like)).keys()) if False else None
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, leaf in leaves_with_path:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs expected {leaf.shape}"
            )
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def save(
    ckpt_dir: str,
    step: int,
    tree,
    *,
    extra: Optional[Dict[str, Any]] = None,
    host_index: int = 0,
) -> str:
    """Write a checkpoint. `tree=None` writes a metadata-only checkpoint
    (manifest + `extra`, no array shards) — used by the serving engine's
    `snapshot()`, whose state is pure-JSON (token streams, not KV arrays)."""
    flat = _flatten(tree) if tree is not None else {}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    if tree is not None:
        np.savez(os.path.join(tmp, f"shard_{host_index}.npz"), **flat)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d[len("step_"):]))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    tree_like,
    *,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[Any, Dict[str, Any]]:
    """Load into the structure of `tree_like`; optionally re-place with
    `shardings` (a pytree of NamedSharding) for elastic re-meshing.
    `tree_like=None` loads only the manifest `extra` (metadata-only
    checkpoints, see `save`)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if tree_like is None:
        return None, manifest["extra"]
    flat: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                flat.update({k: z[k] for k in z.files})
    tree = _unflatten(tree_like, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree, manifest["extra"]


class CheckpointManager:
    """Async saver: snapshot synchronously, write on a daemon thread."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, host_index: int = 0):
        self.dir = ckpt_dir
        self.keep = keep
        self.host_index = host_index
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, *, extra=None):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.dir, step, host_tree, extra=extra, host_index=self.host_index)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d[len("step_"):])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)
