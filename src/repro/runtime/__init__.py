from repro.runtime import checkpoint
from repro.runtime.resilience import ElasticPlan, StragglerMonitor, plan_mesh, run_resilient
__all__ = ["checkpoint", "ElasticPlan", "StragglerMonitor", "plan_mesh", "run_resilient"]
