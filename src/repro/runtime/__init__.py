from repro.runtime import checkpoint
from repro.runtime.kvcache import CowCopy, PagedKVAllocator, PageError, pages_for
from repro.runtime.resilience import ElasticPlan, StragglerMonitor, plan_mesh, run_resilient
__all__ = [
    "checkpoint",
    "CowCopy", "PagedKVAllocator", "PageError", "pages_for",
    "ElasticPlan", "StragglerMonitor", "plan_mesh", "run_resilient",
]
