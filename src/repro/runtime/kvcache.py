"""Paged KV-cache allocator: global page pool, per-sequence block tables,
copy-on-write prefix sharing (DESIGN.md §3.4).

The serving engine's historical memory model reserved one contiguous
`max_len`-wide cache region per batch slot, so `max_batch × max_len` tokens
of KV memory were committed up front even when every live sequence was
short. This module replaces that with the vLLM memory model: KV lives in a
pool of fixed-size *pages* (`page_size` tokens each); a sequence owns an
ordered *block table* of page ids covering `ceil(len / page_size)` pages;
pages are allocated as the sequence grows and returned to the pool when it
finishes. FlashAttention-style kernels are indifferent to where KV tiles
live, and FLASH-D's division-free sigmoid merge blends partials from
non-contiguous pages with the same one-FMA carry as contiguous splits
(`kernels/flashd_decode.flashd_decode_paged_pallas`), so the kernel-side
cost of paging is just the block-table indirection.

This class is pure host-side bookkeeping — it never touches device arrays.
Device effects are communicated back to the caller as:

  * block tables (`table(seq)`) the engine mirrors into the device-side
    `tbl` operand of the paged decode kernel;
  * `CowCopy(src, dst)` records: the caller must copy page `src` → page
    `dst` in every layer's page arrays *before* the next write dispatch.

Sharing / copy-on-write semantics:

  * `admit(..., share_from=parent, shared_tokens=n)` makes the child's
    first `ceil(n / page_size)` table entries reference the parent's pages
    (refcount++). Full pages of the shared prefix are never written by
    either sequence again (writes only happen at positions ≥ the owner's
    length), so they are shared for their whole lifetime for free. The
    *boundary* page — shared only up to mid-page — is immediately
    copy-on-write'd for the child (one `CowCopy`), because the child's
    tail prefill writes into it.
  * Because the boundary page is copied at admit (child side) and full
    shared pages lie strictly below every owner's length, **no live
    sequence ever holds a writable shared page** — writers only touch
    positions ≥ their own length, and those always land on exclusively
    owned (or fresh) pages. `extend()` keeps a defensive CoW for the
    unreachable case anyway, and `check()` asserts the invariant.

Admission control: pages for the worst case (`reserve_tokens`, typically
prompt + max_new_tokens + decode-chunk slack) are *reserved* at admit so a
mid-flight sequence can never hit pool exhaustion (this engine has no
preemption). Reservations only turn into materialized pages as the
sequence actually grows (`extend`), which is what the pool-accounting
invariants measure.

Page id 0 is reserved as the *garbage page*: the engine points the table
rows of dead batch slots at it (and the kernel clamps out-of-table writes
to it), so lockstep decode steps of finished slots scribble harmlessly
instead of corrupting live pages.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["CowCopy", "PagedKVAllocator", "PageError", "pages_for"]

GARBAGE_PAGE = 0


class PageError(RuntimeError):
    """Pool exhausted or API misuse (admitting a live seq, growing a dead one)."""


@dataclasses.dataclass(frozen=True)
class CowCopy:
    """Device-side page copy the caller owes: pages[dst] ← pages[src]."""

    src: int
    dst: int


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages covering n_tokens (0 tokens → 0 pages)."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // page_size)


class PagedKVAllocator:
    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need ≥ 2 pages (page 0 is the garbage page)")
        if page_size < 1:
            raise ValueError("page_size must be ≥ 1")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list → recently-freed pages are reused first (warm VMEM/HBM)
        self._free: List[int] = list(range(n_pages - 1, GARBAGE_PAGE, -1))
        self._ref: List[int] = [0] * n_pages
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}
        self._reserved: Dict[int, int] = {}  # seq → reserved-but-unmaterialized pages

    # ---- accounting ----
    @property
    def free_pages(self) -> int:
        """Pages available to new admissions (excludes live reservations)."""
        return len(self._free) - sum(self._reserved.values())

    @property
    def pages_in_use(self) -> int:
        """Distinct pages currently materialized (shared pages count once)."""
        return sum(1 for r in self._ref if r > 0)

    @property
    def reserved_pages(self) -> int:
        """Pages promised to live sequences but not yet materialized."""
        return sum(self._reserved.values())

    @property
    def live_seqs(self) -> Tuple[int, ...]:
        return tuple(self._tables)

    def table(self, seq: int) -> List[int]:
        return list(self._tables[seq])

    def seq_len(self, seq: int) -> int:
        return self._lens[seq]

    def refcount(self, pid: int) -> int:
        return self._ref[pid]

    # ---- admission ----
    def can_admit(self, reserve_tokens: int, *, shared_tokens: int = 0) -> bool:
        """Would `admit` succeed? Shared full pages come from the parent;
        the boundary page (if any) costs a fresh CoW page, and everything
        past the shared prefix costs fresh pages."""
        return self._admit_cost(reserve_tokens, shared_tokens) <= self.free_pages

    def _admit_cost(self, reserve_tokens: int, shared_tokens: int) -> int:
        total = pages_for(reserve_tokens, self.page_size)
        full_shared = shared_tokens // self.page_size
        return total - full_shared  # boundary partial page needs its own copy

    def admit(
        self,
        seq: int,
        prompt_len: int,
        reserve_tokens: int,
        *,
        share_from: Optional[int] = None,
        shared_tokens: int = 0,
    ) -> List[CowCopy]:
        """Register `seq`, materialize pages covering `prompt_len`, reserve up
        to `reserve_tokens`. With `share_from`, the first `shared_tokens`
        positions alias the parent's pages (full pages by reference; the
        partial boundary page as an immediate CoW copy). Returns the device
        copies owed. Raises PageError when the pool cannot cover it."""
        if seq in self._tables:
            raise PageError(f"seq {seq} already admitted")
        if shared_tokens and share_from is None:
            raise PageError("shared_tokens needs share_from")
        reserve_tokens = max(reserve_tokens, prompt_len)
        if shared_tokens > prompt_len:
            raise PageError("cannot share more than the prompt")
        if share_from is not None and shared_tokens > self._lens.get(share_from, -1):
            raise PageError("cannot share beyond the parent's length")
        if not self.can_admit(reserve_tokens, shared_tokens=shared_tokens):
            raise PageError(
                f"pool exhausted: need {self._admit_cost(reserve_tokens, shared_tokens)}"
                f" pages, {self.free_pages} free"
            )

        table: List[int] = []
        cows: List[CowCopy] = []
        full_shared = shared_tokens // self.page_size
        if share_from is not None:
            parent_tbl = self._tables[share_from]
            for j in range(full_shared):
                pid = parent_tbl[j]
                self._ref[pid] += 1
                table.append(pid)
            if shared_tokens % self.page_size:
                # boundary page: child writes its tail into it → private copy
                dst = self._take_page()
                cows.append(CowCopy(src=parent_tbl[full_shared], dst=dst))
                table.append(dst)
        while len(table) < pages_for(prompt_len, self.page_size):
            table.append(self._take_page())
        self._tables[seq] = table
        self._lens[seq] = prompt_len
        self._reserved[seq] = pages_for(reserve_tokens, self.page_size) - len(table)
        return cows

    # ---- growth ----
    def extend(self, seq: int, new_len: int) -> List[CowCopy]:
        """Materialize pages so positions [len, new_len) are writable by
        `seq` alone: fresh pages from the reservation for new coverage, and
        a private CoW copy of the current tail page if another sequence
        still references it. Returns the device copies owed."""
        if seq not in self._tables:
            raise PageError(f"seq {seq} not admitted")
        cur = self._lens[seq]
        if new_len <= cur:
            return []
        table = self._tables[seq]
        cows: List[CowCopy] = []
        # Defensive writer-side CoW. Unreachable through admit() (shared
        # pages always lie strictly below every owner's length — see the
        # module docstring), but a write into a shared page would silently
        # corrupt the sharer, so guard against future callers anyway. The
        # copy is charged to this seq's reservation when it has one, else
        # the free pool.
        first_page = cur // self.page_size
        if first_page < len(table) and self._ref[table[first_page]] > 1:
            use_resv = self._reserved.get(seq, 0) > 0
            dst = self._take_page(from_reservation=seq if use_resv else None)
            cows.append(CowCopy(src=table[first_page], dst=dst))
            self._ref[table[first_page]] -= 1
            table[first_page] = dst
        need = pages_for(new_len, self.page_size)
        while len(table) < need:
            table.append(self._take_page(from_reservation=seq))
        self._lens[seq] = new_len
        return cows

    def _take_page(self, from_reservation: Optional[int] = None) -> int:
        if from_reservation is not None:
            if self._reserved.get(from_reservation, 0) < 1:
                raise PageError(
                    f"seq {from_reservation} grew past its reservation"
                )
            self._reserved[from_reservation] -= 1
        elif not self._free or self.free_pages < 1:
            raise PageError("page pool exhausted")
        pid = self._free.pop()
        self._ref[pid] = 1
        return pid

    # ---- release ----
    def free(self, seq: int) -> None:
        """Release `seq`: decref its pages (exclusive ones return to the
        pool; pages a sharer still holds stay allocated) and drop its
        reservation."""
        table = self._tables.pop(seq)
        del self._lens[seq]
        self._reserved.pop(seq, None)
        for pid in table:
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)

    # ---- invariants (tests call this after every schedule step) ----
    def check(self) -> None:
        assert self._ref[GARBAGE_PAGE] == 0, "garbage page must never be allocated"
        assert GARBAGE_PAGE not in self._free
        # refcount of every page == number of live tables referencing it
        counts = [0] * self.n_pages
        for table in self._tables.values():
            for pid in table:
                counts[pid] += 1
        assert counts == self._ref, f"refcount drift: {counts} vs {self._ref}"
        # free list holds exactly the zero-ref pages, each once
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate page in free list"
        for pid in range(1, self.n_pages):
            assert (self._ref[pid] == 0) == (pid in free_set)
        # every table covers exactly ceil(len / page) pages
        for seq, table in self._tables.items():
            assert len(table) == pages_for(self._lens[seq], self.page_size)
        # shared pages are read-only: every sequence referencing a page with
        # refcount > 1 must be fully past it (future writes land at
        # positions ≥ len, so page j is write-free iff (j+1)·page ≤ len) —
        # and prefix sharing means it sits at the same logical index in
        # every referencing table
        owners: Dict[int, List[Tuple[int, int]]] = {}
        for seq, table in self._tables.items():
            for j, pid in enumerate(table):
                if self._ref[pid] > 1:
                    assert (j + 1) * self.page_size <= self._lens[seq], (
                        f"seq {seq} can still write shared page {pid}"
                    )
                    owners.setdefault(pid, []).append((seq, j))
        for pid, refs in owners.items():
            assert len({j for _, j in refs}) == 1, (
                f"page {pid} aliased at different logical indexes: {refs}"
            )
        # reservations never exceed the physically free pages
        assert sum(self._reserved.values()) <= len(self._free)
