"""Paged KV-cache allocator: global page pool, per-sequence block tables,
copy-on-write prefix sharing, and a content-addressed radix prefix cache
(DESIGN.md §3.4, §3.6).

The serving engine's historical memory model reserved one contiguous
`max_len`-wide cache region per batch slot, so `max_batch × max_len` tokens
of KV memory were committed up front even when every live sequence was
short. This module replaces that with the vLLM memory model: KV lives in a
pool of fixed-size *pages* (`page_size` tokens each); a sequence owns an
ordered *block table* of page ids covering `ceil(len / page_size)` pages;
pages are allocated as the sequence grows and returned to the pool when it
finishes. FlashAttention-style kernels are indifferent to where KV tiles
live, and FLASH-D's division-free sigmoid merge blends partials from
non-contiguous pages with the same one-FMA carry as contiguous splits
(`kernels/flashd_decode.flashd_decode_paged_pallas`), so the kernel-side
cost of paging is just the block-table indirection.

This class is pure host-side bookkeeping — it never touches device arrays.
Device effects are communicated back to the caller as:

  * block tables (`table(seq)`) the engine mirrors into the device-side
    `tbl` operand of the paged decode kernel;
  * `CowCopy(src, dst)` records: the caller must copy page `src` → page
    `dst` in every layer's page arrays *before* the next write dispatch.

Radix prefix cache (DESIGN.md §3.6):

  The KV content of page j is a pure function of the token ids at
  positions [0, (j+1)·page) — for a pure global-attention stack, attention
  at position p reads only positions ≤ p. So a *full* page is content-
  addressable by its token chain, and the tree below indexes every full
  page the allocator has ever been given by that chain:

  * `insert(seq, tokens)` — called once a live sequence's pages hold valid
    KV (prefill complete): each full page becomes a tree node (keyed by
    the page's token tuple, chained by depth) holding one extra reference.
  * `donate(seq, tokens)` — retirement: like `free`, but the full pages of
    the sequence's clean token stream (prompt + generated) stay in the
    tree instead of returning to the pool. A page whose node has no table
    references left (``refcount == 1``: the tree's own reference) sits on
    the logical LRU eviction list — retained, but reclaimable.
  * `match_prefix(tokens)` — admission walks the tree with the prompt's
    page chain and returns the longest cached full-page prefix; `admit`
    aliases those pages into the new table (refcount++) so prefill starts
    at the first uncached token. FLASH-D's tile-local (O, Λ) carry is what
    makes resuming from a page boundary free: no running max or deferred
    division needs reconstructing — the next tile's sigmoid blend picks up
    from the cached pages as if they had just been computed.
  * eviction — `_take_page` reclaims least-recently-used refcount-1 leaves
    on demand; `CachePolicy` adds a min-free-pages watermark and a cache
    size cap enforced after every donation. Eviction never touches a page
    any table still references.

Sharing / copy-on-write semantics:

  * `admit(..., share_from=parent, shared_tokens=n)` makes the child's
    first `ceil(n / page_size)` table entries reference the parent's pages
    (refcount++). Full pages of the shared prefix are never written by
    either sequence again (writes only happen at positions ≥ the owner's
    length), so they are shared for their whole lifetime for free. The
    *boundary* page — shared only up to mid-page — is immediately
    copy-on-write'd for the child (one `CowCopy`), because the child's
    tail prefill writes into it. Radix-matched pages (`cached=`) are
    always full pages, so they need no boundary copy at all.
  * Because the boundary page is copied at admit (child side) and full
    shared pages lie strictly below every owner's length, **no live
    sequence ever holds a writable shared page** — writers only touch
    positions ≥ their own length, and those always land on exclusively
    owned (or fresh) pages. `extend()` keeps a defensive CoW for the
    unreachable case anyway, and `check()` asserts the invariant.

Admission control: `reserve_tokens` pages are *reserved* at admit; the
preemption-free engines pass the worst case (prompt + max_new_tokens +
decode-chunk slack) so a mid-flight sequence can never hit pool
exhaustion, while the preemptible engines pass just the prompt
(optimistic per-chunk allocation — growth draws the free pool, and page
pressure is resolved by preempting a victim, DESIGN.md §3.6).
Reservations only turn into materialized pages as the sequence actually
grows (`extend`); once a reservation is spent, growth falls back to the
free pool (evicting cached pages on demand).

Page id 0 is reserved as the *garbage page*: the engine points the table
rows of dead batch slots at it (and the kernel clamps out-of-table writes
to it), so lockstep decode steps of finished slots scribble harmlessly
instead of corrupting live pages.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = [
    "CachePolicy",
    "CowCopy",
    "PagedKVAllocator",
    "PageError",
    "PrefixMatch",
    "pages_for",
]

GARBAGE_PAGE = 0


class PageError(RuntimeError):
    """Pool exhausted or API misuse (admitting a live seq, growing a dead one)."""


@dataclasses.dataclass(frozen=True)
class CowCopy:
    """Device-side page copy the caller owes: pages[dst] ← pages[src]."""

    src: int
    dst: int


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """Retention heuristics for the radix prefix cache (tuning layer).

    min_free_pages   — after a donation, evict cached pages until at least
                       this many pages are physically free (admissions
                       should not always pay eviction latency).
    max_cached_pages — hard cap on tree-retained pages (None: unbounded;
                       0 disables retention entirely — donations free).
    """

    min_free_pages: int = 0
    max_cached_pages: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of a radix lookup: the longest cached full-page prefix.

    `n_tokens` is always a multiple of page_size; `pages` are the cached
    page ids in chain order, valid to alias until the next allocator
    mutation (admit revalidates them)."""

    n_tokens: int
    pages: Tuple[int, ...]


class _RadixNode:
    """One full page of cached KV. Children are keyed by the NEXT page's
    token tuple, so a root path spells out a token-chain prefix."""

    __slots__ = ("key", "pid", "children", "parent", "tick")

    def __init__(self, key, pid, parent):
        self.key = key
        self.pid = pid
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_RadixNode"] = {}
        self.tick = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages covering n_tokens (0 tokens → 0 pages)."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // page_size)


class PagedKVAllocator:
    def __init__(self, n_pages: int, page_size: int,
                 *, cache_policy: Optional[CachePolicy] = None):
        if n_pages < 2:
            raise ValueError("need ≥ 2 pages (page 0 is the garbage page)")
        if page_size < 1:
            raise ValueError("page_size must be ≥ 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.policy = cache_policy or CachePolicy()
        # LIFO free list → recently-freed pages are reused first (warm VMEM/HBM)
        self._free: List[int] = list(range(n_pages - 1, GARBAGE_PAGE, -1))
        self._ref: List[int] = [0] * n_pages
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}
        self._reserved: Dict[int, int] = {}  # seq → reserved-but-unmaterialized pages
        # ---- radix prefix cache ----
        self._root = _RadixNode(key=None, pid=-1, parent=None)
        self._tree: Dict[int, _RadixNode] = {}  # pid → its (unique) node
        self._tick = 0
        self.evictions = 0  # cached pages reclaimed (stats)
        self.donated_pages = 0  # tree nodes ever created (stats)

    # ---- accounting ----
    @property
    def free_pages(self) -> int:
        """Pages available to new admissions without evicting anything
        (excludes live reservations)."""
        return len(self._free) - sum(self._reserved.values())

    @property
    def pages_in_use(self) -> int:
        """Distinct pages currently materialized (shared pages count once;
        includes tree-retained pages awaiting eviction)."""
        return sum(1 for r in self._ref if r > 0)

    @property
    def reserved_pages(self) -> int:
        """Pages promised to live sequences but not yet materialized."""
        return sum(self._reserved.values())

    @property
    def cached_pages(self) -> int:
        """Pages indexed by the radix tree (live-shared + LRU-retained)."""
        return len(self._tree)

    @property
    def evictable_pages(self) -> int:
        """Tree pages reclaimable by cascading LRU eviction right now."""
        return self._evictable()

    @property
    def live_seqs(self) -> Tuple[int, ...]:
        return tuple(self._tables)

    def table(self, seq: int) -> List[int]:
        return list(self._tables[seq])

    def seq_len(self, seq: int) -> int:
        return self._lens[seq]

    def refcount(self, pid: int) -> int:
        return self._ref[pid]

    # ---- radix prefix cache ----
    def _page_key(self, tokens, j: int) -> Tuple[int, ...]:
        p = self.page_size
        return tuple(int(t) for t in tokens[j * p:(j + 1) * p])

    def _touch(self, node: _RadixNode) -> None:
        self._tick += 1
        while node is not None and node is not self._root:
            node.tick = self._tick
            node = node.parent

    def match_prefix(self, tokens, *, max_tokens: Optional[int] = None) -> PrefixMatch:
        """Longest cached full-page prefix of `tokens` (pure lookup — no
        refcount or LRU mutation). `max_tokens` caps the match (engines
        pass prompt_len − 1 so at least one token always prefills)."""
        limit = len(tokens) if max_tokens is None else min(len(tokens), max_tokens)
        node, pids, j = self._root, [], 0
        while (j + 1) * self.page_size <= limit:
            child = node.children.get(self._page_key(tokens, j))
            if child is None:
                break
            pids.append(child.pid)
            node = child
            j += 1
        return PrefixMatch(n_tokens=j * self.page_size, pages=tuple(pids))

    def insert(self, seq: int, tokens) -> int:
        """Index a live sequence's full prompt pages in the tree (call once
        its pages hold valid KV — after prefill). Each newly indexed page
        gains the tree's reference, so it outlives the sequence. Pages
        whose chain position is already cached (e.g. radix-matched at
        admission) are just touched. Returns pages newly indexed."""
        if seq not in self._tables:
            raise PageError(f"seq {seq} not admitted")
        table = self._tables[seq]
        clean = min(len(tokens), self._lens[seq])
        node, created, j = self._root, 0, 0
        while (j + 1) * self.page_size <= clean:
            key = self._page_key(tokens, j)
            child = node.children.get(key)
            if child is None:
                pid = table[j]
                if pid in self._tree:  # page already indexed on another chain
                    break  # (unreachable via prefix aliasing; stay safe)
                child = _RadixNode(key=key, pid=pid, parent=node)
                node.children[key] = child
                self._tree[pid] = child
                self._ref[pid] += 1  # the tree's own reference
                created += 1
            node = child
            j += 1
        if j:
            self._touch(node)
        self.donated_pages += created
        self._enforce_policy()
        return created

    def donate(self, seq: int, tokens) -> int:
        """Retire `seq`, donating its clean full pages to the radix tree.

        `tokens` is the sequence's clean token stream — the ids whose KV
        its pages actually hold (effective prompt + generated tokens,
        truncated to the materialized length). Full pages of that stream
        become (or refresh) tree nodes; the boundary partial page and any
        duplicate-content pages are freed normally. Returns pages newly
        indexed."""
        if seq not in self._tables:
            raise PageError(f"seq {seq} not admitted")
        table = self._tables.pop(seq)
        clean = min(len(tokens), self._lens.pop(seq))
        self._reserved.pop(seq, None)
        node, last, created = self._root, None, 0
        for j, pid in enumerate(table):
            if node is not None and (j + 1) * self.page_size <= clean:
                key = self._page_key(tokens, j)
                child = node.children.get(key)
                if child is None and pid not in self._tree:
                    # adopt: the table's reference becomes the tree's
                    child = _RadixNode(key=key, pid=pid, parent=node)
                    node.children[key] = child
                    self._tree[pid] = child
                    created += 1
                else:
                    # chain already cached (or page indexed elsewhere):
                    # this table's reference simply drops
                    self._decref(pid)
                node = child  # None breaks the chain for deeper pages
                last = child if child is not None else last
            else:
                node = None
                self._decref(pid)
        if last is not None:
            self._touch(last)
        self.donated_pages += created
        self._enforce_policy()
        return created

    def _decref(self, pid: int) -> None:
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)

    def _evictable(self, exclude: frozenset = frozenset()) -> int:
        """Pages reclaimable by cascading leaf eviction: a subtree is fully
        reclaimable iff every node in it holds only the tree's reference
        (table references pin whole root chains, so a pinned child implies
        a pinned parent — but grafted chains can pin a child under a free
        parent, hence the subtree walk). `exclude` pids count as pinned
        (an admission about to alias them must not plan to evict them)."""

        def rec(node: _RadixNode) -> Tuple[int, bool]:
            count, full = 0, True
            for child in node.children.values():
                c, f = rec(child)
                count += c
                full = full and f
            if node is self._root:
                return count, full
            if self._ref[node.pid] == 1 and node.pid not in exclude and full:
                return count + 1, True
            return count, False

        return rec(self._root)[0]

    def _evict_one(self) -> bool:
        """Reclaim the least-recently-used evictable leaf. Never touches a
        page any table references (refcount > 1)."""
        best = None
        for pid, node in self._tree.items():
            if not node.children and self._ref[pid] == 1:
                if best is None or node.tick < best.tick:
                    best = node
        if best is None:
            return False
        assert self._ref[best.pid] == 1, "evicting a table-referenced page"
        del best.parent.children[best.key]
        del self._tree[best.pid]
        self._ref[best.pid] = 0
        self._free.append(best.pid)
        self.evictions += 1
        return True

    def _enforce_policy(self) -> None:
        cap = self.policy.max_cached_pages
        while cap is not None and len(self._tree) > cap:
            if not self._evict_one():
                break
        while len(self._free) < self.policy.min_free_pages:
            if not self._evict_one():
                break

    # ---- admission ----
    def can_admit(self, reserve_tokens: int, *, shared_tokens: int = 0,
                  cached: Optional[PrefixMatch] = None) -> bool:
        """Would `admit` succeed? Shared full pages come from the parent
        (or the radix cache); the boundary page (if any) costs a fresh CoW
        page, everything past the shared prefix costs fresh pages, and
        LRU-retained cache pages count as available (eviction on demand)."""
        cost = self._admit_cost(reserve_tokens, shared_tokens, cached)
        if cost <= self.free_pages:  # common case: no tree walk
            return True
        exclude = frozenset(cached.pages) if cached is not None else frozenset()
        return cost <= self.free_pages + self._evictable(exclude)

    def _admit_cost(self, reserve_tokens: int, shared_tokens: int,
                    cached: Optional[PrefixMatch] = None) -> int:
        total = pages_for(reserve_tokens, self.page_size)
        if cached is not None:
            return total - len(cached.pages)
        full_shared = shared_tokens // self.page_size
        return total - full_shared  # boundary partial page needs its own copy

    def admit(
        self,
        seq: int,
        prompt_len: int,
        reserve_tokens: int,
        *,
        share_from: Optional[int] = None,
        shared_tokens: int = 0,
        cached: Optional[PrefixMatch] = None,
    ) -> List[CowCopy]:
        """Register `seq`, materialize pages covering `prompt_len`, reserve up
        to `reserve_tokens`. With `share_from`, the first `shared_tokens`
        positions alias the parent's pages (full pages by reference; the
        partial boundary page as an immediate CoW copy). With `cached` (a
        `match_prefix` result), the matched full pages are aliased out of
        the radix tree instead — no boundary copy, prefill starts at
        `cached.n_tokens`. Returns the device copies owed. Raises
        PageError when the pool cannot cover it."""
        if seq in self._tables:
            raise PageError(f"seq {seq} already admitted")
        if cached is not None and share_from is not None:
            raise PageError("cached= and share_from= are mutually exclusive")
        if shared_tokens and share_from is None:
            raise PageError("shared_tokens needs share_from")
        reserve_tokens = max(reserve_tokens, prompt_len)
        if shared_tokens > prompt_len:
            raise PageError("cannot share more than the prompt")
        if share_from is not None and shared_tokens > self._lens.get(share_from, -1):
            raise PageError("cannot share beyond the parent's length")
        if cached is not None:
            if cached.n_tokens >= max(prompt_len, 1):
                raise PageError("cached prefix must leave ≥ 1 token to prefill")
            for pid in cached.pages:  # revalidate against eviction races
                if pid not in self._tree:
                    raise PageError(f"stale prefix match: page {pid} evicted")
        if not self.can_admit(reserve_tokens, shared_tokens=shared_tokens,
                              cached=cached):
            raise PageError(
                f"pool exhausted: need"
                f" {self._admit_cost(reserve_tokens, shared_tokens, cached)}"
                f" pages, {self.free_pages} free"
                f" (+{self._evictable()} evictable)"
            )

        table: List[int] = []
        cows: List[CowCopy] = []
        if cached is not None:
            for pid in cached.pages:
                self._ref[pid] += 1
                table.append(pid)
            if cached.pages:
                self._touch(self._tree[cached.pages[-1]])
        elif share_from is not None:
            parent_tbl = self._tables[share_from]
            full_shared = shared_tokens // self.page_size
            for j in range(full_shared):
                pid = parent_tbl[j]
                self._ref[pid] += 1
                table.append(pid)
            if shared_tokens % self.page_size:
                # boundary page: child writes its tail into it → private copy
                dst = self._take_page()
                cows.append(CowCopy(src=parent_tbl[full_shared], dst=dst))
                table.append(dst)
        while len(table) < pages_for(prompt_len, self.page_size):
            table.append(self._take_page())
        self._tables[seq] = table
        self._lens[seq] = prompt_len
        self._reserved[seq] = pages_for(reserve_tokens, self.page_size) - len(table)
        return cows

    # ---- growth ----
    def extend(self, seq: int, new_len: int) -> List[CowCopy]:
        """Materialize pages so positions [len, new_len) are writable by
        `seq` alone: pages come from the reservation while it lasts, then
        the free pool (evicting LRU cache pages on demand); plus a private
        CoW copy of the current tail page if another sequence still
        references it. Raises PageError when the pool cannot cover the
        growth — the preemptible engines resolve that by victim selection.
        Returns the device copies owed."""
        if seq not in self._tables:
            raise PageError(f"seq {seq} not admitted")
        cur = self._lens[seq]
        if new_len <= cur:
            return []
        table = self._tables[seq]
        cows: List[CowCopy] = []
        # atomicity precheck: fail BEFORE mutating when the pool cannot
        # cover the whole growth (reservation + free + evictable), so a
        # failed extend leaves the allocator exactly as it was and the
        # preemptible engines can retry after victim selection
        first_page = cur // self.page_size
        need_cow = int(
            first_page < len(table) and self._ref[table[first_page]] > 1
        )
        need = need_cow + (pages_for(new_len, self.page_size) - len(table))
        avail = self._reserved.get(seq, 0) + self.free_pages
        if need > avail:  # count evictable only when actually short: the
            avail += self._evictable()  # tree walk is off the hot path
        if need > avail:
            raise PageError(
                f"page pool exhausted: growing seq {seq} to {new_len} needs"
                f" {need} pages, {avail} coverable"
            )
        # Defensive writer-side CoW. Unreachable through admit() (shared
        # pages always lie strictly below every owner's length — see the
        # module docstring), but a write into a shared page would silently
        # corrupt the sharer, so guard against future callers anyway. The
        # copy is charged to this seq's reservation when it has one, else
        # the free pool.
        if need_cow:
            dst = self._grow_page(seq)
            cows.append(CowCopy(src=table[first_page], dst=dst))
            self._decref(table[first_page])
            table[first_page] = dst
        need = pages_for(new_len, self.page_size)
        while len(table) < need:
            table.append(self._grow_page(seq))
        self._lens[seq] = new_len
        return cows

    def rollback(self, seq: int, new_len: int) -> int:
        """Speculative rollback — the inverse of `extend`. Truncate `seq`'s
        materialized length to `new_len`, dropping the table's references
        to every page wholly past the new boundary. Rejected-draft pages
        are *freed, never donated*: they hold KV for tokens that are not
        part of the committed stream, so indexing them in the radix tree
        would break the bytes-are-a-pure-function-of-the-token-stream
        invariant that prefix caching and the int8 slot-0 scale rule rely
        on (DESIGN.md §3.9).

        Pages this seq owned exclusively return to the pool *via its
        reservation* — rollback + re-extend is the speculative steady
        state, and crediting the reservation keeps the non-preemptive
        worst-case admission guarantee intact (the freed page cannot be
        claimed by a competing admission mid-flight). Returns the number
        of pages actually freed."""
        if seq not in self._tables:
            raise PageError(f"seq {seq} not admitted")
        cur = self._lens[seq]
        if new_len < 0 or new_len > cur:
            raise PageError(
                f"rollback target {new_len} outside [0, {cur}] for seq {seq}"
            )
        table = self._tables[seq]
        keep = pages_for(new_len, self.page_size)
        freed = 0
        for pid in table[keep:]:
            # dropped pages lie past the accepted length, which is ≥ the
            # shared/cached prompt prefix — they are never tree-indexed
            assert pid not in self._tree, "rolling back a cached page"
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)
                self._reserved[seq] = self._reserved.get(seq, 0) + 1
                freed += 1
        del table[keep:]
        self._lens[seq] = new_len
        return freed

    def _grow_page(self, seq: int) -> int:
        """One growth page: reservation first, free pool after (optimistic
        per-chunk allocation past the reserve)."""
        if self._reserved.get(seq, 0) > 0:
            return self._take_page(from_reservation=seq)
        return self._take_page()

    def _take_page(self, from_reservation: Optional[int] = None) -> int:
        if from_reservation is not None:
            if self._reserved.get(from_reservation, 0) < 1:
                raise PageError(
                    f"seq {from_reservation} grew past its reservation"
                )
            self._reserved[from_reservation] -= 1
        elif self.free_pages < 1 and self._evictable() < 1:
            # (short-circuit keeps the tree walk off the common path)
            raise PageError("page pool exhausted")
        while not self._free:
            if not self._evict_one():
                raise PageError("page pool exhausted")
        pid = self._free.pop()
        self._ref[pid] = 1
        return pid

    # ---- release ----
    def free(self, seq: int) -> None:
        """Release `seq`: decref its pages (exclusive ones return to the
        pool; pages a sharer or the radix tree still holds stay allocated)
        and drop its reservation. `donate` is the cache-aware variant."""
        table = self._tables.pop(seq)
        del self._lens[seq]
        self._reserved.pop(seq, None)
        for pid in table:
            self._decref(pid)

    def reset_live(self) -> int:
        """Crash-recovery sweep: release every live sequence (the engine
        could not retire them individually) while keeping the radix tree —
        and everything it has indexed — intact. Partial-page KV of the
        released sequences is simply dropped; tree-indexed full pages stay
        warm. Returns the number of sequences released."""
        seqs = list(self._tables)
        for seq in seqs:
            self.free(seq)
        return len(seqs)

    def cached_chains(self) -> List[List[int]]:
        """Root-to-leaf token chains indexed by the radix tree, each a flat
        token list (length a multiple of page_size). Leaves only — interior
        prefixes are implied. This is the cache's content in *token* space;
        `Engine.snapshot()/restore()` re-derives the KV pages from it,
        exactly, because FLASH-D's (O, Λ) state is a pure function of the
        token stream (DESIGN.md §3.7)."""
        out: List[List[int]] = []

        def rec(node: _RadixNode, toks: List[int]) -> None:
            if not node.children:
                if node is not self._root:
                    out.append(toks)
                return
            for key, child in node.children.items():
                rec(child, toks + list(key))

        rec(self._root, [])
        return out

    # ---- invariants (tests call this after every schedule step) ----
    def check(self, cache=None) -> None:
        """Assert every allocator invariant. With `cache` (the engine's
        device cache tree) the quantized pool's scale side-band is checked
        too — see `_check_scales`."""
        if cache is not None:
            self._check_scales(cache)
        assert self._ref[GARBAGE_PAGE] == 0, "garbage page must never be allocated"
        assert GARBAGE_PAGE not in self._free
        # Σ refcounts == table references + tree references
        tbl_counts = [0] * self.n_pages
        for table in self._tables.values():
            for pid in table:
                tbl_counts[pid] += 1
        counts = list(tbl_counts)
        for pid in self._tree:
            counts[pid] += 1
        assert counts == self._ref, f"refcount drift: {counts} vs {self._ref}"
        # free list holds exactly the zero-ref pages, each once
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate page in free list"
        for pid in range(1, self.n_pages):
            assert (self._ref[pid] == 0) == (pid in free_set)
        # radix tree: structure coherent, every node's page is live-or-LRU
        # (table-referenced XOR evictable), never on the free list
        assert GARBAGE_PAGE not in self._tree
        reachable: Dict[int, int] = {}  # pid → depth

        def walk(node: _RadixNode, depth: int) -> None:
            for key, child in node.children.items():
                assert child.parent is node and child.key == key
                assert child.pid not in reachable, "page in tree twice"
                assert len(key) == self.page_size, "non-full page in tree"
                reachable[child.pid] = depth
                walk(child, depth + 1)

        walk(self._root, 0)
        assert set(reachable) == set(self._tree), "tree index drift"
        for pid, node in self._tree.items():
            assert node.pid == pid
            assert self._ref[pid] >= 1, "tree page lost its tree reference"
            assert pid not in free_set
            # live (some table references it) XOR on the LRU side
            # (refcount 1 = the tree's own reference only) — checked
            # against the tables directly, independent of the refcounts
            assert (tbl_counts[pid] > 0) == (self._ref[pid] > 1), (
                f"tree page {pid} neither live nor LRU-consistent"
            )
        # the eviction planner can never reclaim a table-referenced page:
        # its cascade count is bounded by the pages no table holds (checked
        # against the tables directly, not the refcounts it walks)
        assert self._evictable() <= sum(
            1 for pid in self._tree if tbl_counts[pid] == 0
        )
        # every table covers exactly ceil(len / page) pages
        for seq, table in self._tables.items():
            assert len(table) == pages_for(self._lens[seq], self.page_size)
        # shared pages are read-only: every sequence referencing a page with
        # refcount > 1 — or any tree-indexed page — must be fully past it
        # (future writes land at positions ≥ len, so page j is write-free
        # iff (j+1)·page ≤ len) — and prefix sharing/chaining means it sits
        # at the same logical index in every referencing table
        owners: Dict[int, List[Tuple[int, int]]] = {}
        for seq, table in self._tables.items():
            for j, pid in enumerate(table):
                if self._ref[pid] > 1 or pid in self._tree:
                    assert (j + 1) * self.page_size <= self._lens[seq], (
                        f"seq {seq} can still write shared/cached page {pid}"
                    )
                    owners.setdefault(pid, []).append((seq, j))
        for pid, refs in owners.items():
            assert len({j for _, j in refs}) == 1, (
                f"page {pid} aliased at different logical indexes: {refs}"
            )
            if pid in self._tree:
                # chain depth == logical index (root children at depth 0)
                assert refs[0][1] == reachable[pid], (
                    f"page {pid} at table index {refs[0][1]} but tree depth"
                    f" {reachable[pid]}"
                )
        # reservations never exceed what the pool can actually produce
        assert sum(self._reserved.values()) <= len(self._free) + self._evictable()

    def _check_scales(self, cache) -> None:
        """Quantized-pool scale-side-band invariants (DESIGN.md §3.8).

        Scales are indexed by PHYSICAL page id, so a prefix-shared or
        tree-cached page has exactly one scale entry per head regardless
        of how many tables alias it — the aliasing is structural, and this
        check pins it: every scale leaf must span the pool's page axis
        (one row per physical page), and every in-use page's scales must
        be finite and positive (a page whose slot 0 was ever written gets
        a scale ≥ quant._EPS-derived; never-written pages hold the init
        value 1.0). A native (unquantized) cache has no scale leaves and
        passes vacuously."""
        import numpy as np  # lazy: this module is otherwise array-free
        from jax import tree_util as jtu

        in_use = [pid for pid in range(self.n_pages) if self._ref[pid] > 0]
        for path, leaf in jtu.tree_leaves_with_path(cache):
            name = next(
                (e.key for e in reversed(path) if isinstance(e, jtu.DictKey)),
                None,
            )
            if name not in ("k_scale", "v_scale"):
                continue
            arr = np.asarray(leaf)
            assert arr.shape[-2] == self.n_pages, (
                f"{name} page axis {arr.shape[-2]} != pool n_pages"
                f" {self.n_pages}"
            )
            used = arr[..., in_use, :]
            assert np.all(np.isfinite(used)), f"{name} has non-finite scales"
            assert np.all(used > 0), f"{name} has non-positive scales"
