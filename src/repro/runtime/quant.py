"""Quantized KV page-pool format (DESIGN.md §3.8).

Pages are stored in a low-precision dtype with a per-(page, head) f32
scale side-band held as extra pool leaves (`k_scale`/`v_scale`, shaped
`[n_pages, Hkv]`) beside `k_pages`/`v_pages`. The format is WRITE-ORDER
DETERMINISTIC: a page's scale is fixed by its slot-0 row — amax over the
head dim of the page's first K (resp. V) row, divided by qmax/HEADROOM —
and is never revised afterwards, so a page's quantized bytes + scale are
a pure function of the page's own (token, position) stream. That is
exactly the precondition the radix prefix cache needs to alias quantized
pages content-addressed by token prefix (DESIGN.md §3.6), and it holds
across both write paths (the sequential `_paged_attn_step` scatter and
the packed `_packed_attn` scatter) because slot 0 of a page is always
written at-or-before every other slot of that page.

HEADROOM leaves part of the representable range unused by the slot-0 row
so later rows of the page — drawn from the same activation distribution —
rarely clip; rows that still exceed the range saturate symmetrically.
FLASH-D's max-free stable exponentials make the attention arithmetic
tolerant of exactly this kind of bounded relative K/V error: scores enter
the (acc, Λ) sigmoid carry without a running-max subtraction, so a small
score perturbation moves the blend weight smoothly instead of re-basing
the whole normalizer (the H-FA / fused-exp-mul line of work in PAPERS.md
runs these same blockwise kernels on cheap reduced-precision formats).

int8 ships first; fp8 (e4m3) registers automatically when the host jax
exposes `jnp.float8_e4m3fn` — a format differs only by (dtype, qmax),
which is the point of the spec registry: fp8 is a dtype swap, not a new
plumbing path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec",
    "HEADROOM",
    "available",
    "get_spec",
    "spec_for_dtype",
    "kv_itemsize",
    "slot0_scale",
    "quantize_rows",
    "dequantize_pages",
]

# the slot-0 row maps to ±(qmax / HEADROOM); later rows get 2× margin
HEADROOM = 2.0
_EPS = 1e-8  # all-zero slot-0 rows still get a positive, finite scale


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """One storage format for the KV page pool."""

    name: str
    dtype: object  # jnp dtype of the stored pages
    qmax: float  # largest representable magnitude to clip against
    itemsize: int = 1  # bytes per stored element


_SPECS = {"int8": QuantSpec("int8", jnp.int8, 127.0)}
if hasattr(jnp, "float8_e4m3fn"):  # gated: older hosts lack fp8 dtypes
    _SPECS["fp8"] = QuantSpec("fp8", jnp.float8_e4m3fn, 448.0)


def available() -> tuple:
    return tuple(sorted(_SPECS))


def get_spec(kv_dtype: str) -> Optional[QuantSpec]:
    """Spec for a ServeConfig.kv_dtype string; "" (native) → None."""
    if not kv_dtype:
        return None
    try:
        return _SPECS[kv_dtype]
    except KeyError:
        raise ValueError(
            f"unknown kv_dtype {kv_dtype!r}; available: {available()} "
            "(\"\" stores pages in the model compute dtype)"
        ) from None


def spec_for_dtype(dtype) -> Optional[QuantSpec]:
    """Spec whose storage dtype is `dtype`, else None (native pool).

    The cache pytree carries only arrays, so consumers that find scale
    leaves beside a pool recover the format from the pages' dtype."""
    dt = jnp.dtype(dtype)
    for spec in _SPECS.values():
        if jnp.dtype(spec.dtype) == dt:
            return spec
    return None


def kv_itemsize(kv_dtype: str) -> int:
    """Stored bytes per K/V element (feeds the tuning heuristics)."""
    spec = get_spec(kv_dtype)
    return 4 if spec is None else spec.itemsize


def slot0_scale(row: jax.Array, spec: QuantSpec) -> jax.Array:
    """Per-head page scale from the page's slot-0 row.

    row [..., Hkv, d] → scale [..., Hkv] f32. Deterministic in the row
    alone — the whole soundness argument for radix sharing rests on this
    function never seeing any other slot of the page."""
    amax = jnp.max(jnp.abs(row.astype(jnp.float32)), axis=-1)
    return jnp.maximum(amax, _EPS) / (spec.qmax / HEADROOM)


def quantize_rows(rows: jax.Array, scales: jax.Array, spec: QuantSpec) -> jax.Array:
    """rows [..., Hkv, d] × scales [..., Hkv] → stored dtype (saturating)."""
    x = rows.astype(jnp.float32) / scales[..., None]
    x = jnp.clip(x, -spec.qmax, spec.qmax)
    if jnp.issubdtype(jnp.dtype(spec.dtype), jnp.integer):
        x = jnp.round(x)
    return x.astype(spec.dtype)


def dequantize_pages(pages: jax.Array, scales: jax.Array) -> jax.Array:
    """[P, page, Hkv, d] pages × [P, Hkv] scales → f32 pool view.

    The jnp mirror of the kernels' in-tile dequant (one broadcast multiply
    after the DMA'd tile is upcast) — mathematically identical because the
    scale is constant over a (page, head) tile."""
    return pages.astype(jnp.float32) * scales[:, None, :, None]
