"""Gradient compression for the DP all-reduce, with error feedback.

At 512+ chips the cross-pod (DCN) gradient all-reduce dominates step time
for small-per-chip models. Two standard compressors, both with
error-feedback residual accumulation (the residual pytree rides in the
train state and is checkpointed):

  int8   — per-tensor symmetric quantization: g → round(g/s)·s, s = max|g|/127.
           8× less DCN traffic; EF makes it unbiased-in-the-limit.
  topk   — magnitude top-k per tensor (k = ratio·size), dense-masked so it
           stays SPMD-friendly (no ragged collectives); EF catches the tail.

`compress_gradients` runs INSIDE the jitted train step *before* XLA's
cross-pod reduction of microbatch-accumulated grads, so the wire format is
what the compressor emitted. Returns (decompressed grads, new residual).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "compress_gradients", "init_residual"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | int8 | topk
    topk_ratio: float = 0.05
    min_size: int = 4096  # tensors smaller than this stay uncompressed


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _int8_roundtrip(g: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_mask(g: jax.Array, ratio: float) -> jax.Array:
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.size * ratio))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_gradients(
    grads, residual, cfg: CompressionConfig
) -> Tuple[dict, dict]:
    """Error-feedback compression: c = C(g + r); r' = (g + r) − c."""
    if cfg.kind == "none":
        return grads, residual

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if g.size < cfg.min_size:
            return gf.astype(g.dtype), jnp.zeros_like(r)
        if cfg.kind == "int8":
            c = _int8_roundtrip(gf)
        elif cfg.kind == "topk":
            c = _topk_mask(gf, cfg.topk_ratio)
        else:
            raise ValueError(cfg.kind)
        return c.astype(g.dtype), gf - c

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_flatten(residual)[0]
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [a for a, _ in out])
    new_r = jax.tree_util.tree_unflatten(treedef, [b for _, b in out])
    return new_g, new_r
