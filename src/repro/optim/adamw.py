"""AdamW with decoupled weight decay + global-norm clipping, from scratch.

Optimizer state is a pytree shaped like params (m, v), so it inherits the
parameter sharding (FSDP shards optimizer state for free — ZeRO-3).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt", "apply_updates", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # names never decayed (norm scales, biases, per-channel gates)
    no_decay_keywords: tuple = ("norm", "bias", "lam", "A_log", "D", "dt_bias")


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


def init_opt(params, state_dtype=None) -> OptState:
    """state_dtype='bfloat16' stores m/v at half width (math stays f32) —
    the ZeRO-friendly option giant models (qwen3-moe-235b) need to fit a
    256-chip pod; noted per cell in EXPERIMENTS.md."""
    if state_dtype is None:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    else:
        dt = jnp.dtype(state_dtype)
        zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, dt), p)
    return OptState(m=zeros(params), v=zeros(params), step=jnp.int32(0))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(params, cfg: AdamWConfig):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    mask = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        nd = any(k in name for k in cfg.no_decay_keywords) or leaf.ndim <= 1
        mask.append(0.0 if nd else 1.0)
    return jax.tree_util.tree_unflatten(treedef, mask)


def apply_updates(
    params,
    grads,
    opt: OptState,
    cfg: AdamWConfig,
    lr: Optional[jax.Array] = None,
):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    lr = cfg.lr if lr is None else lr
    step = opt.step + 1

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    m_dt = jax.tree.leaves(opt.m)[0].dtype
    new_m = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(m_dt),
        opt.m, grads)
    new_v = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g).astype(v.dtype),
        opt.v, grads)
    decay = _decay_mask(params, cfg)

    def upd(p, m, v, d):
        mhat = m.astype(jnp.float32) / b1c
        vhat = v.astype(jnp.float32) / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * d * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v, decay)
    metrics = {"grad_norm": gnorm, "lr": jnp.float32(lr)}
    return new_params, OptState(new_m, new_v, step), metrics
