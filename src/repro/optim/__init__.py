from repro.optim.adamw import AdamWConfig, OptState, apply_updates, global_norm, init_opt
from repro.optim.schedule import warmup_cosine
from repro.optim.compress import CompressionConfig, compress_gradients

__all__ = [
    "AdamWConfig", "OptState", "apply_updates", "global_norm", "init_opt",
    "warmup_cosine", "CompressionConfig", "compress_gradients",
]
