"""Resilient training orchestration: chaos in, bitwise-identical curve out.

`train_resilient` layers training-specific fault policy on top of the
generic `repro.runtime.resilience.run_resilient` supervisor:

* **Fault sites** — a `FaultInjector` is checked once per step at each
  train site, placed where the failure would surface in a real pipeline:
  `data_batch` before the batch is materialized, `grad_step` and
  `optimizer_update` before the fused jitted step that contains both,
  `collective` after the step (a failed cross-device reduction loses the
  step's result), and `ckpt_save` inside the supervisor's `on_save` hook
  (aborting the write). Every site raises *before* the step's result is
  committed to history, so a restart replays from the latest verified
  checkpoint and — because `SyntheticLM.batch(step)` is a pure function of
  (seed, step) and all mutable state lives in the checkpoint — the resumed
  loss curve is bitwise identical to an uninterrupted run.

* **Loss-spike rollback** — a host-side divergence detector compares each
  committed loss against the median of the trailing `spike_window` losses;
  a spike beyond `spike_threshold`× raises `DivergenceRollback` (retryable
  → the supervisor restores the last good checkpoint), rolling back past
  silently-corrupted state instead of training through it. A per-step
  rollback cap distinguishes corruption (transient: the replay is clean)
  from a genuine distribution shift (persistent: accept after the cap).

* **Counters** — restarts / rollbacks / injected faults / on-device
  skipped updates, surfaced for the launcher's status line and asserted
  by the goodput benchmark (BENCH_train.json).

The jitted step itself carries the numerics guard (non-finite-grad
skip-update + dynamic loss scaling) — see `repro.train.train_step`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.resilience import (
    DivergenceRollback,
    FaultInjector,
    RetryPolicy,
    run_resilient,
)
from repro.train.train_step import (
    TrainConfig,
    init_train_state,
    make_train_step,
)

__all__ = ["ResilienceConfig", "train_resilient"]


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Policy knobs for the supervised train loop."""

    ckpt_every: int = 10
    keep_checkpoints: Optional[int] = None  # None → keep all
    max_restarts: int = 50
    retry: RetryPolicy = RetryPolicy()
    # loss-spike divergence detector (0 → disabled)
    spike_threshold: float = 0.0  # loss > threshold * trailing median ⇒ spike
    spike_window: int = 8
    spike_warmup: int = 8  # committed steps before detection arms
    max_rollbacks_per_step: int = 2  # then accept: real shift, not corruption


def train_resilient(
    *,
    ckpt_dir: str,
    model_cfg,
    train_cfg: TrainConfig,
    data,
    total_steps: int,
    seed: int = 0,
    res: ResilienceConfig = ResilienceConfig(),
    injector: Optional[FaultInjector] = None,
    chaos_hook: Optional[Callable[[int, object], object]] = None,
    init_state_fn: Optional[Callable[[], object]] = None,
    step_fn: Optional[Callable] = None,
    on_step: Optional[Callable[[int, Dict[str, float], Dict[str, int]], None]] = None,
) -> Tuple[object, List[Dict], Dict[str, int]]:
    """Train `total_steps` under the resilience policy; returns
    (final_state, history, counters).

    `data.batch(step)` must be a pure function of step (the bitwise-resume
    contract). `chaos_hook(step, state) -> state | None` is a test hook
    that can silently corrupt state before a step — the spike detector's
    adversary. `init_state_fn` / `step_fn` override the defaults (fresh
    `init_train_state` / `jax.jit(make_train_step(...))`) so a sharded
    launcher can supply device_put state and a pjit'd step.
    """
    if init_state_fn is None:
        init_state_fn = lambda: init_train_state(
            jax.random.PRNGKey(seed), model_cfg, train_cfg
        )
    if step_fn is None:
        step_fn = jax.jit(make_train_step(model_cfg, train_cfg))

    counters = {"restarts": 0, "rollbacks": 0, "faults": 0, "skipped": 0}
    losses: Dict[int, float] = {}  # committed loss per data step (replay-safe)
    rollbacks_at: Dict[int, int] = {}

    def _spike_check(step: int, loss: float) -> None:
        if res.spike_threshold <= 0 or step < res.spike_warmup:
            return
        window = [losses[s] for s in range(max(0, step - res.spike_window), step)
                  if s in losses]
        if not window:
            return
        ref = float(np.median(window))
        if np.isfinite(loss) and loss <= res.spike_threshold * ref:
            return
        if rollbacks_at.get(step, 0) >= res.max_rollbacks_per_step:
            return  # persistent across clean replays ⇒ genuine shift: accept
        rollbacks_at[step] = rollbacks_at.get(step, 0) + 1
        counters["rollbacks"] += 1
        raise DivergenceRollback(step, loss, ref)

    def supervised_step(state, data_step: int):
        if injector is not None:
            injector.check("data_batch")
        batch = jax.tree.map(jnp.asarray, data.batch(data_step))
        if chaos_hook is not None:
            corrupted = chaos_hook(data_step, state)
            if corrupted is not None:
                state = corrupted
        if injector is not None:
            injector.check("grad_step")
            injector.check("optimizer_update")
        new_state, metrics = step_fn(state, batch)
        if injector is not None:
            injector.check("collective")  # a lost reduction loses the step
        loss = float(metrics["loss"])  # host sync: the commit point
        _spike_check(data_step, loss)
        losses[data_step] = loss
        if on_step is not None:
            if injector is not None:
                counters["faults"] = injector.total_fired
            on_step(data_step, {k: float(v) for k, v in metrics.items()}, counters)
        return new_state, metrics

    def on_save(step: int, state) -> None:
        if injector is not None:
            injector.check("ckpt_save")

    def on_restart(n: int, exc: BaseException) -> None:
        counters["restarts"] = n

    state, history = run_resilient(
        ckpt_dir=ckpt_dir,
        init_state_fn=init_state_fn,
        step_fn=supervised_step,
        total_steps=total_steps,
        ckpt_every=res.ckpt_every,
        max_restarts=res.max_restarts,
        retry=res.retry,
        keep=res.keep_checkpoints,
        on_save=on_save,
        on_restart=on_restart,
    )
    if injector is not None:
        counters["faults"] = injector.total_fired
    if hasattr(state, "skipped"):
        counters["skipped"] = int(state.skipped)
    return state, history, counters
