"""Training step: loss → grads → numerics guard → (compress) → AdamW.

Pure function of (TrainState, batch); jit/pjit-compiled by the launcher with
parameter/optimizer shardings from the rules engine. Microbatch gradient
accumulation (`accum_steps > 1`) runs as a `lax.scan` over batch slices —
XLA's latency-hiding scheduler overlaps each microbatch's reduce-scatter
with the next microbatch's compute (the compute/comm-overlap trick).

Attention inside the loss runs through `repro.core.flash_attention`, whose
custom_vjp routes `attn_impl="flashd_pallas"` to the fused Pallas
fwd+bwd kernel pair via the `attention_fwd`/`attention_bwd` registry ops
(kernels/ops.py) — activation-checkpointed: the backward recomputes score
tiles from (q, k, Λ), no [S, S] intermediate is saved (DESIGN.md §6).

Numerics guard (`numerics_guard=True`, the default): the loss is scaled by
the carried `loss_scale` before differentiation, gradients are unscaled
(power-of-two scales, so the round-trip is exact), and a fused
all-leaves-finite check gates the update ON DEVICE — a non-finite step
skips the param/opt/residual update entirely (old state selected through),
bumps the `skipped` counter, and halves the loss scale; after
`loss_scale_growth_interval` consecutive finite steps the scale doubles
back. With the default static scale of 1.0 the guarded step is
numerically identical to an unguarded one on every finite step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, get_model
from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    OptState,
    apply_updates,
    compress_gradients,
    init_opt,
    warmup_cosine,
)
from repro.optim.compress import init_residual

__all__ = ["TrainConfig", "TrainState", "init_train_state", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    compression: CompressionConfig = CompressionConfig()
    warmup_steps: int = 100
    total_steps: int = 10000
    accum_steps: int = 1  # microbatch gradient accumulation
    opt_state_dtype: str = "float32"  # 'bfloat16' halves Adam m/v memory
    # Cast f32 master params to the compute dtype ONCE at step start while
    # still FSDP-sharded, so every per-layer all-gather moves bf16 instead of
    # f32 — halves FSDP gather traffic (§Perf lever; off = paper-faithful
    # baseline semantics, numerics identical either way since compute casts
    # to bf16 at use regardless).
    cast_params_once: bool = False
    # Differentiate w.r.t. the bf16 cast tree so gradients — and their
    # cross-device reductions — are bf16 (halves grad all-reduce wire; the
    # classic mixed-precision trade: bf16 grad summaries, f32 master update).
    grad_dtype: str = "float32"  # or 'bfloat16'
    # Numerics guard: on-device non-finite-gradient skip + dynamic loss
    # scaling (DESIGN.md §6). Scales are powers of two, so scale/unscale
    # round-trips are exact; growth_interval=0 keeps the scale static.
    numerics_guard: bool = True
    loss_scale_init: float = 1.0
    loss_scale_growth_interval: int = 0  # 0 → static scale
    loss_scale_min: float = 2.0 ** -14
    loss_scale_max: float = 2.0 ** 16


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    residual: Optional[dict]  # error-feedback state (None if no compression)
    step: jax.Array
    loss_scale: jax.Array  # f32 dynamic loss scale (numerics guard)
    good_steps: jax.Array  # i32 consecutive finite steps since last growth
    skipped: jax.Array  # i32 total non-finite updates skipped


def init_train_state(key, model_cfg: ModelConfig, train_cfg: TrainConfig) -> TrainState:
    api = get_model(model_cfg)
    params = api.init(key, model_cfg)
    residual = (
        init_residual(params) if train_cfg.compression.kind != "none" else None
    )
    dt = None if train_cfg.opt_state_dtype == "float32" else train_cfg.opt_state_dtype
    return TrainState(
        params, init_opt(params, state_dtype=dt), residual, jnp.int32(0),
        jnp.float32(train_cfg.loss_scale_init), jnp.int32(0), jnp.int32(0),
    )


def _split_microbatches(batch: Dict, n: int) -> Dict:
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _cast_params_sharded(params, cdt):
    """Cast ≥2-D f32 masters to the compute dtype, re-asserting each leaf's
    FSDP/TP sharding so XLA's partitioner gathers the bf16 copy (the convert
    lands before the all-gather). 1-D leaves (norm scales, gates, A_log)
    stay f32 — negligible traffic, and some are used in f32 math."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd

    ctx = shd.active_ctx()
    specs = None
    if ctx is not None:
        specs = jax.tree_util.tree_leaves(
            shd.param_specs(params), is_leaf=lambda x: isinstance(x, P)
        )
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i, p in enumerate(leaves):
        q = p.astype(cdt) if (p.ndim >= 2 and p.dtype == jnp.float32) else p
        if specs is not None:
            q = jax.lax.with_sharding_constraint(q, specs[i])
        out.append(q)
    return jax.tree_util.tree_unflatten(treedef, out)


def _select_tree(pred, new, old):
    """Leafwise `pred ? new : old` — the guard's skip-update selection."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), new, old)


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""
    api = get_model(model_cfg)
    bf16_grads = train_cfg.grad_dtype == "bfloat16"
    if train_cfg.cast_params_once and not bf16_grads:
        def loss_fn(p, b):
            return api.loss(_cast_params_sharded(p, model_cfg.compute_dtype), b, model_cfg)
    else:
        loss_fn = lambda p, b: api.loss(p, b, model_cfg)
    guard = train_cfg.numerics_guard

    def grads_of(params, batch, scale):
        """(scaled loss, metrics), grads of the SCALED loss (grad_dtype)."""
        if not bf16_grads:
            return jax.value_and_grad(
                lambda p, b: ((lambda l, m: (l * scale, m))(*loss_fn(p, b))),
                has_aux=True,
            )(params, batch)
        # differentiate w.r.t. the bf16 tree: grads (and their reductions)
        # stay bf16; masters get the upcast copy at the optimizer
        params_b = _cast_params_sharded(params, model_cfg.compute_dtype)
        (loss, metrics), g_b = jax.value_and_grad(
            lambda p, b: ((lambda l, m: (l * scale, m))(*api.loss(p, b, model_cfg))),
            has_aux=True,
        )(params_b, batch)
        return (loss, metrics), g_b

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        scale = state.loss_scale if guard else jnp.float32(1.0)
        n = train_cfg.accum_steps
        if n > 1:
            mb = _split_microbatches(batch, n)

            def accum(carry, one_batch):
                g_acc, l_acc, m_acc = carry
                (loss, metrics), grads = grads_of(state.params, one_batch, scale)
                # in-place add into the carried accumulator (single buffer)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, l_acc + loss, m_acc), None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            metrics0 = jax.eval_shape(
                lambda p, b: loss_fn(p, b)[1], state.params,
                jax.tree.map(lambda x: x[0], mb),
            )
            zeros_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), metrics0)
            (grads, loss, metrics), _ = jax.lax.scan(
                accum, (zeros_g, jnp.float32(0.0), zeros_m), mb
            )
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = jax.tree.map(lambda m: m / n, metrics)
        else:
            (loss, metrics), grads = grads_of(state.params, batch, scale)

        # unscale (exact: power-of-two scales); Inf/NaN survive the divide,
        # so detection on the unscaled tree still catches overflow
        inv = jnp.float32(1.0) / scale
        grads = jax.tree.map(lambda g: g * inv.astype(g.dtype), grads)
        loss = loss * inv
        finite = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            finite &= jnp.all(jnp.isfinite(g))

        residual = state.residual
        if train_cfg.compression.kind != "none":
            grads, residual = compress_gradients(
                grads, residual, train_cfg.compression
            )

        lr = warmup_cosine(
            state.step,
            peak_lr=train_cfg.optimizer.lr,
            warmup_steps=train_cfg.warmup_steps,
            total_steps=train_cfg.total_steps,
        )
        params, opt, opt_metrics = apply_updates(
            state.params, grads, state.opt, train_cfg.optimizer, lr=lr
        )
        if guard:
            # skip-update: non-finite grads leave params/opt/residual (and
            # the EF residual's view of what was "sent") untouched
            params = _select_tree(finite, params, state.params)
            opt = _select_tree(finite, opt, state.opt)
            if residual is not None:
                residual = _select_tree(finite, residual, state.residual)
            good = jnp.where(finite, state.good_steps + 1, 0)
            interval = train_cfg.loss_scale_growth_interval
            if interval > 0:
                ripe = finite & (good >= interval)
                # grow only while doubling stays ≤ max (never pull an
                # above-max scale down — halving is the only down-path)
                grow = ripe & (scale * 2.0 <= train_cfg.loss_scale_max)
                scale_ok = jnp.where(grow, scale * 2.0, scale)
                good = jnp.where(ripe, 0, good)
            else:
                scale_ok = scale
            new_scale = jnp.where(
                finite, scale_ok,
                jnp.maximum(scale * 0.5, train_cfg.loss_scale_min),
            )
            skipped = state.skipped + jnp.where(finite, 0, 1).astype(jnp.int32)
        else:
            good = state.good_steps
            new_scale = state.loss_scale
            skipped = state.skipped
        new_state = TrainState(
            params, opt, residual, state.step + 1, new_scale, good, skipped
        )
        guard_metrics = {
            "loss_scale": scale,
            "skipped": skipped.astype(jnp.float32),
            "finite": finite.astype(jnp.float32),
        }
        return new_state, {"loss": loss, **metrics, **opt_metrics, **guard_metrics}

    return train_step
