from repro.train.resilient import ResilienceConfig, train_resilient
from repro.train.train_step import TrainConfig, TrainState, init_train_state, make_train_step

__all__ = [
    "TrainConfig",
    "TrainState",
    "init_train_state",
    "make_train_step",
    "ResilienceConfig",
    "train_resilient",
]
