"""recurrentgemma-9b — Griffin: RG-LRU + local attention 1:2
[arXiv:2402.19427; unverified].

38L (12 full (rglru, rglru, attn_local) blocks + 2 remainder rglru layers),
d_model 4096, 16 heads (MQA kv=1), d_ff 12288, vocab 256000, window 2048.
lru_width = d_model (assumption documented in DESIGN.md). Sub-quadratic by
construction — runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    pattern=(
        ("rglru", "swiglu"),
        ("rglru", "swiglu"),
        ("attn_local", "swiglu"),
    ),
    attn_window=2048,
    lru_width=4096,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    pattern=(
        ("rglru", "swiglu"),
        ("rglru", "swiglu"),
        ("attn_local", "swiglu"),
    ),
    attn_window=8,
    lru_width=64,
    vocab_pad_multiple=64,
)
