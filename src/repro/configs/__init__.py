"""Config registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.shapes import SHAPES, ShapeCfg, cell_status, input_specs, cache_specs
from repro.models.config import ModelConfig

_MODULES = {
    "deepseek-7b": "deepseek_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "yi-34b": "yi_34b",
    "qwen3-0.6b": "qwen3_0_6b",
    "mamba2-2.7b": "mamba2_2_7b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "paper-llama": "paper_llama",
}

ARCHS: List[str] = [a for a in _MODULES if a != "paper-llama"]


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def cells() -> List[Tuple[str, str, bool, str]]:
    """All 40 (arch, shape) cells → (arch, shape, runnable, skip_reason)."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = cell_status(cfg, shape)
            out.append((arch, shape.name, ok, reason))
    return out


__all__ = [
    "ARCHS", "SHAPES", "ShapeCfg", "get_config", "get_smoke_config",
    "cells", "cell_status", "input_specs", "cache_specs", "ModelConfig",
]
