"""deepseek-7b — dense llama-arch decoder [arXiv:2401.02954; hf].

30L, d_model 4096, 32 heads (GQA kv=32 ⇒ MHA), d_ff 11008, vocab 102400.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    head_dim=128,
    pattern=(("attn", "swiglu"),),
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    pattern=(("attn", "swiglu"),),
    vocab_pad_multiple=64,
)
