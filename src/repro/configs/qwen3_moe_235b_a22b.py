"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3 family; hf].

94L, d_model 4096, 64 heads (GQA kv=4), per-expert d_ff 1536, vocab 151936.
Experts shard over the 16-way model axis (8 experts/shard).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    pattern=(("attn", "moe"),),
    n_experts=128,
    n_experts_active=8,
    capacity_factor=1.25,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=32,
    vocab_size=512,
    head_dim=16,
    qk_norm=True,
    pattern=(("attn", "moe"),),
    n_experts=8,
    n_experts_active=2,
    vocab_pad_multiple=64,
)
