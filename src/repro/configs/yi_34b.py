"""yi-34b — dense llama-arch GQA [arXiv:2403.04652; hf].

60L, d_model 7168, 56 heads (GQA kv=8), d_ff 20480, vocab 64000.
The largest dense assigned arch — the FSDP+TP+SP memory stress test.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    pattern=(("attn", "swiglu"),),
    rope_theta=5000000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    head_dim=16,
    pattern=(("attn", "swiglu"),),
    vocab_pad_multiple=64,
)
