"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060].

64L, d_model 2560, ssm_state 128, vocab 50280. FLASH-D is inapplicable
(no softmax attention) — arch implemented without it per the assignment;
noted in DESIGN.md §Arch-applicability. Runs long_500k (sub-quadratic).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    pattern=(("ssm", "none"),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=512,
    pattern=(("ssm", "none"),),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=8,
    vocab_pad_multiple=64,
)
