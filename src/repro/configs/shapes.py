"""Assigned input-shape sets and the (arch × shape) cell matrix.

Every LM arch pairs with four shapes; decode_*/long_* lower `serve_step`
(one token against a cache of seq_len), train_4k lowers `train_step`,
prefill_32k lowers the forward pass. long_500k runs only for sub-quadratic
archs (assignment skip rule — skips recorded, not silently dropped).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg: ModelConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k skipped: pure full-attention arch (assignment rule; "
            "sub-quadratic attention required at 524k context)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCfg):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation — these feed `.lower()` for the dry-run and
    `jax.eval_shape` everywhere else.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.is_encdec:
            batch = {
                "frame_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif cfg.frontend == "vision":
            n_patch = cfg.frontend_tokens
            batch = {
                "patch_embeds": jax.ShapeDtypeStruct((b, n_patch, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, s - n_patch), i32),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "train":
            label_len = batch["tokens"].shape[1]
            batch["labels"] = jax.ShapeDtypeStruct((b, label_len), i32)
        return batch
    # decode: one token per sequence + absolute positions
    return {
        "token": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeCfg):
    """Abstract decode-cache tree (ShapeDtypeStructs) for decode shapes."""
    from repro.models import get_model

    api = get_model(cfg)
    return jax.eval_shape(
        lambda: api.init_cache(shape.global_batch, shape.seq_len, cfg)
    )
