"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596; hf].

12L encoder + 12L decoder, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 256206 (padded to 256256 for clean 16-way vocab sharding). The audio
frontend is a STUB: input_specs provide precomputed frame embeddings.
Deviation: RoPE replaces the original relative-position scheme (DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12,
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    pattern=(("attn", "swiglu"),),
    frontend="audio",
)

SMOKE = ModelConfig(
    name="seamless-m4t-smoke",
    n_layers=2,
    n_encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    pattern=(("attn", "swiglu"),),
    frontend="audio",
    vocab_pad_multiple=64,
)
