"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L, d_model 3072, 32 heads (kv=32), d_ff 8192, vocab 32064. The modality
frontend is a STUB per the assignment: input_specs provide precomputed
patch embeddings [B, 256, d_model] that a learned projection prepends to
the token stream.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    pattern=(("attn", "swiglu"),),
    frontend="vision",
    frontend_tokens=256,
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    pattern=(("attn", "swiglu"),),
    frontend="vision",
    frontend_tokens=8,
    vocab_pad_multiple=64,
)
