"""qwen2-1.5b — dense GQA with QKV bias [arXiv:2407.10671; hf].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936.
Note: 12 query heads do NOT divide the 16-way model axis — the sharding
rule engine falls back per-dim (DESIGN.md §4); this arch is the divisibility
stress test.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    pattern=(("attn", "swiglu"),),
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke",
    n_layers=2,
    d_model=48,
    n_heads=6,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    head_dim=16,
    qkv_bias=True,
    pattern=(("attn", "swiglu"),),
    vocab_pad_multiple=64,
)
