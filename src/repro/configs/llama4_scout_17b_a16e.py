"""llama4-scout-17b-a16e — 16-expert top-1 MoE, iRoPE chunked attention
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model 5120, 40 heads (GQA kv=8), d_ff 8192, vocab 202048.
Attention pattern 3:1 — three chunked-local (RoPE, chunk 8192) layers per
one global NoPE layer. Chunked layers keep long_500k sub-quadratic; the
global layer reads the whole cache once per decode step (linear/step).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    pattern=(
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn_nope", "moe"),
    ),
    attn_chunk=8192,
    n_experts=16,
    n_experts_active=1,
    capacity_factor=1.25,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    head_dim=16,
    pattern=(
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn_chunked", "moe"),
        ("attn_nope", "moe"),
    ),
    attn_chunk=16,
    n_experts=4,
    n_experts_active=1,
    vocab_pad_multiple=64,
)
