"""qwen3-0.6b — dense GQA with qk_norm [hf:Qwen/Qwen3-8B family; hf].

28L, d_model 1024, 16 heads (GQA kv=8), d_ff 3072, vocab 151936.
head_dim 128 is decoupled from d_model/n_heads (Qwen3 convention).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    pattern=(("attn", "swiglu"),),
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=32,
    qk_norm=True,
    pattern=(("attn", "swiglu"),),
    vocab_pad_multiple=64,
)
