"""paper-llama — the paper's own evaluation vehicle (§V: llama2.c-style).

The paper verified the FLASH-D C++ datapath by integrating it into
llama2.c and checking bit-identical replies, then measured Table-I skip
rates on small HF LLMs. This config is the equivalently-sized model this
repo trains end-to-end (examples/train_lm.py) and measures skip rates on
(benchmarks/table1_skiprate.py). ~15M params trains on the CPU container;
PAPER_110M matches llama2.c's stories110M for the scaled run.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(  # llama2.c stories15M-shaped
    name="paper-llama-15m",
    n_layers=6,
    d_model=288,
    n_heads=6,
    n_kv_heads=6,
    d_ff=768,
    vocab_size=512,  # byte-ish toy vocab for the synthetic pipeline
    head_dim=48,
    pattern=(("attn", "swiglu"),),
    vocab_pad_multiple=64,
    dtype="float32",
    remat="none",
)

PAPER_110M = ModelConfig(  # llama2.c stories110M-shaped (end-to-end driver)
    name="paper-llama-110m",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab_size=32000,
    head_dim=64,
    pattern=(("attn", "swiglu"),),
    dtype="float32",
    remat="none",
)

SMOKE = CONFIG
