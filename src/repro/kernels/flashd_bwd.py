"""Pallas TPU kernels: FLASH-D backward (dQ, dK, dV) from saved (O, Λ).

Probabilities are reconstructed as P = exp(s − Λ); with FLASH-D's Λ the
exponent is always ≤ 0, so the backward — like the forward — needs no
max-subtraction pass and cannot overflow (DESIGN.md §2.1). Two kernels,
the canonical TPU split:

  dq kernel : grid (B, H_q, q_block, kv_block), kv innermost; carries
              dQ_acc in VMEM, writes at the last kv step.
  dkv kernel: grid (B, H_kv, kv_block, g·q_block), the q-head group is
              folded into the innermost loop so GQA's dK/dV accumulate over
              their query group without revisiting output blocks.

D = rowsum(dO ∘ O) is precomputed by the wrapper (one fused jnp reduction).
Masks reuse the forward's in-kernel position logic; statically-dead tiles
are predicated off with `pl.when`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.core.blockwise import MaskSpec, NEG_INF, tile_live
from repro.kernels.flashd_fwd import _mask_bias

__all__ = ["flashd_bwd_pallas"]


def _recompute_p_ds(q, k, v, do, lam, dsum, q_pos, k_pos, mask, scale, kv_len):
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    keep = _mask_bias(mask, q_pos, k_pos, kv_len)
    s = jnp.where(keep, s, NEG_INF)
    p = jnp.exp(s - lam[:, None])  # exponent ≤ 0 — overflow-free
    p = jnp.where(lam[:, None] <= NEG_INF / 2, 0.0, p)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - dsum[:, None]) * scale
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lam_ref, dsum_ref, dq_ref, acc_ref,
               *, mask, scale, block_q, block_k, kv_len, n_kv):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)

    @pl.when(tile_live(mask, iq, ik, block_q, block_k, kv_len))
    def _body():
        _, ds = _recompute_p_ds(
            q_ref[0, 0].astype(jnp.float32), k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32), do_ref[0, 0].astype(jnp.float32),
            lam_ref[0, 0], dsum_ref[0, 0], q_pos, k_pos, mask, scale, kv_len,
        )
        acc_ref[...] += jax.lax.dot_general(
            ds, k_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == n_kv - 1)
    def _fin():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lam_ref, dsum_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, mask, scale, block_q, block_k, kv_len, n_q, group):
    ik, inner = pl.program_id(2), pl.program_id(3)
    iq = inner % n_q

    @pl.when(inner == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)

    @pl.when(tile_live(mask, iq, ik, block_q, block_k, kv_len))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        p, ds = _recompute_p_ds(
            q, k_ref[0, 0].astype(jnp.float32), v_ref[0, 0].astype(jnp.float32),
            do, lam_ref[0, 0], dsum_ref[0, 0], q_pos, k_pos, mask, scale, kv_len,
        )
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(inner == n_q * group - 1)
    def _fin():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flashd_bwd_pallas(
    q: jax.Array,  # [B, Hq, Sq, d]
    k: jax.Array,  # [B, Hkv, Skv, d]
    v: jax.Array,  # [B, Hkv, Skv, dv]
    o: jax.Array,  # [B, Hq, Sq, dv]
    lam: jax.Array,  # [B, Hq, Sq] f32
    do: jax.Array,  # [B, Hq, Sq, dv]
    *,
    mask: MaskSpec = MaskSpec("causal"),
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
):
    b, hq, sq, d = q.shape
    _, hkv, skv, dv = v.shape
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        widths = ((0, 0), (0, 0), (0, pad_q), (0, 0))
        q, o, do = (jnp.pad(x, widths) for x in (q, o, do))
        lam = jnp.pad(lam, ((0, 0), (0, 0), (0, pad_q)), constant_values=NEG_INF)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (sq + pad_q) // block_q
    n_k = (skv + pad_k) // block_k

    dsum = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # [B,Hq,Sq']

    scr = (lambda shp: [pltpu.VMEM(shp, jnp.float32)]) if _HAS_PLTPU else (lambda shp: [])

    # ---- dQ ----
    dq_kernel = functools.partial(
        _dq_kernel, mask=mask, scale=scale, block_q=block_q, block_k=block_k,
        kv_len=skv, n_kv=n_k,
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=g: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, iq, ik, g=g: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_q, dv), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq + pad_q, d), q.dtype),
        scratch_shapes=scr((block_q, d)),
        interpret=interpret,
    )(q, k, v, do, lam, dsum)

    # ---- dK, dV (q-group folded into the inner loop) ----
    dkv_kernel = functools.partial(
        _dkv_kernel, mask=mask, scale=scale, block_q=block_q, block_k=block_k,
        kv_len=skv, n_q=n_q, group=g,
    )

    def qhead(b_, h, ik, inner, g=g, n_q=n_q):
        return (b_, h * g + inner // n_q, inner % n_q, 0)

    def qhead3(b_, h, ik, inner, g=g, n_q=n_q):
        return (b_, h * g + inner // n_q, inner % n_q)

    dk, dv_out = pl.pallas_call(
        dkv_kernel,
        grid=(b, hkv, n_k, n_q * g),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), qhead),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, ik, inner: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, ik, inner: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, dv), qhead),
            pl.BlockSpec((1, 1, block_q), qhead3),
            pl.BlockSpec((1, 1, block_q), qhead3),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, ik, inner: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, ik, inner: (b_, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, skv + pad_k, d), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, skv + pad_k, dv), v.dtype),
        ],
        scratch_shapes=scr((block_k, d)) + scr((block_k, dv)),
        interpret=interpret,
    )(q, k, v, do, lam, dsum)

    return dq[:, :, :sq], dk[:, :, :skv], dv_out[:, :, :skv]
