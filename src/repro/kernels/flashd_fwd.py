"""Pallas TPU kernel: blockwise FLASH-D forward (prefill / training fwd).

Grid (batch, q_head, q_block, kv_block) — kv_block is the innermost,
sequential ("arbitrary") dimension; the (O, Λ) recurrence is carried in VMEM
scratch across kv steps, exactly the canonical TPU flash-attention structure,
but with the FLASH-D carry: **one f32 scratch row-vector (Λ) instead of two
(m, ℓ), and no division / epilogue normalization pass anywhere**:

    W_b = σ(λ_b − Λ)          c_b = e^{m_b − Λ'}        Λ' = λ_b − ln W_b
    acc ← acc·(1−W_b) + (P_b V_b)·c_b

Tile-level skipping (paper §III-C generalized, DESIGN.md §2.1): when every
row of the tile satisfies m_b − Λ < −(θ + ln B_k) the exp, the P·V MXU
matmul and the blend are all predicated off with `pl.when` — the tile's
total weight is < σ(−θ) ≈ 2.5e-3 of the output. Partial-row skips fall back
to VPU selects, which are exact.

GQA is handled in the index maps: q head h reads kv head h // group_size.
Causal / local / chunked masks: tiles that are statically outside the mask
never compute (pl.when on block indices); boundary tiles apply an in-kernel
position mask.

VMEM budget per grid step (f32): q (B_q·d) + k,v (2·B_k·d) + acc (B_q·d)
+ Λ (B_q) + scores (B_q·B_k). Defaults B_q = B_k = 512, d = 128 →
~2.6 MB, comfortably inside the ~16 MB/core VMEM of TPU v5e.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific bits are optional so the module imports on CPU hosts
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.core.blockwise import MaskSpec, NEG_INF, DEFAULT_SKIP_THETA, tile_live

__all__ = ["flashd_fwd_pallas"]


def _mask_bias(mask: MaskSpec, q_pos, k_pos, kv_len: int):
    """In-kernel additive bias for a (B_q, B_k) tile; None if fully visible."""
    keep = k_pos[None, :] < kv_len  # mask padded keys
    if mask.kind != "full":
        qp = (q_pos + mask.q_offset)[:, None]
        kp = k_pos[None, :]
        if mask.kind == "causal":
            keep = keep & (kp <= qp)
        elif mask.kind == "local":
            keep = keep & (kp <= qp) & (qp - kp < mask.window)
        elif mask.kind == "chunked":
            keep = keep & (kp <= qp) & (qp // mask.chunk == kp // mask.chunk)
        else:
            raise ValueError(mask.kind)
    return keep


def _flashd_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref, lam_ref,  # outputs
    acc_ref, lam_scratch,  # VMEM scratch
    *,
    mask: MaskSpec,
    scale: float,
    block_q: int,
    block_k: int,
    q_len: int,
    kv_len: int,
    n_kv_blocks: int,
    skip: bool,
    skip_theta: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        lam_scratch[...] = jnp.full_like(lam_scratch, NEG_INF)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)

    # static tile pruning: tiles fully outside the mask never compute;
    # fully-padded q tiles (from pad_q) have no live rows: skip their whole
    # kv loop rather than running it into masked-out scores
    compute = jnp.logical_and(
        tile_live(mask, iq, ik, block_q, block_k, kv_len),
        iq * block_q < q_len,
    )

    @pl.when(compute)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [B_q, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [B_k, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [B_q, B_k] on the MXU
        keep = _mask_bias(mask, q_pos, k_pos, kv_len)
        s = jnp.where(keep, s, NEG_INF)

        m_b = jnp.max(s, axis=-1)  # tile-LOCAL max; no cross-tile chain
        lam_run = lam_scratch[0]

        def _update():
            m_safe = jnp.maximum(m_b, NEG_INF / 2)
            p = jnp.exp(s - m_safe[:, None])
            l_b = jnp.sum(p, axis=-1)
            lam_b = jnp.where(
                l_b > 0,
                m_safe + jnp.log(jnp.maximum(l_b, jnp.finfo(jnp.float32).tiny)),
                NEG_INF,
            )
            delta = lam_b - lam_run
            w = jax.nn.sigmoid(delta)  # division hidden here
            ln_w = jax.nn.log_sigmoid(delta)
            lam_new = lam_b - ln_w  # = logaddexp, division-free
            tile_dead = lam_b <= NEG_INF / 2
            first = lam_run <= NEG_INF / 2
            w = jnp.where(tile_dead, 0.0, jnp.where(first, 1.0, w))
            lam_new = jnp.where(tile_dead, lam_run, jnp.where(first, lam_b, lam_new))
            c = jnp.where(tile_dead, 0.0, jnp.exp(m_safe - lam_new))  # ≤ 1

            v = v_ref[0, 0].astype(jnp.float32)
            pv = jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            if skip:  # per-row predication (exact at any θ by construction)
                row_skip = jnp.logical_and(
                    m_b - lam_run < -(skip_theta + jnp.log(jnp.float32(block_k))),
                    ~first,
                )
                w = jnp.where(row_skip, 0.0, w)
                c = jnp.where(row_skip, 0.0, c)
                lam_new = jnp.where(row_skip, lam_run, lam_new)
            acc_ref[...] = acc_ref[...] * (1.0 - w)[:, None] + pv * c[:, None]
            lam_scratch[0] = lam_new

        if skip:
            # whole-tile skip: every row below threshold ⇒ no exp, no MXU
            # matmul, no blend. This is the FLOP-level win on TPU.
            any_live = jnp.any(
                m_b - lam_run >= -(skip_theta + jnp.log(jnp.float32(block_k)))
            )
            first_any = jnp.any(lam_run <= NEG_INF / 2)
            pl.when(jnp.logical_or(any_live, first_any))(_update)
        else:
            _update()

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        # No division, no rescale: acc already holds softmax(S)·V exactly.
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
        lam_ref[0, 0] = lam_scratch[0]


def flashd_fwd_pallas(
    q: jax.Array,  # [B, Hq, Sq, d]
    k: jax.Array,  # [B, Hkv, Skv, d]
    v: jax.Array,  # [B, Hkv, Skv, dv]
    *,
    mask: MaskSpec = MaskSpec("causal"),
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    skip: bool = False,
    skip_theta: float = DEFAULT_SKIP_THETA,
    interpret: bool = False,
):
    """Returns (o [B, Hq, Sq, dv] in q.dtype, Λ [B, Hq, Sq] f32).

    block_q / block_k = None picks the tiling from the VMEM-budget
    heuristics in repro.kernels.tuning."""
    b, hq, sq, d = q.shape
    _, hkv, skv, dv = v.shape
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    group = hq // hkv

    if block_q is None or block_k is None:
        from repro.kernels.tuning import choose_prefill_blocks  # lazy: no cycle

        tiling = choose_prefill_blocks(sq, skv, d, dv)
        block_q = tiling.block_q if block_q is None else block_q
        block_k = tiling.block_k if block_k is None else block_k
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (sq + pad_q) // block_q
    n_k = (skv + pad_k) // block_k

    grid = (b, hq, n_q, n_k)
    kernel = functools.partial(
        _flashd_kernel,
        mask=mask,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        q_len=sq,
        kv_len=skv,
        n_kv_blocks=n_k,
        skip=skip,
        skip_theta=skip_theta,
    )

    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, block_q, dv), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, hq, sq + pad_q, dv), q.dtype),
        jax.ShapeDtypeStruct((b, hq, sq + pad_q), jnp.float32),
    ]
    scratch_shapes = None
    compiler_params = None
    if _HAS_PLTPU:
        scratch_shapes = [
            pltpu.VMEM((block_q, dv), jnp.float32),
            pltpu.VMEM((1, block_q), jnp.float32),
        ]
        try:
            compiler_params = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
            )
        except Exception:  # older/newer API name drift
            compiler_params = None

    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes or [],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )
    o, lam = call(q, k, v)
    return o[:, :, :sq], lam[:, :, :sq]
