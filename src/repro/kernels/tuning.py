"""Tile/split autotuner for the FLASH-D kernels (DESIGN.md §3).

Every kernel entry point (and the jnp tiled recurrences behind
`repro.core.attention`) routes its tiling through this module when the
caller does not pin one explicitly:

  prefill / training fwd — (block_q, block_k) per (Sq, Skv, d, dv), sized
      so the per-step VMEM working set (q, k, v, acc, Λ, scores tiles)
      fits a configurable budget, preferring MXU-friendly multiples of 128;
  decode — (n_splits, split) per (S_max, d, dv, G), sized so one split's
      KV block (+ the [G, split] score tile) fits the budget with splits
      long enough to amortize DMA issue overhead;
  ring context-parallel prefill — per-hop (block_q, block_k) for the
      per-shard kernel plus the number of *live* ring hops (structured
      masks kill distant hops statically: a sliding window only ever needs
      ⌈window/shard⌉ + 1 of the n_devices hops, so the ring stops early
      and the dead hops' KV exchange never hits the wire).

Two modes:
  heuristic (default) — closed-form from the shape and the VMEM budget;
      pure Python on static shapes, so decisions are jit-stable.
  measured — `measure_best` times a candidate set on the current backend
      and caches the winner per shape key (process-lifetime cache). The
      benchmark harness and power users opt in; unit tests pin it down.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax

from repro.core.blockwise import MaskSpec

__all__ = [
    "PrefillTiling",
    "DecodeSplit",
    "RingSchedule",
    "PageLayout",
    "VarlenBlocks",
    "choose_prefill_blocks",
    "choose_decode_split",
    "choose_ring_schedule",
    "choose_page_size",
    "choose_page_layout",
    "choose_cache_policy",
    "choose_varlen_blocks",
    "bucket_pow2",
    "prefill_vmem_bytes",
    "decode_vmem_bytes",
    "measure_best",
    "clear_measure_cache",
    "VMEM_BUDGET_BYTES",
]

# ~16 MB VMEM per TPU core (v4/v5e); leave headroom for double buffering,
# spills and the compiler's own scratch.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
VMEM_BUDGET_BYTES = VMEM_BYTES_PER_CORE // 2

_LANE = 128  # MXU/VPU lane width — tiles want multiples of this
_MIN_BLOCK = 8  # f32 sublane minimum


@dataclasses.dataclass(frozen=True)
class PrefillTiling:
    block_q: int
    block_k: int


@dataclasses.dataclass(frozen=True)
class DecodeSplit:
    n_splits: int
    split: int


@dataclasses.dataclass(frozen=True)
class RingSchedule:
    """Static schedule for ring context-parallel prefill (DESIGN.md §4.1).

    n_hops    — live hops; hop h puts each device's KV shard h shards
                behind its q shard, so structured masks make distant hops
                statically dead (a prefix of the ring suffices).
    block_q/k — per-shard kernel tiling (from the prefill heuristics at
                the shard shape).
    """

    n_hops: int
    block_q: int
    block_k: int


def prefill_vmem_bytes(block_q: int, block_k: int, d: int, dv: int) -> int:
    """f32 working set of one fwd grid step: q + k + v + acc + Λ + scores."""
    words = (
        block_q * d          # q tile
        + block_k * d        # k tile
        + block_k * dv       # v tile
        + block_q * dv       # acc scratch
        + block_q            # Λ scratch
        + block_q * block_k  # score tile
    )
    return 4 * words


def decode_vmem_bytes(
    split: int, d: int, dv: int, group: int, *, kv_itemsize: int = 4
) -> int:
    """Working set of one decode grid step: q + k + v + carry + scores.

    Everything is f32 except the K/V split, which is `kv_itemsize` bytes
    per element (1 for an int8/fp8 quantized page pool). A quantized tile
    also DMAs its per-page scale side-band (two f32 scalars)."""
    f32_words = (
        group * d            # q block
        + group * dv         # acc carry
        + group              # Λ carry
        + group * split      # score tile
    )
    kv_words = split * d + split * dv  # k split + v split
    side_band = 2 * 4 if kv_itemsize < 4 else 0  # k/v page scales
    return 4 * f32_words + kv_itemsize * kv_words + side_band


def _shrink_to_lane(n: int) -> int:
    """Largest multiple of _LANE ≤ n (or n itself when already below one lane)."""
    if n <= _LANE:
        return max(n, 1)
    return (n // _LANE) * _LANE


def choose_prefill_blocks(
    sq: int,
    skv: int,
    d: int,
    dv: Optional[int] = None,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> PrefillTiling:
    """Heuristic (block_q, block_k) for the tiled forward.

    Starts from the 512×512 sweet spot (MXU-saturating, small Λ overhead)
    and halves the larger block until the working set fits the budget.
    Blocks are clamped to the sequence lengths (short sequences should not
    pad to a full tile)."""
    dv = d if dv is None else dv
    block_q = min(512, max(sq, 1))
    block_k = min(512, max(skv, 1))
    while (
        prefill_vmem_bytes(block_q, block_k, d, dv) > vmem_budget
        and max(block_q, block_k) > _MIN_BLOCK
    ):
        if block_q >= block_k:
            block_q = max(_MIN_BLOCK, _shrink_to_lane(block_q // 2))
        else:
            block_k = max(_MIN_BLOCK, _shrink_to_lane(block_k // 2))
    return PrefillTiling(block_q=block_q, block_k=block_k)


def choose_decode_split(
    s_max: int,
    d: int,
    dv: Optional[int] = None,
    *,
    group: int = 1,
    window: int = 0,
    chunk: int = 0,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    kv_itemsize: int = 4,
) -> DecodeSplit:
    """Heuristic (n_splits, split) for split-K decode.

    The fused kernel walks splits sequentially with a VMEM carry, so the
    split length trades DMA pipelining granularity against VMEM footprint:
    long splits amortize issue overhead, short splits let masked (dead)
    regions be skipped at finer grain. Target 512 positions per split —
    shrunk until the KV block fits the budget, and never longer than the
    live mask region (window / chunk caches only ever attend that many).
    `kv_itemsize` is the stored K/V element width (1 for a quantized
    pool) — smaller elements let more positions fit one split."""
    dv = d if dv is None else dv
    s_max = max(s_max, 1)
    live = s_max
    if window > 0:
        live = min(live, window)
    if chunk > 0:
        live = min(live, chunk)

    split = min(512, s_max)
    while (
        decode_vmem_bytes(split, d, dv, group, kv_itemsize=kv_itemsize)
        > vmem_budget
        and split > _MIN_BLOCK
    ):
        split = max(_MIN_BLOCK, _shrink_to_lane(split // 2))
    # a split longer than the live region wastes masked work at its edges
    if live < split:
        split = max(_MIN_BLOCK, min(split, _shrink_to_lane(live) or live))
    n_splits = max(1, -(-s_max // split))
    split = -(-s_max // n_splits)  # actual padded split length
    return DecodeSplit(n_splits=n_splits, split=split)


def choose_ring_schedule(
    sq_shard: int,
    skv_shard: int,
    d: int,
    dv: Optional[int] = None,
    *,
    n_devices: int,
    mask: MaskSpec = MaskSpec("causal"),
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> RingSchedule:
    """Heuristic ring schedule for context-parallel prefill.

    At hop h every device's resident KV shard sits exactly h shards behind
    its q shard (canonical +1 ring rotation), so the hop's mask offset is
    the *static* value h·skv_shard and hop liveness is decidable at trace
    time: causal masks keep all n hops (wrapped shards are future ⇒ dead
    per-device, handled dynamically), a sliding window keeps only hops with
    h·S − (S−1) < window, chunked keeps hops inside the q chunk. Dead hops
    are a suffix of the ring (offsets grow monotonically), so the schedule
    is just the live-prefix length — later hops skip both the kernel and
    the KV wire transfer entirely.
    """
    n_hops = n_devices
    if mask.kind in ("causal", "local", "chunked"):
        n_hops = 0
        for h in range(n_devices):
            hop = dataclasses.replace(mask, q_offset=mask.q_offset + h * skv_shard)
            if hop.block_fully_masked(0, sq_shard, 0, skv_shard):
                break
            n_hops = h + 1
    tiling = choose_prefill_blocks(
        sq_shard, skv_shard, d, dv, vmem_budget=vmem_budget
    )
    return RingSchedule(
        n_hops=max(n_hops, 1), block_q=tiling.block_q, block_k=tiling.block_k
    )


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Paged KV-cache geometry (DESIGN.md §3.4): `page_size` tokens per
    page, `n_pages` pages in the pool (page 0 is the reserved garbage
    page), `pages_per_seq` block-table width covering max_len."""

    page_size: int
    n_pages: int
    pages_per_seq: int


def choose_page_size(
    max_len: int,
    d: int,
    dv: Optional[int] = None,
    *,
    group: int = 1,
    window: int = 0,
    chunk: int = 0,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    kv_itemsize: int = 4,
) -> int:
    """Heuristic page size for the paged decode kernel.

    A page doubles as the kernel's split: each grid step DMAs one
    (page, d) K/V block through the block-table indirection and merges it
    into the FLASH-D carry. The competing pressures:

      * kernel: long pages amortize DMA issue overhead and keep the MXU
        fed — same force as the decode split heuristic;
      * allocator: internal fragmentation wastes up to page−1 tokens per
        live sequence, so serving many short sequences wants small pages;
      * radix cache: only FULL pages are cacheable, so a max-length
        sequence must span ≥ 2 pages or the prefix cache can never index
        anything (one page per sequence means the lone page is never
        "full" until the sequence retires at exactly max_len).

    We take the decode-split answer (VMEM-fitted, ≤ live mask region),
    cap it at 64 tokens — at that size the fragmentation bound is ≤ 63
    tokens/seq while a [64, d] tile still fills an MXU pass for d ≥ 128 —
    and additionally at max_len // 2 whenever max_len ≥ 16 (the ≥ 2 pages
    guarantee above; below 16 tokens a useful cache granule doesn't exist
    and kernel efficiency wins), then round down to a power of two so page
    arithmetic (pos // page, pos % page) stays cheap on the scalar core."""
    split = choose_decode_split(
        max_len, d, dv, group=group, window=window, chunk=chunk,
        vmem_budget=vmem_budget, kv_itemsize=kv_itemsize,
    ).split
    size = min(64, split, max(max_len, 1))
    if max_len >= 16:
        size = min(size, max_len // 2)
    return max(_MIN_BLOCK // 2, 1 << (max(size, 1).bit_length() - 1))


def choose_page_layout(
    max_len: int,
    d: int,
    dv: Optional[int] = None,
    *,
    group: int = 1,
    pool_tokens: int,
    page_size: Optional[int] = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    kv_itemsize: int = 4,
) -> PageLayout:
    """Full pool geometry for a token budget: pages covering `pool_tokens`
    plus the reserved garbage page (id 0, the write target of dead batch
    slots — never allocated)."""
    page = page_size or choose_page_size(
        max_len, d, dv, group=group, vmem_budget=vmem_budget,
        kv_itemsize=kv_itemsize,
    )
    n_pages = max(2, -(-pool_tokens // page) + 1)
    return PageLayout(
        page_size=page, n_pages=n_pages, pages_per_seq=-(-max_len // page)
    )


def choose_cache_policy(
    n_pages: int,
    page_size: int,
    *,
    min_free_pages: Optional[int] = None,
    max_cached_pages: Optional[int] = None,
):
    """Retention heuristics for the radix prefix cache (DESIGN.md §3.6).

    The cache trades pool headroom for prefill reuse, and the two knobs
    bound each side of that trade:

      * min_free_pages — eviction watermark. Donations evict LRU entries
        until this many pages are physically free, so a fresh admission
        usually finds pages without paying eviction latency on its own
        critical path. Default: 1/16 of the pool (≥ 1) — small enough
        that a hot shared prefix survives, large enough that the common
        single-page admission never blocks on eviction.
      * max_cached_pages — hard cap on retained pages. Default: the whole
        usable pool — retention is free (cached pages are reclaimed on
        demand before anything else gives), so the only reason to cap
        below that is to bound the host-side tree walk; callers serving
        adversarial (never-repeating) traffic can set it low or to 0 to
        disable retention.

    Explicit values are honored as given (0 is meaningful: a 0 watermark
    never proactively evicts; a 0 cap disables retention)."""
    from repro.runtime.kvcache import CachePolicy  # lazy: no cycle

    if min_free_pages is None:
        min_free_pages = max(1, n_pages // 16)
    if max_cached_pages is None:
        max_cached_pages = max(n_pages - 1, 0)
    return CachePolicy(
        min_free_pages=min_free_pages, max_cached_pages=max_cached_pages
    )


@dataclasses.dataclass(frozen=True)
class VarlenBlocks:
    """Tiling for the packed varlen kernel (DESIGN.md §3.5): `block_q`
    packed rows per q tile (segments are aligned to this, so it is also the
    per-sequence padding granularity of the packed layout)."""

    block_q: int


def varlen_vmem_bytes(
    block_q: int, page: int, d: int, dv: int, group: int,
    *, kv_itemsize: int = 4,
) -> int:
    """Working set of one varlen grid step: q + k + v + carry + scores.
    The q tile carries `group` heads per row (GQA rows collapse into the
    score matmul), the KV block is one page — stored at `kv_itemsize`
    bytes per element (1 when the page pool is quantized, plus the
    two-scalar f32 scale side-band)."""
    rows = block_q * group
    f32_words = (
        rows * d          # q tile
        + rows * dv       # acc carry
        + rows            # Λ carry
        + rows * page     # score tile
    )
    kv_words = page * d + page * dv  # k page + v page
    side_band = 2 * 4 if kv_itemsize < 4 else 0  # k/v page scales
    return 4 * f32_words + kv_itemsize * kv_words + side_band


def choose_varlen_blocks(
    total_tokens: int,
    d: int,
    dv: Optional[int] = None,
    *,
    group: int = 1,
    page: int = 64,
    segment_hint: Optional[int] = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    kv_itemsize: int = 4,
) -> VarlenBlocks:
    """Heuristic block_q for the packed varlen kernel.

    Larger q tiles amortize the page DMA over more rows, but every
    SEGMENT of the pack pads to a block multiple — a decode row (q_len 1)
    wastes block_q − 1 rows — so the tile must be sized to the typical
    segment, not the pack: `segment_hint` is the caller's expected tokens
    per segment (the scheduler passes 1 when decode rows share its packs,
    the prefill chunk when they don't, and K+1 when speculative verify
    segments dominate — a K=4 draft chain in a 128-row tile would waste
    123 rows, in its pow2 bucket (floor `_MIN_BLOCK`) it wastes ≤ 3;
    default: the whole pack, the single-segment case). Start from
    min(128, bucket(hint)) and halve until the working set fits the
    budget; floor at the f32 sublane minimum so alignment waste stays
    proportionate."""
    dv = d if dv is None else dv
    hint = max(min(segment_hint or total_tokens, total_tokens), 1)
    block_q = min(128, bucket_pow2(hint, lo=_MIN_BLOCK))
    while (
        varlen_vmem_bytes(block_q, page, d, dv, group, kv_itemsize=kv_itemsize)
        > vmem_budget
        and block_q > _MIN_BLOCK
    ):
        block_q = max(_MIN_BLOCK, block_q // 2)
    return VarlenBlocks(block_q=block_q)


def padded_rows(seg_len: int, block_q: int) -> int:
    """Pack rows one segment of `seg_len` tokens occupies: the packed
    layout aligns every segment to a `block_q` multiple so each q tile
    owns exactly one sequence (kernels/flashd_varlen.py). The engine's
    packer and the waste-pinning tests share this so the padding
    arithmetic can't drift between them."""
    if seg_len <= 0:
        return 0
    return -(-seg_len // block_q) * block_q


def bucket_pow2(n: int, *, lo: int = 8, hi: Optional[int] = None) -> int:
    """Smallest power of two ≥ n (clamped to [lo, hi]).

    The static-shape bucketing primitive (DESIGN.md §3.5): padding dynamic
    lengths — prompt lengths, packed-batch sizes — up to a power of two
    bounds the number of distinct compiled programs at O(log max_len)
    instead of one per distinct length. `hi` caps the bucket (a length
    already at the cap compiles exactly one program); a cap SMALLER than
    `n` would silently truncate the caller's batch, so it raises."""
    n = max(int(n), 1)
    if hi is not None and hi < n:
        raise ValueError(f"bucket_pow2: hi={hi} < n={n} would truncate")
    b = max(1 << (n - 1).bit_length(), lo)
    if hi is not None:
        b = min(b, hi)
    return b


# ---------------------------------------------------------------------------
# measured mode
# ---------------------------------------------------------------------------

_MEASURE_CACHE: Dict[Tuple, object] = {}


def clear_measure_cache() -> None:
    _MEASURE_CACHE.clear()


def measure_best(
    key: Tuple,
    candidates: Sequence,
    build: Callable[[object], Callable[[], jax.Array]],
    *,
    iters: int = 3,
):
    """Time `build(candidate)()` for each candidate; cache the winner by key.

    `build` returns a zero-arg thunk whose result is blocked on. The first
    call per candidate warms compilation; the best of `iters` timed calls
    wins. Failures (e.g. a block shape the backend rejects) disqualify the
    candidate rather than raising."""
    if key in _MEASURE_CACHE:
        return _MEASURE_CACHE[key]
    best = None
    best_t = float("inf")
    for cand in candidates:
        try:
            thunk = build(cand)
            jax.block_until_ready(thunk())  # warm-up / compile
            t = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(thunk())
                t = min(t, time.perf_counter() - t0)
        except Exception:
            continue
        if t < best_t:
            best, best_t = cand, t
    if best is None:
        raise RuntimeError(f"no measurable candidate for {key}")
    _MEASURE_CACHE[key] = best
    return best


def measured_decode_split(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    candidates: Iterable[int] = (1, 2, 4, 8, 16, 32),
    interpret: bool = False,
) -> DecodeSplit:
    """Measured-mode decode tuning: times the fused kernel at each split
    count on the live backend and returns the winner (cached per shape)."""
    from repro.kernels.flashd_decode import flashd_decode_pallas

    s_max = k_cache.shape[2]
    cands = sorted({max(1, min(int(c), s_max)) for c in candidates})
    key = ("decode", q.shape, k_cache.shape, v_cache.shape, q.dtype.name,
           tuple(cands), interpret)

    def build(n_splits):
        f = jax.jit(
            lambda q, k, v, cl: flashd_decode_pallas(
                q, k, v, cl, n_splits=n_splits, interpret=interpret
            )
        )
        return lambda: f(q, k_cache, v_cache, cache_len)

    n = measure_best(key, cands, build)
    return DecodeSplit(n_splits=n, split=-(-s_max // n))
