"""Pallas TPU kernel: FlashAttention2 forward — the paper's comparison
baseline (Alg. 2 / Fig. 1), tiled for the MXU.

Identical grid / BlockSpec structure to `flashd_fwd.py` so the two kernels
differ ONLY in the datapath, mirroring the paper's controlled comparison:

  FA2 carry:      m (B_q) + ℓ (B_q) + acc (B_q·dv)   — two row-vectors
  FA2 per tile:   α = e^{m−m'} rescale of acc + ℓ, unnormalized accumulate
  FA2 epilogue:   acc / ℓ division pass at the last kv block

vs. FLASH-D's single Λ row-vector, no rescale chain through a running max,
and no division/epilogue. The op-count benchmark reads both kernels' HLO.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.core.blockwise import MaskSpec, NEG_INF, tile_live
from repro.kernels.flashd_fwd import _mask_bias

__all__ = ["fa2_fwd_pallas"]


def _fa2_kernel(
    q_ref, k_ref, v_ref,
    o_ref, lam_ref,
    acc_ref, m_scratch, l_scratch,
    *,
    mask: MaskSpec,
    scale: float,
    block_q: int,
    block_k: int,
    kv_len: int,
    n_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)

    # shared static tile pruning (one predicate for fwd/bwd/fa2 kernels)
    compute = tile_live(mask, iq, ik, block_q, block_k, kv_len)

    @pl.when(compute)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        keep = _mask_bias(mask, q_pos, k_pos, kv_len)
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_scratch[0]
        m_b = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_b)  # serial cross-tile max chain
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        alpha = jnp.exp(m_prev - m_safe)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        p = jnp.exp(s - m_safe[:, None])
        l_new = l_scratch[0] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv  # rescale + accum
        m_scratch[0] = m_new
        l_scratch[0] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_scratch[0]
        l_safe = jnp.maximum(l, jnp.finfo(jnp.float32).tiny)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)  # epilogue div
        lam_ref[0, 0] = jnp.where(
            l > 0, m_scratch[0] + jnp.log(l_safe), NEG_INF
        )


def fa2_fwd_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: MaskSpec = MaskSpec("causal"),
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: bool = False,
):
    """Returns (o [B, Hq, Sq, dv], Λ [B, Hq, Sq] f32). Same contract as
    `flashd_fwd_pallas` (GQA via index maps, padding handled here)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, dv = v.shape
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    group = hq // hkv
    if block_q is None or block_k is None:
        from repro.kernels.tuning import choose_prefill_blocks  # lazy: no cycle

        tiling = choose_prefill_blocks(sq, skv, d, dv)
        block_q = tiling.block_q if block_q is None else block_q
        block_k = tiling.block_k if block_k is None else block_k
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (sq + pad_q) // block_q
    n_k = (skv + pad_k) // block_k

    kernel = functools.partial(
        _fa2_kernel,
        mask=mask,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        kv_len=skv,
        n_kv_blocks=n_k,
    )
    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, block_q, dv), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, hq, sq + pad_q, dv), q.dtype),
        jax.ShapeDtypeStruct((b, hq, sq + pad_q), jnp.float32),
    ]
    scratch_shapes = []
    compiler_params = None
    if _HAS_PLTPU:
        scratch_shapes = [
            pltpu.VMEM((block_q, dv), jnp.float32),
            pltpu.VMEM((1, block_q), jnp.float32),
            pltpu.VMEM((1, block_q), jnp.float32),
        ]
        try:
            compiler_params = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
            )
        except Exception:
            compiler_params = None

    call = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )
    o, lam = call(q, k, v)
    return o[:, :, :sq], lam[:, :, :sq]
