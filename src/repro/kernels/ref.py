"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These deliberately use the O(S²) full-matrix softmax formulation — maximally
simple, obviously correct — NOT the tiled recurrences (those live in
repro.core.blockwise and are themselves validated against these oracles).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blockwise import MaskSpec, NEG_INF

__all__ = ["attention_ref", "decode_ref"]


def attention_ref(
    q: jax.Array,  # [B, Hq, Sq, d]
    k: jax.Array,  # [B, Hkv, Skv, d]
    v: jax.Array,  # [B, Hkv, Skv, dv]
    *,
    mask: MaskSpec = MaskSpec("causal"),
    scale: Optional[float] = None,
):
    """Full-matrix softmax attention with GQA. Returns (o, Λ)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, dv = v.shape
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    bias = mask.block_bias(jnp.arange(sq), jnp.arange(skv))
    if bias is not None:
        s = s + bias
    lam = jax.nn.logsumexp(s, axis=-1)
    # q rows with no visible key: logsumexp of all-sentinel scores is
    # FINITE (−1e30 + ln skv), so an isfinite check misses them — detect by
    # magnitude and apply the dead-row convention (Λ = NEG_INF, o = 0)
    dead = lam <= NEG_INF / 2
    lam = jnp.where(dead, NEG_INF, lam)
    p = jnp.where(dead[..., None], 0.0, jnp.exp(s - lam[..., None]))
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return (
        o.reshape(b, hq, sq, dv).astype(q.dtype),
        lam.reshape(b, hq, sq),
    )


def decode_ref(
    q: jax.Array,  # [B, Hq, d]
    k_cache: jax.Array,  # [B, Hkv, S, d]
    v_cache: jax.Array,  # [B, Hkv, S, dv]
    cache_len: jax.Array,  # [B]
    *,
    scale: Optional[float] = None,
    window: int = 0,
    chunk: int = 0,
):
    b, hq, d = q.shape
    _, hkv, s_max, dv = v_cache.shape
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhsd->bhgs", qf, k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(s_max)
    cache_len = jnp.asarray(cache_len).reshape(b, 1)
    keep = pos[None, :] < cache_len
    if window > 0:
        keep &= pos[None, :] >= cache_len - window
    if chunk > 0:
        keep &= (pos[None, :] // chunk) == ((cache_len - 1) // chunk)
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    lam = jax.nn.logsumexp(s, axis=-1)
    p = jnp.exp(s - lam[..., None])
    # rows with no visible key (cache_len == 0) are zero, not uniform —
    # matching the kernel's dead-partial convention
    p = jnp.where(keep[:, None, None, :], p, 0.0)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, hq, dv).astype(q.dtype)
