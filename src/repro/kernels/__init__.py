"""Pallas TPU kernels for the FLASH-D attention hot spot.

flashd_fwd    — blockwise FLASH-D prefill/training forward (tile-skip capable)
fa2_fwd       — FlashAttention2 baseline (the paper's comparison point)
flashd_decode — split-K decode with FLASH-D sigmoid merging of partials
ops           — jit'd dispatch (TPU: compiled kernels; CPU: interpret mode)
ref           — pure-jnp oracles
"""
