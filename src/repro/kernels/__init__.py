"""Pallas TPU kernels for the FLASH-D attention hot spot.

flashd_fwd    — blockwise FLASH-D prefill/training forward (tile-skip capable)
fa2_fwd       — FlashAttention2 baseline (the paper's comparison point)
flashd_decode — split-K decode with FLASH-D sigmoid merging of partials
flashd_varlen — packed varlen prefill+decode over the paged cache (§3.5)
ops           — dispatch REGISTRY (TPU: compiled kernels; CPU: interpret
                mode); entry points register under stable op names and are
                re-exported here
ref           — pure-jnp oracles
"""

from repro.kernels.ops import (
    get_op,
    on_tpu,
    op_names,
    pallas_attention_fwd_batched,
    pallas_decode,
    pallas_decode_paged,
    pallas_varlen,
    register_op,
)

__all__ = [
    "get_op",
    "on_tpu",
    "op_names",
    "pallas_attention_fwd_batched",
    "pallas_decode",
    "pallas_decode_paged",
    "pallas_varlen",
    "register_op",
]
