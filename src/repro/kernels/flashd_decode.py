"""Pallas TPU kernel: FLASH-D split-K decode (flash-decoding adapted).

One new token per sequence attends a long KV cache. The cache is split along
the sequence axis; each split yields a partial (o_p, λ_p) pair and partials
are merged with the FLASH-D sigmoid blend

    o ← o_a + (o_b − o_a)·σ(λ_b − λ_a)

— one sigmoid + one vector FMA per merge, where the FA2 merge needs two
exp-rescales and a division (beyond-paper contribution, DESIGN.md §2.2).

Two execution modes:

fused=True (default) — the split axis is the innermost sequential
  ("arbitrary") grid dimension and the merge carry (acc, Λ) lives in VMEM
  scratch, exactly the `flashd_fwd_pallas` carry pattern. The kernel emits
  the final [B, Hq, dv] output directly: zero per-split HBM partials, no
  host-side moveaxis / merge scan. This is the decode hot path.

paged (`flashd_decode_paged_pallas`) — the fused carry structure, but K/V
  live in a global page pool ([P, page, Hkv, d]) addressed through a
  per-sequence block table. The table (and cache_len) enter as
  scalar-prefetch operands: the K/V BlockSpec index maps read
  `tbl[b, ip]` so each sequential grid step DMAs the *physical* page of
  logical page ip — the gather happens in the DMA engine, the kernel body
  and the in-VMEM merge are identical to the fused path. This is what the
  paged serving cache (runtime/kvcache.py, DESIGN.md §3.4) decodes with.

fused=False — the historical multi-output form: every split writes its
  (o_p, λ_p) to HBM and the merge runs on the host graph via
  `merge_partials` (a log-depth pairwise tree of the same blend — the op
  sequence differs from the fused carry's sequential order, but the blend
  is associative, so the two paths agree to a few f32 ulps). Kept as the
  oracle for the fused kernel and as the cross-device merge building block
  for context-parallel caches (repro.distributed.context).

Dynamic cache bounds enter as scalar operands (i32 arrays indexed per batch
row): `cache_len` is the exclusive upper bound and the optional `start` a
per-row inclusive lower bound — context-parallel callers use it to clip a
globally-windowed live region [start, cache_len) to their shard. Sliding-
window / chunked masks for recurrentgemma / llama4 decode are applied
in-kernel, so only live splits do work (`pl.when` on split bounds).
`return_lam=True` additionally emits the merged Λ [B, Hq], which is what a
cross-device merge needs to keep blending.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.core.blockwise import NEG_INF, merge_partials

__all__ = ["flashd_decode_pallas", "flashd_decode_paged_pallas"]


def _split_partial(cache_len, start, q, k, v, *, lo, split, window, chunk, scale):
    """Per-split normalized partial (o_p [G, dv], λ_p [G]) — shared by the
    fused, unfused and paged kernels so their per-split arithmetic is
    identical. q [G, d], k [split, d], v [split, dv] (already f32)."""
    lo_bound = _lo_bound(cache_len, start, window=window, chunk=chunk)
    pos = lo + jax.lax.broadcasted_iota(jnp.int32, (split,), 0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, split]
    keep = jnp.logical_and(pos >= lo_bound, pos < cache_len)
    s = jnp.where(keep[None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    l = jnp.sum(p, axis=-1)
    lam = jnp.where(
        l > 0,
        m_safe + jnp.log(jnp.maximum(l, jnp.finfo(jnp.float32).tiny)),
        NEG_INF,
    )
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    c = jnp.where(l > 0, jnp.exp(m_safe - lam), 0.0)  # ⇒ pv·c = softmax·V
    return pv * c[:, None], lam


def _lo_bound(cache_len, start, *, window: int, chunk: int):
    """Inclusive lower bound of the live region: window/chunk structure ∨
    the caller's explicit per-row `start` (context-parallel shard clip)."""
    lo_bound = jnp.maximum(jnp.int32(0), start)
    if window > 0:
        lo_bound = jnp.maximum(lo_bound, cache_len - window)
    if chunk > 0:
        lo_bound = jnp.maximum(lo_bound, ((cache_len - 1) // chunk) * chunk)
    return lo_bound


def _split_live(cache_len, start, lo, split, *, window: int, chunk: int):
    """A split is live iff it overlaps [lo_bound, cache_len)."""
    lo_bound = _lo_bound(cache_len, start, window=window, chunk=chunk)
    return jnp.logical_and(lo < cache_len, lo + split > lo_bound)


def _merge_into_carry(o_p, lam_p, acc_ref, lam_scratch):
    """FLASH-D sigmoid merge of one partial into the VMEM carry — the same
    blend op as blockwise.merge_pair, applied sequentially along the
    innermost grid axis. Shared by the fused and paged kernels."""
    lam_run = lam_scratch[0]
    w = jax.nn.sigmoid(lam_p - lam_run)
    dead_b = lam_p <= NEG_INF / 2
    dead_a = lam_run <= NEG_INF / 2
    w = jnp.where(dead_b, 0.0, jnp.where(dead_a, 1.0, w))
    acc = acc_ref[...]
    acc_ref[...] = acc + (o_p - acc) * w[:, None]
    ln_w1 = jax.nn.log_sigmoid(lam_run - lam_p)  # ln(1−w)
    lam_scratch[0] = jnp.where(
        dead_b, lam_run, jnp.where(dead_a, lam_p, lam_run - ln_w1)
    )


def _decode_fused_kernel(
    cache_len_ref, start_ref, q_ref, k_ref, v_ref,
    *refs,  # outputs (o [, λ]) then VMEM scratch (acc, Λ carry)
    split: int,
    n_splits: int,
    window: int,
    chunk: int,
    scale: float,
    emit_lam: bool,
):
    if emit_lam:
        o_ref, lam_ref, acc_ref, lam_scratch = refs
    else:
        (o_ref, acc_ref, lam_scratch), lam_ref = refs, None
    ip = pl.program_id(2)  # innermost, sequential
    cache_len = cache_len_ref[0, 0]
    start = start_ref[0, 0]
    lo = ip * split

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        lam_scratch[...] = jnp.full_like(lam_scratch, NEG_INF)

    @pl.when(_split_live(cache_len, start, lo, split, window=window, chunk=chunk))
    def _body():
        o_p, lam_p = _split_partial(
            cache_len, start,
            q_ref[0, 0].astype(jnp.float32),
            k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32),
            lo=lo, split=split, window=window, chunk=chunk, scale=scale,
        )
        _merge_into_carry(o_p, lam_p, acc_ref, lam_scratch)

    @pl.when(ip == n_splits - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
        if emit_lam:
            lam_ref[0, 0] = lam_scratch[0]


def _decode_unfused_kernel(
    cache_len_ref, start_ref, q_ref, k_ref, v_ref,
    o_ref, lam_ref,
    *,
    split: int,
    window: int,
    chunk: int,
    scale: float,
):
    ip = pl.program_id(2)
    cache_len = cache_len_ref[0, 0]
    start = start_ref[0, 0]
    lo = ip * split
    live = _split_live(cache_len, start, lo, split, window=window, chunk=chunk)

    @pl.when(live)
    def _body():
        o_p, lam = _split_partial(
            cache_len, start,
            q_ref[0, 0].astype(jnp.float32),
            k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32),
            lo=lo, split=split, window=window, chunk=chunk, scale=scale,
        )
        o_ref[0, 0, :, 0, :] = o_p.astype(o_ref.dtype)
        lam_ref[0, 0, :, 0] = lam

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[...] = jnp.zeros_like(o_ref)
        lam_ref[...] = jnp.full_like(lam_ref, NEG_INF)


def flashd_decode_pallas(
    q: jax.Array,  # [B, Hq, d] — one token per sequence
    k_cache: jax.Array,  # [B, Hkv, S_max, d]
    v_cache: jax.Array,  # [B, Hkv, S_max, dv]
    cache_len: jax.Array,  # [B] i32
    *,
    scale: Optional[float] = None,
    n_splits: Optional[int] = None,
    window: int = 0,
    chunk: int = 0,
    start: Optional[jax.Array] = None,  # [B] i32 inclusive lower bound
    fused: bool = True,
    return_lam: bool = False,
    interpret: bool = False,
):
    """Returns o [B, Hq, dv] (or (o, Λ [B, Hq] f32) with return_lam=True).
    Split partials merged with the FLASH-D blend.

    n_splits=None picks the split count from the tuning heuristics
    (repro.kernels.tuning). fused=True merges in VMEM (single HBM output);
    fused=False emits per-split HBM partials and merges on the host graph
    (the oracle path). `start` clips the live region to [start, cache_len)
    per batch row — context-parallel callers pass their shard's slice of a
    globally-windowed region; `return_lam` exposes the merged Λ so those
    callers can keep blending partials across devices.
    """
    b, hq, d = q.shape
    _, hkv, s_max, dv = v_cache.shape
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if n_splits is None:
        from repro.kernels.tuning import choose_decode_split  # lazy: no cycle

        n_splits = choose_decode_split(
            s_max, d, dv, group=g, window=window, chunk=chunk
        ).n_splits
    n_splits = max(1, min(n_splits, s_max))
    pad = (-s_max) % n_splits
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    split = (s_max + pad) // n_splits

    qg = q.reshape(b, hkv, g, d)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(b, 1)
    if start is None:
        start = jnp.zeros((b, 1), jnp.int32)
    else:
        start = jnp.asarray(start, jnp.int32).reshape(b, 1)

    in_specs = [
        pl.BlockSpec((1, 1), lambda b_, h, ip: (b_, 0)),
        pl.BlockSpec((1, 1), lambda b_, h, ip: (b_, 0)),
        pl.BlockSpec((1, 1, g, d), lambda b_, h, ip: (b_, h, 0, 0)),
        pl.BlockSpec((1, 1, split, d), lambda b_, h, ip: (b_, h, ip, 0)),
        pl.BlockSpec((1, 1, split, dv), lambda b_, h, ip: (b_, h, ip, 0)),
    ]
    grid = (b, hkv, n_splits)

    if fused and _HAS_PLTPU:
        kernel = functools.partial(
            _decode_fused_kernel, split=split, n_splits=n_splits,
            window=window, chunk=chunk, scale=scale, emit_lam=return_lam,
        )
        try:
            compiler_params = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )
        except Exception:  # older/newer API name drift
            compiler_params = None
        # one output block revisited across splits — written once, at the
        # last split, from the VMEM carry: no per-split HBM partials
        out_specs = [pl.BlockSpec((1, 1, g, dv), lambda b_, h, ip: (b_, h, 0, 0))]
        out_shape = [jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype)]
        if return_lam:
            out_specs.append(pl.BlockSpec((1, 1, g), lambda b_, h, ip: (b_, h, 0)))
            out_shape.append(jax.ShapeDtypeStruct((b, hkv, g), jnp.float32))
        call = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs if return_lam else out_specs[0],
            out_shape=out_shape if return_lam else out_shape[0],
            scratch_shapes=[
                pltpu.VMEM((g, dv), jnp.float32),
                pltpu.VMEM((1, g), jnp.float32),
            ],
            interpret=interpret,
            **({"compiler_params": compiler_params} if compiler_params else {}),
        )
        out = call(cache_len, start, qg, k_cache, v_cache)
        if return_lam:
            o, lam = out
            return o.reshape(b, hq, dv), lam.reshape(b, hq)
        return out.reshape(b, hq, dv)

    kernel = functools.partial(
        _decode_unfused_kernel, split=split, window=window, chunk=chunk, scale=scale
    )
    out_specs = [
        pl.BlockSpec((1, 1, g, 1, dv), lambda b_, h, ip: (b_, h, 0, ip, 0)),
        pl.BlockSpec((1, 1, g, 1), lambda b_, h, ip: (b_, h, 0, ip)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, hkv, g, n_splits, dv), jnp.float32),
        jax.ShapeDtypeStruct((b, hkv, g, n_splits), jnp.float32),
    ]
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    o_p, lam_p = call(cache_len, start, qg, k_cache, v_cache)
    # FLASH-D sigmoid merge over splits (axis moved to front for the tree)
    o_p = jnp.moveaxis(o_p, 3, 0)  # [P, B, Hkv, G, dv]
    lam_p = jnp.moveaxis(lam_p, 3, 0)
    o, lam = merge_partials(o_p, lam_p)
    o = o.reshape(b, hq, dv).astype(q.dtype)
    if return_lam:
        return o, lam.reshape(b, hq)
    return o


# ---------------------------------------------------------------------------
# paged variant: K/V gathered through a block table (scalar prefetch)
# ---------------------------------------------------------------------------

def _decode_paged_kernel(
    tbl_ref, cache_len_ref,  # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref,  # VMEM blocks (k/v: the ip-th *physical* page)
    *refs,  # quantized: (ks, vs) scale blocks; then o, then VMEM carry
    page: int,
    n_tbl: int,
    window: int,
    chunk: int,
    scale: float,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, lam_scratch = refs
    else:
        (o_ref, acc_ref, lam_scratch), ks_ref, vs_ref = refs, None, None
    ib = pl.program_id(0)
    ip = pl.program_id(2)  # logical page index — innermost, sequential
    cache_len = cache_len_ref[ib]
    start = jnp.int32(0)
    lo = ip * page

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        lam_scratch[...] = jnp.full_like(lam_scratch, NEG_INF)

    @pl.when(_split_live(cache_len, start, lo, page, window=window, chunk=chunk))
    def _body():
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [page, d] — gathered page
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:  # dequant in-tile: one per-(page, head) f32 scale each
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        o_p, lam_p = _split_partial(
            cache_len, start, q_ref[0, 0].astype(jnp.float32), k, v,
            lo=lo, split=page, window=window, chunk=chunk, scale=scale,
        )
        _merge_into_carry(o_p, lam_p, acc_ref, lam_scratch)

    @pl.when(ip == n_tbl - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def flashd_decode_paged_pallas(
    q: jax.Array,  # [B, Hq, d] — one token per sequence
    k_pages: jax.Array,  # [P, page, Hkv, d] — global page pool
    v_pages: jax.Array,  # [P, page, Hkv, dv]
    block_tbl: jax.Array,  # [B, N] i32 — physical page id of logical page j
    cache_len: jax.Array,  # [B] i32
    *,
    scale: Optional[float] = None,
    window: int = 0,
    chunk: int = 0,
    k_scale: Optional[jax.Array] = None,  # [P, Hkv] f32 — quantized pool
    v_scale: Optional[jax.Array] = None,  # [P, Hkv] f32
    interpret: bool = False,
):
    """Fused FLASH-D decode over a paged KV cache → o [B, Hq, dv].

    Grid (B, Hkv, N) with the logical-page axis innermost and sequential;
    `block_tbl` and `cache_len` are scalar-prefetch operands, so the K/V
    BlockSpec index maps resolve `tbl[b, ip]` *before* the step's DMA is
    issued — the kernel never sees the indirection, each step's K/V block
    is one physical page, and the (acc, Λ) carry merges pages with the same
    one-sigmoid-one-FMA blend as the contiguous fused kernel. Table slots
    past the live region may hold anything (engine convention: garbage page
    0) — their pages are DMA'd but `pl.when`-skipped, like padded splits.

    With `k_scale`/`v_scale` the pool is quantized (runtime/quant.py,
    DESIGN.md §3.8): the same index maps fetch the page's per-head f32
    scale as a (1, 1) block and the tile is dequantized right after its
    upcast, before the scores — nothing downstream of the multiply changes.

    Without pltpu (non-TPU install), falls back to a jnp gather of the
    table followed by the contiguous fused kernel — same math, the gather
    (and dequant) materialized in HBM instead of hidden in the DMA
    descriptors.
    """
    b, hq, d = q.shape
    p_pool, page, hkv, dv = v_pages.shape
    n_tbl = block_tbl.shape[1]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    quantized = k_scale is not None
    block_tbl = jnp.asarray(block_tbl, jnp.int32)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(b)

    if not _HAS_PLTPU:  # pragma: no cover — jax without pallas TPU support
        kg, vg_ = k_pages[block_tbl], v_pages[block_tbl]  # [B, N, page, Hkv, ·]
        if quantized:
            kg = kg.astype(jnp.float32) * k_scale[block_tbl][:, :, None, :, None]
            vg_ = vg_.astype(jnp.float32) * v_scale[block_tbl][:, :, None, :, None]
        kc = jnp.moveaxis(kg, 3, 1).reshape(b, hkv, n_tbl * page, d)
        vc = jnp.moveaxis(vg_, 3, 1).reshape(b, hkv, n_tbl * page, dv)
        return flashd_decode_pallas(
            q, kc, vc, cache_len, scale=scale, n_splits=n_tbl, window=window,
            chunk=chunk, fused=True, interpret=interpret,
        )

    qg = q.reshape(b, hkv, g, d)
    kernel = functools.partial(
        _decode_paged_kernel, page=page, n_tbl=n_tbl, window=window,
        chunk=chunk, scale=scale, quantized=quantized,
    )
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h, ip, tbl, cl: (b_, h, 0, 0)),
        # the physical page: logical page ip of row b_ through the table
        pl.BlockSpec(
            (1, page, 1, d), lambda b_, h, ip, tbl, cl: (tbl[b_, ip], 0, h, 0)
        ),
        pl.BlockSpec(
            (1, page, 1, dv), lambda b_, h, ip, tbl, cl: (tbl[b_, ip], 0, h, 0)
        ),
    ]
    if quantized:  # per-(page, head) scales ride the same table indirection
        in_specs += [
            pl.BlockSpec((1, 1), lambda b_, h, ip, tbl, cl: (tbl[b_, ip], h)),
            pl.BlockSpec((1, 1), lambda b_, h, ip, tbl, cl: (tbl[b_, ip], h)),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_tbl),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, dv), lambda b_, h, ip, tbl, cl: (b_, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((1, g), jnp.float32),
        ],
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except Exception:  # older/newer API name drift
        compiler_params = None
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )
    args = (block_tbl, cache_len, qg, k_pages, v_pages)
    if quantized:
        args += (jnp.asarray(k_scale, jnp.float32), jnp.asarray(v_scale, jnp.float32))
    o = call(*args)
    return o.reshape(b, hq, dv)
