"""Pallas TPU kernel: FLASH-D split-K decode (flash-decoding adapted).

One new token per sequence attends a long KV cache. The cache is split along
the sequence axis across the innermost grid dimension; each split emits a
partial (o_p, λ_p) pair. Partials are merged with the FLASH-D sigmoid blend

    o ← o_a + (o_b − o_a)·σ(λ_b − λ_a)

— one sigmoid + one vector FMA per merge, where the FA2 merge needs two
exp-rescales and a division (beyond-paper contribution, DESIGN.md §2.2).
The same merge combines cross-device partials under context-parallel
sharding of the cache (see repro.serve).

Dynamic cache length enters as a scalar-prefetch-style operand (an i32 array
indexed per batch row) and masks padded cache slots inside the kernel.
Sliding-window / chunked masks for recurrentgemma / llama4 decode are also
applied in-kernel, so only live splits do work (`pl.when` on split bounds).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.core.blockwise import NEG_INF, merge_partials

__all__ = ["flashd_decode_pallas"]


def _decode_kernel(
    cache_len_ref, q_ref, k_ref, v_ref,
    o_ref, lam_ref,
    *,
    split: int,
    window: int,
    chunk: int,
    scale: float,
):
    ib = pl.program_id(0)
    ip = pl.program_id(2)
    cache_len = cache_len_ref[0, 0]

    # a split is live iff it overlaps [lo_bound, cache_len)
    lo = ip * split
    lo_bound = jnp.int32(0)
    if window > 0:
        lo_bound = jnp.maximum(lo_bound, cache_len - window)
    if chunk > 0:
        lo_bound = jnp.maximum(lo_bound, ((cache_len - 1) // chunk) * chunk)
    live = jnp.logical_and(lo < cache_len, lo + split > lo_bound)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [split, d]
        v = v_ref[0, 0].astype(jnp.float32)  # [split, dv]
        pos = lo + jax.lax.broadcasted_iota(jnp.int32, (split,), 0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, split]
        keep = jnp.logical_and(pos >= lo_bound, pos < cache_len)
        s = jnp.where(keep[None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        m_safe = jnp.maximum(m, NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None])
        l = jnp.sum(p, axis=-1)
        lam = jnp.where(
            l > 0,
            m_safe + jnp.log(jnp.maximum(l, jnp.finfo(jnp.float32).tiny)),
            NEG_INF,
        )
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        c = jnp.where(l > 0, jnp.exp(m_safe - lam), 0.0)  # ⇒ pv·c = softmax·V
        o_ref[0, 0, :, 0, :] = (pv * c[:, None]).astype(o_ref.dtype)
        lam_ref[0, 0, :, 0] = lam

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[...] = jnp.zeros_like(o_ref)
        lam_ref[...] = jnp.full_like(lam_ref, NEG_INF)


def flashd_decode_pallas(
    q: jax.Array,  # [B, Hq, d] — one token per sequence
    k_cache: jax.Array,  # [B, Hkv, S_max, d]
    v_cache: jax.Array,  # [B, Hkv, S_max, dv]
    cache_len: jax.Array,  # [B] i32
    *,
    scale: Optional[float] = None,
    n_splits: int = 8,
    window: int = 0,
    chunk: int = 0,
    interpret: bool = False,
):
    """Returns o [B, Hq, dv]. Split partials merged with the FLASH-D blend."""
    b, hq, d = q.shape
    _, hkv, s_max, dv = v_cache.shape
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    n_splits = max(1, min(n_splits, s_max))
    pad = (-s_max) % n_splits
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    split = (s_max + pad) // n_splits

    qg = q.reshape(b, hkv, g, d)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(b, 1)

    kernel = functools.partial(
        _decode_kernel, split=split, window=window, chunk=chunk, scale=scale
    )
    in_specs = [
        pl.BlockSpec((1, 1), lambda b_, h, ip: (b_, 0)),
        pl.BlockSpec((1, 1, g, d), lambda b_, h, ip: (b_, h, 0, 0)),
        pl.BlockSpec((1, 1, split, d), lambda b_, h, ip: (b_, h, ip, 0)),
        pl.BlockSpec((1, 1, split, dv), lambda b_, h, ip: (b_, h, ip, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, g, 1, dv), lambda b_, h, ip: (b_, h, 0, ip, 0)),
        pl.BlockSpec((1, 1, g, 1), lambda b_, h, ip: (b_, h, 0, ip)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, hkv, g, n_splits, dv), jnp.float32),
        jax.ShapeDtypeStruct((b, hkv, g, n_splits), jnp.float32),
    ]
    call = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_splits),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    o_p, lam_p = call(cache_len, qg, k_cache, v_cache)
    # FLASH-D sigmoid merge over splits (axis moved to front for the scan)
    o_p = jnp.moveaxis(o_p, 3, 0)  # [P, B, Hkv, G, dv]
    lam_p = jnp.moveaxis(lam_p, 3, 0)
    o, _ = merge_partials(o_p, lam_p)
    return o.reshape(b, hq, dv).astype(q.dtype)
