"""Pallas TPU kernel: packed varlen FLASH-D over a paged KV cache.

ONE kernel for the whole serving hot path (DESIGN.md §3.5). Queries from
many sequences arrive as a flat packed batch [T, Hq, d] — prefill chunks,
whole prompts and single decode tokens side by side — and K/V live in the
global page pool of the paged cache (runtime/kvcache.py). FlashAttention's
tiling and the FLASH-D sigmoid carry are both indifferent to *whose* rows a
tile holds, so prefill-vs-decode disappears from the dispatch layer:

  * a prefill chunk is a segment of q_len rows attending [0, kv_len);
  * a decode token is the degenerate q_len == 1 segment of the same grid —
    no separate decode kernel on this path.

Packing contract (the scheduler's packer enforces it):

  * each sequence's rows occupy one contiguous *segment*, and segments are
    aligned to `block_q` rows, so every q tile belongs to exactly ONE
    sequence (flash-attn varlen's per-sequence blocking, expressed in the
    packed layout instead of the launch grid);
  * `seq_ids[t]` is the owning sequence (batch row of `block_tbl`/`kv_len`)
    or −1 for alignment padding; `q_pos[t]` is the row's ABSOLUTE position
    in its sequence's KV space, −1 for padding. Padding rows mask every key
    (q_pos −1 defeats the causal test) and come back as zero rows.

Grid (q_block, kv_head, logical_page) — the page axis innermost and
sequential. Per-block metadata (`blk_seq` = seq_ids[::block_q], which is
exact under the alignment contract) plus `kv_len` and the block table are
scalar-prefetch operands: the K/V BlockSpec index maps resolve
`tbl[blk_seq[ib], ip]` before each step's DMA is issued, so the page
gather lives in the DMA descriptors exactly like the paged decode kernel.
The body is the flashd_fwd tile body: tile-local (m, λ), normalized
partial, and the in-VMEM (acc, Λ) sigmoid carry — merged with
`_merge_into_carry`, unchanged. Masks are per-element (sequence boundary ×
causal × window/chunk), so tile pruning is purely a FLOP optimization.

Without pltpu (non-TPU install) the jnp mirror in
`repro.core.attention.varlen_attention` provides the same math; this
module's fallback just routes there.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific bits are optional so the module imports on CPU hosts
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.core.blockwise import NEG_INF
from repro.kernels.flashd_decode import _merge_into_carry

__all__ = ["flashd_varlen_pallas"]


def _varlen_partial(q, k, q_pos, kv_len, lo, *, page, window, chunk, scale, v):
    """Normalized partial (o_p [R, dv], λ_p [R]) of R packed query rows
    against one gathered page. Per-row masks: key visible iff it is inside
    the row's sequence (< kv_len), causally visible (≤ q_pos), and inside
    the window/chunk structure. Rows with q_pos < 0 (padding) see nothing
    and come back dead (λ = NEG_INF ⇒ identity under the sigmoid merge)."""
    pos = lo + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [R, page]
    keep = jnp.logical_and(pos[None, :] < kv_len, pos[None, :] <= q_pos[:, None])
    if window > 0:
        keep = jnp.logical_and(keep, q_pos[:, None] - pos[None, :] < window)
    if chunk > 0:
        keep = jnp.logical_and(keep, q_pos[:, None] // chunk == pos[None, :] // chunk)
    s = jnp.where(keep, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    l = jnp.sum(p, axis=-1)
    lam = jnp.where(
        l > 0,
        m_safe + jnp.log(jnp.maximum(l, jnp.finfo(jnp.float32).tiny)),
        NEG_INF,
    )
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    c = jnp.where(l > 0, jnp.exp(m_safe - lam), 0.0)
    return pv * c[:, None], lam


def _varlen_kernel(
    blk_seq_ref, kv_len_ref, tbl_ref,  # scalar prefetch (SMEM)
    q_ref, qpos_ref, k_ref, v_ref,  # VMEM (k/v: the gathered physical page)
    *refs,  # quantized: (ks, vs) scale blocks; then o, then VMEM carry
    block_q: int,
    group: int,
    page: int,
    n_tbl: int,
    window: int,
    chunk: int,
    scale: float,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, lam_scratch = refs
    else:
        (o_ref, acc_ref, lam_scratch), ks_ref, vs_ref = refs, None, None
    ib = pl.program_id(0)
    ip = pl.program_id(2)  # logical page — innermost, sequential
    seq_raw = blk_seq_ref[ib]
    seq = jnp.maximum(seq_raw, 0)
    kv_len = jnp.where(seq_raw >= 0, kv_len_ref[seq], 0)
    lo = ip * page

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        lam_scratch[...] = jnp.full_like(lam_scratch, NEG_INF)

    q_pos = qpos_ref[0]  # [block_q]
    q_max = jnp.max(q_pos)
    # conservative tile pruning: per-element masks above are exact, this
    # only skips pages no row of the block can see (future pages under the
    # causal test, pages past the sequence end). Padding rows carry
    # q_pos = −1, which can only shrink q_max — never un-prune a live page.
    live = jnp.logical_and(
        seq_raw >= 0, jnp.logical_and(lo < kv_len, lo <= q_max)
    )
    if window > 0:
        live = jnp.logical_and(live, lo + page > jnp.min(q_pos) - window + 1)

    @pl.when(live)
    def _body():
        q = q_ref[:, 0].astype(jnp.float32).reshape(block_q * group, -1)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:  # dequant in-tile: one per-(page, head) f32 scale each
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        o_p, lam_p = _varlen_partial(
            q, k,
            jnp.repeat(q_pos, group),
            kv_len, lo, page=page, window=window, chunk=chunk, scale=scale,
            v=v,
        )
        _merge_into_carry(o_p, lam_p, acc_ref, lam_scratch)

    @pl.when(ip == n_tbl - 1)
    def _finalize():
        dv = o_ref.shape[-1]
        o_ref[:, 0] = acc_ref[...].reshape(block_q, group, dv).astype(o_ref.dtype)


def flashd_varlen_pallas(
    q: jax.Array,  # [T, Hq, d] — packed, block_q-aligned segments
    k_pages: jax.Array,  # [P, page, Hkv, d] — global page pool
    v_pages: jax.Array,  # [P, page, Hkv, dv]
    block_tbl: jax.Array,  # [B, N] i32
    seq_ids: jax.Array,  # [T] i32 (−1 = padding row)
    q_pos: jax.Array,  # [T] i32 absolute position in KV space (−1 = padding)
    kv_len: jax.Array,  # [B] i32 per-sequence visible KV length
    *,
    scale: Optional[float] = None,
    window: int = 0,
    chunk: int = 0,
    block_q: int,
    k_scale: Optional[jax.Array] = None,  # [P, Hkv] f32 — quantized pool
    v_scale: Optional[jax.Array] = None,  # [P, Hkv] f32
    interpret: bool = False,
) -> jax.Array:
    """Packed varlen FLASH-D forward over a paged cache → o [T, Hq, dv].

    T must be a multiple of `block_q` and each block must belong to one
    sequence (the packing contract above) — callers go through
    `repro.core.attention.varlen_attention`, which pads and documents it.

    With `k_scale`/`v_scale` the page pool is quantized (runtime/quant.py,
    DESIGN.md §3.8): each per-(page, head) f32 scale rides the same
    block-table indirection as its page and the tile is dequantized right
    after its upcast, before the scores — the merge is untouched.
    """
    t, hq, d = q.shape
    _, page, hkv, dv = v_pages.shape
    n_tbl = block_tbl.shape[1]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if t % block_q:
        raise ValueError(f"packed length {t} not a multiple of block_q={block_q}")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    quantized = k_scale is not None
    nb = t // block_q

    seq_ids = jnp.asarray(seq_ids, jnp.int32)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(-1)
    blk_seq = seq_ids[::block_q]  # exact under the alignment contract

    if not _HAS_PLTPU:  # pragma: no cover — jax without pallas TPU support
        from repro.core.attention import varlen_attention

        return varlen_attention(
            q, k_pages, v_pages, block_tbl, seq_ids, q_pos, kv_len,
            scale=scale, window=window, chunk=chunk, impl="flashd",
            k_scale=k_scale, v_scale=v_scale,
        )

    qg = q.reshape(t, hkv, g, d)
    qpos2 = q_pos.reshape(nb, block_q)

    kernel = functools.partial(
        _varlen_kernel, block_q=block_q, group=g, page=page, n_tbl=n_tbl,
        window=window, chunk=chunk, scale=scale, quantized=quantized,
    )
    in_specs = [
        pl.BlockSpec(
            (block_q, 1, g, d),
            lambda ib, h, ip, bs, kl, tbl: (ib, h, 0, 0),
        ),
        pl.BlockSpec((1, block_q), lambda ib, h, ip, bs, kl, tbl: (ib, 0)),
        # the physical page: logical page ip of the block's sequence,
        # resolved through the table in the DMA descriptor
        pl.BlockSpec(
            (1, page, 1, d),
            lambda ib, h, ip, bs, kl, tbl: (
                tbl[jnp.maximum(bs[ib], 0), ip], 0, h, 0
            ),
        ),
        pl.BlockSpec(
            (1, page, 1, dv),
            lambda ib, h, ip, bs, kl, tbl: (
                tbl[jnp.maximum(bs[ib], 0), ip], 0, h, 0
            ),
        ),
    ]
    if quantized:  # per-(page, head) scales ride the same table indirection
        in_specs += [
            pl.BlockSpec(
                (1, 1),
                lambda ib, h, ip, bs, kl, tbl: (
                    tbl[jnp.maximum(bs[ib], 0), ip], h
                ),
            ),
            pl.BlockSpec(
                (1, 1),
                lambda ib, h, ip, bs, kl, tbl: (
                    tbl[jnp.maximum(bs[ib], 0), ip], h
                ),
            ),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nb, hkv, n_tbl),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (block_q, 1, g, dv), lambda ib, h, ip, bs, kl, tbl: (ib, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, dv), jnp.float32),
            pltpu.VMEM((1, block_q * g), jnp.float32),
        ],
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except Exception:  # older/newer API name drift
        compiler_params = None
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, hkv, g, dv), q.dtype),
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )
    args = (
        blk_seq, kv_len, jnp.asarray(block_tbl, jnp.int32),
        qg, qpos2, k_pages, v_pages,
    )
    if quantized:
        args += (
            jnp.asarray(k_scale, jnp.float32),
            jnp.asarray(v_scale, jnp.float32),
        )
    o = call(*args)
    return o.reshape(t, hq, dv)
