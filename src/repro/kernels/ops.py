"""jit'd dispatch registry over the Pallas kernels.

On TPU backends the real kernels run; everywhere else they execute in
Pallas interpret mode (kernel body evaluated op-by-op on CPU) so every code
path is exercised in CI. The models never import kernels directly — they go
through `repro.core.attention`, which lands here for the `*_pallas` impls.

The entry points form a REGISTRY: each is registered under a stable op
name (`attention_fwd`, `decode`, `decode_paged`, `varlen`) so new kernel
families plug in with `@register_op` instead of another hand-threaded
import chain, and callers that route dynamically (benchmarks, tuning
sweeps) resolve them with `get_op(name)`. The module-level functions stay
importable by name — the registry is the same objects, indexed.

Every op also carries a registered *jnp fallback* — a pure-jnp callable
with the SAME signature, resolved with `get_fallback(name)`. The serving
engine's graceful-degradation path (DESIGN.md §3.7) uses `fallback_impl`
to flip a faulting `*_pallas` attention impl to its jnp twin for the rest
of a serve; dynamic callers can swap a single op the same way.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.blockwise import MaskSpec
from repro.kernels.fa2_fwd import fa2_fwd_pallas
from repro.kernels.flashd_decode import (
    flashd_decode_paged_pallas,
    flashd_decode_pallas,
)
from repro.kernels.flashd_fwd import flashd_fwd_pallas
from repro.kernels.flashd_varlen import flashd_varlen_pallas

__all__ = [
    "pallas_attention_fwd_batched",
    "pallas_attention_bwd_batched",
    "pallas_decode",
    "pallas_decode_paged",
    "pallas_varlen",
    "register_op",
    "get_op",
    "op_names",
    "register_fallback",
    "get_fallback",
    "fallback_impl",
    "on_tpu",
]

_REGISTRY: Dict[str, Callable] = {}
_FALLBACKS: Dict[str, Callable] = {}


def register_op(name: str) -> Callable[[Callable], Callable]:
    """Register a kernel dispatch entry point under `name` (decorator)."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"op {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def get_op(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel op {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def op_names() -> tuple:
    return tuple(sorted(_REGISTRY))


def register_fallback(name: str) -> Callable[[Callable], Callable]:
    """Register the pure-jnp fallback for op `name` (same signature)."""

    def deco(fn: Callable) -> Callable:
        if name in _FALLBACKS:
            raise ValueError(f"fallback for {name!r} already registered")
        _FALLBACKS[name] = fn
        return fn

    return deco


def get_fallback(name: str) -> Callable:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown kernel op {name!r}; registered: {sorted(_REGISTRY)}"
        )
    try:
        return _FALLBACKS[name]
    except KeyError:
        raise KeyError(f"op {name!r} has no registered jnp fallback") from None


def fallback_impl(attn_impl: str) -> str:
    """The jnp twin of a Pallas attention impl name ('flashd_pallas' →
    'flashd'); non-Pallas impls map to themselves (nothing to downgrade)."""
    suffix = "_pallas"
    return attn_impl[: -len(suffix)] if attn_impl.endswith(suffix) else attn_impl


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _interpret() -> bool:
    return not on_tpu()


@register_op("attention_fwd")
def pallas_attention_fwd_batched(
    q: jax.Array,  # [B, Sq, Hq, d]   (model layout)
    k: jax.Array,  # [B, Skv, Hkv, d]
    v: jax.Array,  # [B, Skv, Hkv, dv]
    *,
    mask: MaskSpec,
    scale: float,
    impl: str,
    block_q: int,
    block_k: int,
    skip: bool,
):
    """Returns (o [B,Sq,Hq,dv], Λ [B,Hq,Sq]) — kernel layout handled here."""
    qt = q.transpose(0, 2, 1, 3)  # [B, Hq, Sq, d]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "flashd":
        o, lam = flashd_fwd_pallas(
            qt, kt, vt, mask=mask, scale=scale, block_q=block_q,
            block_k=block_k, skip=skip, interpret=_interpret(),
        )
    elif impl == "fa2":
        o, lam = fa2_fwd_pallas(
            qt, kt, vt, mask=mask, scale=scale, block_q=block_q,
            block_k=block_k, interpret=_interpret(),
        )
    else:
        raise ValueError(f"unknown pallas impl {impl!r}")
    return o.transpose(0, 2, 1, 3), lam


@register_op("attention_bwd")
def pallas_attention_bwd_batched(
    q: jax.Array,  # [B, Sq, Hq, d]   (model layout)
    k: jax.Array,  # [B, Skv, Hkv, d]
    v: jax.Array,  # [B, Skv, Hkv, dv]
    o: jax.Array,  # [B, Sq, Hq, dv]  — saved forward output
    lam: jax.Array,  # [B, Hq, Sq] f32  — saved Λ (log-normalizer)
    do: jax.Array,  # [B, Sq, Hq, dv]
    *,
    mask: MaskSpec,
    scale: float,
    impl: str,
    block_q: int,
    block_k: int,
):
    """Fused attention backward from saved (O, Λ) — the training twin of
    `attention_fwd` (DESIGN.md §6). Recomputes score tiles inside the
    kernel (activation checkpointing: nothing [Sq, Skv]-sized is ever
    materialized in HBM) and reconstructs P = exp(s − Λ), which with
    FLASH-D's Λ is overflow-free with no max subtraction — the same
    max-free property as the forward. Both `flashd` and `fa2` forwards
    save the same Λ, so one backward kernel serves both impls.
    Returns (dq, dk, dv) in model layout."""
    del impl  # one bwd kernel serves every fwd impl that saves Λ
    from repro.kernels.flashd_bwd import flashd_bwd_pallas  # lazy: keep import cheap

    dq, dk, dv = flashd_bwd_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), o.transpose(0, 2, 1, 3),
        lam, do.transpose(0, 2, 1, 3),
        mask=mask, scale=scale, block_q=block_q, block_k=block_k,
        interpret=_interpret(),
    )
    return (
        dq.transpose(0, 2, 1, 3),
        dk.transpose(0, 2, 1, 3),
        dv.transpose(0, 2, 1, 3),
    )


@register_op("decode")
def pallas_decode(
    q: jax.Array,  # [B, 1, Hq, d]
    k_cache: jax.Array,  # [B, S, Hkv, d]
    v_cache: jax.Array,  # [B, S, Hkv, dv]
    cache_len: jax.Array,
    *,
    scale=None,
    n_splits: int | None = None,  # None → tuned (repro.kernels.tuning)
    window: int = 0,
    chunk: int = 0,
    fused: bool = True,
):
    o = flashd_decode_pallas(
        q[:, 0] if q.ndim == 4 else q,  # accept [B,1,Hq,d] or [B,Hq,d]
        k_cache.transpose(0, 2, 1, 3),
        v_cache.transpose(0, 2, 1, 3),
        jnp.asarray(cache_len, jnp.int32).reshape(-1),
        scale=scale,
        n_splits=n_splits,
        window=window,
        chunk=chunk,
        fused=fused,
        interpret=_interpret(),
    )
    return o[:, None]  # [B, 1, Hq, dv]


@register_op("decode_paged")
def pallas_decode_paged(
    q: jax.Array,  # [B, 1, Hq, d] or [B, Hq, d]
    k_pages: jax.Array,  # [P, page, Hkv, d] — model page layout == kernel layout
    v_pages: jax.Array,  # [P, page, Hkv, dv]
    block_tbl: jax.Array,  # [B, N] i32
    cache_len: jax.Array,  # [B]
    *,
    scale=None,
    window: int = 0,
    chunk: int = 0,
    k_scale: jax.Array | None = None,  # [P, Hkv] f32 — quantized pool
    v_scale: jax.Array | None = None,
):
    """Paged fused decode — the block table rides in as a scalar-prefetch
    operand, so K/V pages are gathered by the DMA engine (DESIGN.md §3.4).
    Page arrays are stored page-major ([P, page, Hkv, d]), which is already
    the kernel layout — no transpose on the hot path. When the pool is
    quantized (DESIGN.md §3.8) the per-(page, head) scales ride the same
    indirection and tiles are dequantized in-kernel."""
    o = flashd_decode_paged_pallas(
        q[:, 0] if q.ndim == 4 else q,
        k_pages,
        v_pages,
        jnp.asarray(block_tbl, jnp.int32),
        jnp.asarray(cache_len, jnp.int32).reshape(-1),
        scale=scale,
        window=window,
        chunk=chunk,
        k_scale=k_scale,
        v_scale=v_scale,
        interpret=_interpret(),
    )
    return o[:, None]  # [B, 1, Hq, dv]


@register_op("varlen")
def pallas_varlen(
    q: jax.Array,  # [T, Hq, d] — packed, block_q-aligned segments
    k_pages: jax.Array,  # [P, page, Hkv, d]
    v_pages: jax.Array,  # [P, page, Hkv, dv]
    block_tbl: jax.Array,  # [B, N] i32
    seq_ids: jax.Array,  # [T] i32 (−1 padding)
    q_pos: jax.Array,  # [T] i32 (−1 padding)
    kv_len: jax.Array,  # [B] i32
    *,
    scale=None,
    window: int = 0,
    chunk: int = 0,
    block_q: int,
    k_scale: jax.Array | None = None,  # [P, Hkv] f32 — quantized pool
    v_scale: jax.Array | None = None,
):
    """Unified packed varlen step (DESIGN.md §3.5): prefill chunks and
    decode rows in ONE kernel dispatch, K/V gathered through the block
    table in the DMA descriptors. Subsumes `attention_fwd` + `decode` +
    `decode_paged` on the serving path — decode is the q_len == 1 case."""
    return flashd_varlen_pallas(
        q, k_pages, v_pages,
        jnp.asarray(block_tbl, jnp.int32),
        jnp.asarray(seq_ids, jnp.int32),
        jnp.asarray(q_pos, jnp.int32),
        jnp.asarray(kv_len, jnp.int32).reshape(-1),
        scale=scale, window=window, chunk=chunk, block_q=block_q,
        k_scale=k_scale, v_scale=v_scale,
        interpret=_interpret(),
    )


# ---------------------------------------------------------------------------
# jnp fallbacks — same signatures, pure-jnp bodies (graceful degradation)
# ---------------------------------------------------------------------------

@register_fallback("attention_fwd")
def jnp_attention_fwd_batched(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: MaskSpec,
    scale: float,
    impl: str,
    block_q: int,
    block_k: int,
    skip: bool,
):
    from repro.core.attention import _attention_core_fwd  # lazy: avoid cycle

    b, sq, hq, _ = q.shape
    o, (_, _, _, _, lam) = _attention_core_fwd(
        q, k, v, mask, scale, impl, block_q, block_k, skip
    )
    return o, lam.reshape(b, hq, sq)


@register_fallback("attention_bwd")
def jnp_attention_bwd_batched(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lam: jax.Array,
    do: jax.Array,
    *,
    mask: MaskSpec,
    scale: float,
    impl: str,
    block_q: int,
    block_k: int,
):
    """jnp mirror of the fused backward — `blockwise_backward` vmapped over
    (B, Hkv, G). The differential oracle the Pallas bwd kernel is tested
    against, and the graceful-degradation target for training."""
    import functools as _ft

    from repro.core.blockwise import blockwise_backward  # lazy: avoid cycle

    del impl, block_q
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    dv_ = v.shape[-1]
    qg = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, d)
    og = o.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, dv_)
    dog = do.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, dv_)
    lamg = lam.reshape(b, hkv, g, sq)
    kg = k.transpose(0, 2, 1, 3)  # [B, Hkv, Skv, d]
    vg = v.transpose(0, 2, 1, 3)

    fn = _ft.partial(blockwise_backward, mask=mask, scale=scale, block_k=block_k)
    fn = jax.vmap(fn, in_axes=(0, None, None, 0, 0, 0))  # over G
    fn = jax.vmap(fn)  # over Hkv
    fn = jax.vmap(fn)  # over B
    dq, dk, dv = fn(qg, kg, vg, og, lamg, dog)
    dq = dq.reshape(b, hq, sq, d).transpose(0, 2, 1, 3).astype(q.dtype)
    dk = jnp.sum(dk, axis=2).transpose(0, 2, 1, 3).astype(k.dtype)  # sum over G
    dv = jnp.sum(dv, axis=2).transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


@register_fallback("decode")
def jnp_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    scale=None,
    n_splits: int | None = None,
    window: int = 0,
    chunk: int = 0,
    fused: bool = True,
):
    from repro.core.attention import decode_attention  # lazy: avoid cycle

    return decode_attention(
        q if q.ndim == 4 else q[:, None],
        k_cache, v_cache,
        jnp.asarray(cache_len, jnp.int32).reshape(-1),
        scale=scale, window=window, chunk=chunk, n_splits=n_splits,
    )


@register_fallback("decode_paged")
def jnp_decode_paged(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tbl: jax.Array,
    cache_len: jax.Array,
    *,
    scale=None,
    window: int = 0,
    chunk: int = 0,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
):
    from repro.core.attention import decode_attention_paged  # lazy: avoid cycle

    return decode_attention_paged(
        q if q.ndim == 4 else q[:, None],
        k_pages, v_pages,
        jnp.asarray(block_tbl, jnp.int32),
        jnp.asarray(cache_len, jnp.int32).reshape(-1),
        scale=scale, window=window, chunk=chunk,
        k_scale=k_scale, v_scale=v_scale,
    )


@register_fallback("varlen")
def jnp_varlen(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tbl: jax.Array,
    seq_ids: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    scale=None,
    window: int = 0,
    chunk: int = 0,
    block_q: int,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
):
    from repro.core.attention import varlen_attention  # lazy: avoid cycle

    return varlen_attention(
        q, k_pages, v_pages,
        jnp.asarray(block_tbl, jnp.int32),
        jnp.asarray(seq_ids, jnp.int32),
        jnp.asarray(q_pos, jnp.int32),
        jnp.asarray(kv_len, jnp.int32).reshape(-1),
        scale=scale, window=window, chunk=chunk, impl="flashd",
        block_q=block_q, k_scale=k_scale, v_scale=v_scale,
    )
