"""Blockwise (tiled) FLASH-D and FlashAttention2 — pure jnp, runs anywhere.

This is the TPU-native generalization of the paper's per-element recurrence
(DESIGN.md §2.1). A query tile scans KV tiles carrying only (O, Λ):

    W_b = sigmoid(λ_b − Λ_{b−1})          tile weight (paper's w_i per tile)
    Λ_b = λ_b − ln W_b                    running LSE, division-free
    c_b = exp(m_b − Λ_b)                  ≤ 1 ⇒ overflow-impossible
    O_b = O_{b−1}·(1−W_b) + (P_b V_b)·c_b

vs. FlashAttention2's (m, ℓ, O) carry + final O/ℓ epilogue. Both are exact.

These functions are single-(q-head) kernels on 2-D operands; batching over
(batch, kv_head, q-group) happens in `repro.core.attention` via vmap. The
Pallas TPU kernels in `repro.kernels` implement the same recurrence with
explicit VMEM tiling; this module is their oracle and the CPU execution path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "MaskSpec",
    "blockwise_flashd",
    "blockwise_fa2",
    "blockwise_backward",
    "merge_pair",
    "merge_partials",
    "tile_live",
    "DEFAULT_SKIP_THETA",
]

NEG_INF = -1e30  # finite stand-in for -inf in masked scores (NaN-safe)
DEFAULT_SKIP_THETA = 6.0  # paper §III-C active-region lower edge


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Structural attention mask, evaluated per (q, k) position block.

    kind:
      'full'    — no mask (encoder / cross attention)
      'causal'  — k_pos <= q_pos
      'local'   — causal sliding window: 0 <= q_pos − k_pos < window
      'chunked' — causal within chunks of `chunk` tokens (llama4-style)
    q_offset: absolute position of q row 0 (decode: cache length).
    """

    kind: str = "causal"
    window: int = 0
    chunk: int = 0
    q_offset: int = 0

    def block_bias(self, q_pos: jax.Array, k_pos: jax.Array) -> Optional[jax.Array]:
        """Additive bias [len(q_pos), len(k_pos)] or None when fully visible."""
        if self.kind == "full":
            return None
        qp = (q_pos + self.q_offset)[:, None]
        kp = k_pos[None, :]
        if self.kind == "causal":
            keep = kp <= qp
        elif self.kind == "local":
            keep = (kp <= qp) & (qp - kp < self.window)
        elif self.kind == "chunked":
            keep = (kp <= qp) & (qp // self.chunk == kp // self.chunk)
        else:
            raise ValueError(f"unknown mask kind {self.kind!r}")
        return jnp.where(keep, 0.0, NEG_INF)

    def block_fully_visible(self, q_lo: int, q_hi: int, k_lo: int, k_hi: int) -> bool:
        """Static check: is the [q_lo:q_hi, k_lo:k_hi] tile unmasked?"""
        if self.kind == "full":
            return True
        q_lo, q_hi = q_lo + self.q_offset, q_hi + self.q_offset
        if self.kind == "causal":
            return k_hi - 1 <= q_lo
        if self.kind == "local":
            return (k_hi - 1 <= q_lo) and (q_hi - 1 - k_lo < self.window)
        if self.kind == "chunked":
            return (k_hi - 1 <= q_lo) and (q_lo // self.chunk == (q_hi - 1) // self.chunk == k_lo // self.chunk == (k_hi - 1) // self.chunk)
        raise ValueError(self.kind)

    def block_fully_masked(self, q_lo: int, q_hi: int, k_lo: int, k_hi: int) -> bool:
        """Static check: is the tile entirely masked (skippable at trace time)?"""
        if self.kind == "full":
            return False
        q_lo, q_hi = q_lo + self.q_offset, q_hi + self.q_offset
        if self.kind in ("causal", "local", "chunked"):
            if k_lo > q_hi - 1:  # strictly future
                return True
        if self.kind == "local" and q_lo - (k_hi - 1) >= self.window:
            return True
        if self.kind == "chunked" and q_lo // self.chunk > (k_hi - 1) // self.chunk:
            return True
        return False


def tile_live(mask: MaskSpec, iq, ik, block_q: int, block_k: int, kv_len: int):
    """Traced-index tile liveness: is tile (iq, ik) possibly inside the mask?

    The dynamic analogue of `MaskSpec.block_fully_masked` for block *indices*
    (Pallas `program_id`s or loop counters). Shared by the fwd/bwd Pallas
    kernels and the jnp recurrences so the pruning predicate exists exactly
    once. `kv_len` bounds the key axis for 'full' masks (padded tails)."""
    if mask.kind in ("causal", "local", "chunked"):
        live = (ik * block_k) <= (iq * block_q + block_q - 1 + mask.q_offset)
        if mask.kind == "local":
            live = jnp.logical_and(
                live,
                (iq * block_q + mask.q_offset) - (ik * block_k + block_k - 1)
                < mask.window,
            )
        if mask.kind == "chunked":
            live = jnp.logical_and(
                live,
                (iq * block_q + mask.q_offset) // mask.chunk
                <= (ik * block_k + block_k - 1) // mask.chunk,
            )
        return live
    return ik * block_k < kv_len


def _pad_to_multiple(x: jax.Array, block: int, axis: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % block
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def _tile_stats(s: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row (m_b, l_b, λ_b) of a score tile with NaN-safe full-mask rows."""
    m = jnp.max(s, axis=-1)
    m_safe = jnp.maximum(m, NEG_INF / 2)  # fully-masked row ⇒ exp() = 0 below
    p = jnp.exp(s - m_safe[:, None])
    l = jnp.sum(p, axis=-1)
    lam = m_safe + jnp.log(jnp.maximum(l, jnp.finfo(jnp.float32).tiny))
    lam = jnp.where(l > 0, lam, NEG_INF)
    return m_safe, p, lam


def blockwise_flashd(
    q: jax.Array,  # [Sq, d]
    k: jax.Array,  # [Skv, d]
    v: jax.Array,  # [Skv, dv]
    *,
    mask: MaskSpec = MaskSpec("full"),
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    skip: bool = False,
    skip_theta: float = DEFAULT_SKIP_THETA,
    return_skiprate: bool = False,
):
    """Tiled FLASH-D forward. Returns (O [Sq, dv], Λ [Sq]) in float32.

    `skip=True` applies the tile-level analogue of the paper's [-6, 11]
    criterion: tiles with m_b − Λ_{b−1} < −θ − ln(B_k) contribute < σ(−θ)
    of weight and their update is suppressed (in the Pallas kernel the exp,
    the P·V matmul and the blend are truly predicated off; here the update
    is masked, which is bit-identical in output).
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    sq, d = q.shape
    skv, dv = v.shape[0], v.shape[-1]

    qf = q.astype(jnp.float32)
    q_pad, sq0 = _pad_to_multiple(qf, block_q, 0)
    k_pad, skv0 = _pad_to_multiple(k.astype(jnp.float32), block_k, 0)
    v_pad, _ = _pad_to_multiple(v.astype(jnp.float32), block_k, 0)
    n_qb = q_pad.shape[0] // block_q
    n_kb = k_pad.shape[0] // block_k
    kb = k_pad.reshape(n_kb, block_k, d)
    vb = v_pad.reshape(n_kb, block_k, dv)
    k_positions = jnp.arange(n_kb * block_k).reshape(n_kb, block_k)
    kv_valid = (k_positions < skv0).astype(jnp.float32)  # mask padded keys

    ln_bk = jnp.log(jnp.float32(block_k))

    def one_q_block(qi: jax.Array, q_pos: jax.Array):
        def step(carry, xs):
            o_prev, lam_run, nskip, nlive = carry
            k_b, v_b, k_pos, valid = xs
            s = (qi @ k_b.T) * scale  # MXU matmul in the kernel
            bias = mask.block_bias(q_pos, k_pos)
            if bias is not None:
                s = s + bias
            s = jnp.where(valid[None, :] > 0, s, NEG_INF)
            m_b, p, lam_b = _tile_stats(s)

            # W_b = sigmoid(λ_b − Λ);  ln W_b = log_sigmoid (division hidden)
            delta = lam_b - lam_run
            w = jax.nn.sigmoid(delta)
            ln_w = jax.nn.log_sigmoid(delta)
            lam_new = lam_b - ln_w  # = logaddexp(Λ, λ_b), no division
            # guards for ±inf-like sentinels
            tile_dead = lam_b <= NEG_INF / 2
            first = lam_run <= NEG_INF / 2
            w = jnp.where(tile_dead, 0.0, jnp.where(first, 1.0, w))
            lam_new = jnp.where(tile_dead, lam_run, jnp.where(first, lam_b, lam_new))

            c = jnp.where(tile_dead, 0.0, jnp.exp(m_b - lam_new))  # ≤ 1 always
            pv = p @ v_b
            o_new = o_prev * (1.0 - w)[:, None] + pv * c[:, None]

            if skip:
                skip_tile = m_b - lam_run < -(skip_theta + ln_bk)
                skip_tile = jnp.logical_and(skip_tile, ~first)
                o_new = jnp.where(skip_tile[:, None], o_prev, o_new)
                lam_new = jnp.where(skip_tile, lam_run, lam_new)
                # count only dynamically-skipped live tiles — fully-masked
                # (causal-future) tiles are pruned statically on TPU and
                # would inflate the rate
                counted = jnp.logical_and(skip_tile, ~tile_dead)
                nskip = nskip + jnp.sum(counted.astype(jnp.int32))
                nlive = nlive + jnp.sum((~tile_dead).astype(jnp.int32))
            return (o_new, lam_new, nskip, nlive), None

        init = (
            jnp.zeros((block_q, dv), jnp.float32),
            jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.int32(0),
            jnp.int32(0),
        )
        (o, lam, nskip, nlive), _ = jax.lax.scan(step, init, (kb, vb, k_positions, kv_valid))
        return o, lam, nskip, nlive

    q_blocks = q_pad.reshape(n_qb, block_q, d)
    q_positions = jnp.arange(n_qb * block_q).reshape(n_qb, block_q)
    o, lam, nskip, nlive = jax.vmap(one_q_block)(q_blocks, q_positions)
    o = o.reshape(n_qb * block_q, dv)[:sq0]
    lam = lam.reshape(n_qb * block_q)[:sq0]
    if return_skiprate:
        return o, lam, jnp.sum(nskip) / jnp.maximum(jnp.sum(nlive), 1)
    return o, lam


def blockwise_fa2(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: MaskSpec = MaskSpec("full"),
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
):
    """Tiled FlashAttention2 (the paper's baseline): (m, ℓ, O) carry +
    exp-rescale per tile + final division. Returns (O, Λ) like flashd."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    sq, d = q.shape
    dv = v.shape[-1]
    q_pad, sq0 = _pad_to_multiple(q.astype(jnp.float32), block_q, 0)
    k_pad, skv0 = _pad_to_multiple(k.astype(jnp.float32), block_k, 0)
    v_pad, _ = _pad_to_multiple(v.astype(jnp.float32), block_k, 0)
    n_qb = q_pad.shape[0] // block_q
    n_kb = k_pad.shape[0] // block_k
    kb = k_pad.reshape(n_kb, block_k, d)
    vb = v_pad.reshape(n_kb, block_k, dv)
    k_positions = jnp.arange(n_kb * block_k).reshape(n_kb, block_k)
    kv_valid = (k_positions < skv0).astype(jnp.float32)

    def one_q_block(qi, q_pos):
        def step(carry, xs):
            m_prev, l_prev, o_prev = carry
            k_b, v_b, k_pos, valid = xs
            s = (qi @ k_b.T) * scale
            bias = mask.block_bias(q_pos, k_pos)
            if bias is not None:
                s = s + bias
            s = jnp.where(valid[None, :] > 0, s, NEG_INF)
            m_b = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_b)
            m_safe = jnp.maximum(m_new, NEG_INF / 2)
            alpha = jnp.exp(m_prev - m_safe)
            alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
            p = jnp.exp(s - m_safe[:, None])
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            o_new = o_prev * alpha[:, None] + p @ v_b
            return (m_new, l_new, o_new), None

        init = (
            jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, dv), jnp.float32),
        )
        (m, l, o), _ = jax.lax.scan(step, init, (kb, vb, k_positions, kv_valid))
        l_safe = jnp.maximum(l, jnp.finfo(jnp.float32).tiny)
        o = o / l_safe[:, None]  # the FA2 epilogue FLASH-D eliminates
        lam = jnp.where(l > 0, m + jnp.log(l_safe), NEG_INF)
        return o, lam

    q_blocks = q_pad.reshape(n_qb, block_q, d)
    q_positions = jnp.arange(n_qb * block_q).reshape(n_qb, block_q)
    o, lam = jax.vmap(one_q_block)(q_blocks, q_positions)
    return o.reshape(-1, dv)[:sq0], lam.reshape(-1)[:sq0]


def blockwise_backward(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lam: jax.Array,
    do: jax.Array,
    *,
    mask: MaskSpec = MaskSpec("full"),
    scale: Optional[float] = None,
    block_k: int = 128,
):
    """Memory-efficient attention backward from saved (O, Λ).

    Probabilities are reconstructed as P = exp(s − Λ) — with FLASH-D's Λ the
    argument is always ≤ 0, so the backward is overflow-free with no
    max-subtraction, the same property as the forward (DESIGN.md §2.1).
    Scans KV tiles carrying dQ and emitting (dK_b, dV_b).
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    sq, d = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    of, dof = o.astype(jnp.float32), do.astype(jnp.float32)
    k_pad, skv0 = _pad_to_multiple(kf, block_k, 0)
    v_pad, _ = _pad_to_multiple(vf, block_k, 0)
    n_kb = k_pad.shape[0] // block_k
    kb = k_pad.reshape(n_kb, block_k, d)
    vb = v_pad.reshape(n_kb, block_k, dv)
    k_positions = jnp.arange(n_kb * block_k).reshape(n_kb, block_k)
    kv_valid = (k_positions < skv0).astype(jnp.float32)
    q_pos = jnp.arange(sq)

    dsum = jnp.sum(dof * of, axis=-1)  # D = rowsum(dO ∘ O)

    def step(dq_acc, xs):
        k_b, v_b, k_pos, valid = xs
        s = (qf @ k_b.T) * scale
        bias = mask.block_bias(q_pos, k_pos)
        if bias is not None:
            s = s + bias
        s = jnp.where(valid[None, :] > 0, s, NEG_INF)
        p = jnp.exp(s - lam[:, None])  # exact probs; argument ≤ 0
        p = jnp.where(lam[:, None] <= NEG_INF / 2, 0.0, p)
        dv_b = p.T @ dof
        dp = dof @ v_b.T
        ds = p * (dp - dsum[:, None])
        dq_acc = dq_acc + ds @ k_b * scale
        dk_b = ds.T @ qf * scale
        return dq_acc, (dk_b, dv_b)

    dq, (dk, dv_out) = jax.lax.scan(
        step, jnp.zeros((sq, d), jnp.float32), (kb, vb, k_positions, kv_valid)
    )
    dk = dk.reshape(-1, d)[:skv0]
    dv_out = dv_out.reshape(-1, dv)[:skv0]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv_out.astype(v.dtype)


def merge_pair(a, b):
    """One FLASH-D blend of two attention partials: (o_a, Λ_a) ⊕ (o_b, Λ_b).

    o = o_a + (o_b − o_a)·σ(Λ_b − Λ_a) — one sigmoid + one FMA, vs. FA2's
    two exp-rescales + division. The operator is associative AND commutative
    in (O, Λ) (it is the Λ-weighted mean with Λ = logaddexp), so partials may
    be reduced in any order: sequential carries (the fused decode kernel),
    log-depth trees (`merge_partials`), or cross-device butterflies
    (`repro.distributed.context`). Dead partials (Λ ≤ NEG_INF/2) are
    identity elements."""
    o_a, lam_a = a
    o_b, lam_b = b
    w = jax.nn.sigmoid(lam_b - lam_a)
    dead_b = lam_b <= NEG_INF / 2
    dead_a = lam_a <= NEG_INF / 2
    w = jnp.where(dead_b, 0.0, jnp.where(dead_a, 1.0, w))
    o = o_a + (o_b - o_a) * w[..., None]
    ln_w1 = jax.nn.log_sigmoid(lam_a - lam_b)  # ln(1−w)
    lam = jnp.where(
        dead_b, lam_a, jnp.where(dead_a, lam_b, lam_a - ln_w1)
    )
    return o, lam


def merge_partials(o_parts: jax.Array, lam_parts: jax.Array):
    """FLASH-D merge of split-K partial attention results (beyond-paper).

    o_parts [P, ..., dv], lam_parts [P, ...] → merged (o, Λ). Reduced as a
    log-depth pairwise tree (⌈log₂ P⌉ vectorized `merge_pair` levels) rather
    than a sequential scan — the blend is associative, so the tree is exact
    in real arithmetic and O(log P) on the critical path, which is what the
    unfused decode path and cross-device context-parallel merges want.
    """
    o, lam = o_parts, lam_parts
    while o.shape[0] > 1:
        n = o.shape[0]
        half = n // 2
        pair = merge_pair(
            (o[0 : 2 * half : 2], lam[0 : 2 * half : 2]),
            (o[1 : 2 * half : 2], lam[1 : 2 * half : 2]),
        )
        if n % 2:  # odd leftover rides up to the next level
            o = jnp.concatenate([pair[0], o[-1:]], axis=0)
            lam = jnp.concatenate([pair[1], lam[-1:]], axis=0)
        else:
            o, lam = pair
    return o[0], lam[0]
