"""Skip-rate instrumentation (paper §V-B, Table I).

Element level: fraction of FLASH-D steps whose sigmoid argument falls outside
the active region [-6, 11] — below ⇒ output update skipped entirely (no v_i
load, no FMA); above ⇒ output replaced by v_i (FMA skipped). The paper
measures 0.5–2.8 % on real LLM inference; `benchmarks/table1_skiprate.py`
reproduces the measurement on a model trained by this repo.

Tile level (beyond-paper, DESIGN.md §2.1): fraction of KV tiles whose whole
update (exp + P·V matmul + blend) is predicated off by
m_b − Λ < −θ − ln(B_k). This is the rate that matters on TPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.blockwise import MaskSpec, blockwise_flashd
from repro.core.flashd import flashd_alg3_skipstats

__all__ = ["SkipStats", "element_skip_stats", "tile_skip_rate"]


class SkipStats(NamedTuple):
    skip_low: jax.Array  # updates skipped (w≈0) — paper's Table I number
    skip_high: jax.Array  # outputs replaced (w≈1)
    total: jax.Array

    @property
    def rate_low(self):
        return self.skip_low / self.total

    @property
    def rate_high(self):
        return self.skip_high / self.total


def element_skip_stats(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> SkipStats:
    """Element-level Table-I statistics over a [B, S, H, d] attention batch.

    Runs the sequential paper-faithful Alg. 3 per (batch, head, query) row;
    causal queries process exactly their key prefix [0..i] — the realized
    steps an incremental decoder executes. Totals count steps after the
    first (w_1 = 1 is structural, not a skip opportunity).
    """
    b, s, h, d = q.shape
    scale = 1.0 / (d ** 0.5)

    def per_head(qh, kh, vh):
        prefix = (jnp.arange(s) + 1) if causal else jnp.full((s,), s)
        o, lo, hi = jax.vmap(
            lambda qi, n: flashd_alg3_skipstats(qi * scale, kh, vh, n_valid=n)
        )(qh, prefix)
        return jnp.sum(lo), jnp.sum(hi)

    fn = jax.vmap(jax.vmap(per_head, in_axes=(1, 1, 1)), in_axes=(0, 0, 0))
    lo, hi = fn(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    steps_per_head = (s * (s - 1)) // 2 if causal else s * (s - 1)
    total = jnp.int32(b * h * steps_per_head)
    return SkipStats(jnp.sum(lo), jnp.sum(hi), total)


def tile_skip_rate(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: MaskSpec = MaskSpec("causal"),
    block_q: int = 128,
    block_k: int = 128,
    theta: float = 6.0,
) -> jax.Array:
    """Tile-level skip rate of the blockwise FLASH-D kernel on [B,S,H,d]."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, s, d)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)

    def one(qi, ki, vi):
        _, _, rate = blockwise_flashd(
            qi, ki, vi, mask=mask, block_q=block_q, block_k=block_k,
            skip=True, skip_theta=theta, return_skiprate=True,
        )
        return rate

    fn = jax.vmap(jax.vmap(jax.vmap(one, in_axes=(0, None, None))))
    rates = fn(qg, kg, vg)
    return jnp.mean(rates)
