"""FLASH-D core: the paper's contribution as composable JAX ops."""

from repro.core.attention import (
    MaskSpec,
    decode_attention,
    flash_attention,
    varlen_attention,
)
from repro.core.blockwise import (
    blockwise_fa2,
    blockwise_flashd,
    merge_partials,
)
from repro.core.flashd import (
    flash_attention_alg1,
    flash_attention2_alg2,
    flashd_alg3,
    naive_attention,
)

__all__ = [
    "MaskSpec",
    "flash_attention",
    "decode_attention",
    "varlen_attention",
    "blockwise_flashd",
    "blockwise_fa2",
    "merge_partials",
    "flashd_alg3",
    "flash_attention_alg1",
    "flash_attention2_alg2",
    "naive_attention",
]
