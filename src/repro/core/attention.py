"""Public attention ops: batched, GQA-aware, differentiable FLASH-D.

`flash_attention`  — training / prefill: [B, S, H, d] tensors, tiled scan.
`decode_attention` — single-token decode against a KV cache with dynamic
                     length; optional split-K with FLASH-D sigmoid merging.

impl ∈ {'flashd', 'fa2', 'naive', 'xla', 'flashd_pallas', 'fa2_pallas'}:
  flashd / fa2  — pure-jnp tiled recurrences (run on any backend; these are
                  what the CPU-hosted dry-run lowers).
  *_pallas      — Pallas TPU kernels from repro.kernels (interpret mode on
                  CPU; real kernels on TPU).
  naive         — O(S²) softmax oracle (custom_vjp with the tiled backward,
                  like every impl above).
  xla           — O(S²) softmax DIFFERENTIATED BY XLA: no custom_vjp, the
                  [S, S] probability matrix is saved for the backward. The
                  seed-era training baseline BENCH_train.json compares the
                  fused fwd+bwd pair against (DESIGN.md §6).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blockwise import (
    MaskSpec,
    NEG_INF,
    blockwise_fa2,
    blockwise_flashd,
    merge_partials,
)

__all__ = [
    "flash_attention",
    "decode_attention",
    "decode_attention_paged",
    "gather_pages",
    "varlen_attention",
    "MaskSpec",
]


def _single_head_fwd(q, k, v, mask, scale, impl, block_q, block_k, skip):
    if impl == "flashd":
        return blockwise_flashd(
            q, k, v, mask=mask, scale=scale, block_q=block_q, block_k=block_k, skip=skip
        )
    if impl == "fa2":
        return blockwise_fa2(q, k, v, mask=mask, scale=scale, block_q=block_q, block_k=block_k)
    if impl == "naive":
        s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
        bias = mask.block_bias(jnp.arange(q.shape[0]), jnp.arange(k.shape[0]))
        if bias is not None:
            s = s + bias
        lam = jax.nn.logsumexp(s, axis=-1)
        dead = lam <= NEG_INF / 2  # no visible key → zero row, Λ sentinel
        lam = jnp.where(dead, NEG_INF, lam)
        p = jnp.where(dead[:, None], 0.0, jnp.exp(s - lam[:, None]))
        return p @ v.astype(jnp.float32), lam
    raise ValueError(f"unknown attention impl {impl!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _attention_core(q, k, v, mask, scale, impl, block_q, block_k, skip):
    o, _ = _attention_core_fwd(q, k, v, mask, scale, impl, block_q, block_k, skip)
    return o


def _attention_core_fwd(q, k, v, mask, scale, impl, block_q, block_k, skip):
    """q [B,Sq,Hq,d], k/v [B,Skv,Hkv,d|dv] → o [B,Sq,Hq,dv]; saves Λ."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if impl.endswith("_pallas"):
        from repro.kernels import ops as kernel_ops  # lazy: avoid import cycle

        o, lam = kernel_ops.pallas_attention_fwd_batched(
            q, k, v, mask=mask, scale=scale, impl=impl.replace("_pallas", ""),
            block_q=block_q, block_k=block_k, skip=skip,
        )
        return o, (q, k, v, o, lam.reshape(b, hkv, g, sq))
    # group queries on their shared KV head: [B, Hkv, G, Sq, d]
    qg = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, d)
    kg = k.transpose(0, 2, 1, 3)  # [B, Hkv, Skv, d]
    vg = v.transpose(0, 2, 1, 3)

    fn = functools.partial(
        _single_head_fwd, mask=mask, scale=scale, impl=impl,
        block_q=block_q, block_k=block_k, skip=skip,
    )
    fn = jax.vmap(fn, in_axes=(0, None, None))  # over G
    fn = jax.vmap(fn, in_axes=(0, 0, 0))  # over Hkv
    fn = jax.vmap(fn, in_axes=(0, 0, 0))  # over B
    o, lam = fn(qg, kg, vg)  # o [B,Hkv,G,Sq,dv], lam [B,Hkv,G,Sq]
    dv_ = o.shape[-1]
    o = o.reshape(b, hq, sq, dv_).transpose(0, 2, 1, 3).astype(q.dtype)
    return o, (q, k, v, o, lam)


def _attention_core_bwd(mask, scale, impl, block_q, block_k, skip, res, do):
    """Backward from saved (q, k, v, O, Λ) through the `attention_bwd`
    registry op (kernels/ops.py): `*_pallas` impls run the fused Pallas
    kernel, everything else its jnp fallback twin — which keeps the jnp
    mirror the differential oracle for the training path (DESIGN.md §6).
    Both recompute score tiles from (q, k, Λ); no [Sq, Skv] intermediate
    is ever saved by the forward."""
    q, k, v, o, lam = res
    b, sq, hq, _ = q.shape
    from repro.kernels import ops as kernel_ops  # lazy: avoid import cycle

    op = (
        kernel_ops.get_op("attention_bwd")
        if impl.endswith("_pallas")
        else kernel_ops.get_fallback("attention_bwd")
    )
    return op(
        q, k, v, o, lam.reshape(b, hq, sq), do,
        mask=mask, scale=scale, impl=impl, block_q=block_q, block_k=block_k,
    )


_attention_core.defvjp(
    lambda q, k, v, mask, scale, impl, bq, bk, skip: _attention_core_fwd(
        q, k, v, mask, scale, impl, bq, bk, skip
    ),
    _attention_core_bwd,
)


def _xla_attention(q, k, v, mask: MaskSpec, scale: float):
    """Plain softmax attention with NO custom_vjp — XLA's autodiff saves
    the [B, H, Sq, Skv] probabilities for the backward. This is the
    seed-era training datapath and the baseline the fused FLASH-D fwd+bwd
    pair is benchmarked against (BENCH_train.json, DESIGN.md §6)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:  # GQA: materialize the repeated KV heads (baseline semantics)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    bias = mask.block_bias(jnp.arange(sq), jnp.arange(skv))
    if bias is not None:
        s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: MaskSpec = MaskSpec("causal"),
    scale: Optional[float] = None,
    impl: str = "flashd",
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
    skip: bool = False,
) -> jax.Array:
    """Multi-head GQA attention. q [B,Sq,Hq,d]; k,v [B,Skv,Hkv,·].

    block_q / block_k = None resolves the tiling from the VMEM-budget
    heuristics in repro.kernels.tuning (shape-static, so jit-stable).

    Context parallelism: when the active ShardingCtx opts into prefill CP
    (`cp_prefill=True`) and the kv_cache rule seq-shards these operands,
    the call routes to the ring schedule in repro.distributed.context —
    per-shard kernels + cross-device FLASH-D Λ-merge, no score gather.
    That path is forward-only (serving/prefill)."""
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError("expected [batch, seq, heads, dim] operands")
    if q.shape[2] % k.shape[2] != 0:
        raise ValueError(f"Hq={q.shape[2]} not a multiple of Hkv={k.shape[2]}")
    if scale is None:
        scale = float(1.0 / (q.shape[-1] ** 0.5))

    if impl == "xla":  # XLA-autodiff baseline: no custom_vjp, no tiling
        return _xla_attention(q, k, v, mask, scale)

    from repro.distributed.context import maybe_ring_prefill  # lazy: no cycle

    o_cp = maybe_ring_prefill(
        q, k, v, mask=mask, scale=scale, impl=impl,
        block_q=block_q, block_k=block_k, skip=skip,
    )
    if o_cp is not None:
        return o_cp

    if block_q is None or block_k is None:
        from repro.kernels.tuning import choose_prefill_blocks  # lazy: no cycle

        tiling = choose_prefill_blocks(
            q.shape[1], k.shape[1], q.shape[-1], v.shape[-1]
        )
        block_q = tiling.block_q if block_q is None else block_q
        block_k = tiling.block_k if block_k is None else block_k
    block_q = min(block_q, max(q.shape[1], 1))
    block_k = min(block_k, max(k.shape[1], 1))
    return _attention_core(q, k, v, mask, scale, impl, block_q, block_k, skip)


def decode_attention(
    q: jax.Array,  # [B, 1, Hq, d] — one new token per sequence
    k_cache: jax.Array,  # [B, S_max, Hkv, d]
    v_cache: jax.Array,  # [B, S_max, Hkv, dv]
    cache_len: jax.Array,  # [B] or scalar — number of valid cache entries
    *,
    scale: Optional[float] = None,
    window: int = 0,  # >0: sliding-window (local) attention
    chunk: int = 0,  # >0: llama4-style chunked attention
    n_splits: Optional[int] = None,  # split-K partitions; None → tuned
) -> jax.Array:
    """Single-step decode against a (possibly sharded) KV cache.

    Uses the einsum formulation (one query row ⇒ attention is linear in S and
    memory-bound: the roofline term is the KV-cache read). With n_splits > 1
    the cache is partitioned along S, each partition yields (o_p, Λ_p), and
    partials are merged with the FLASH-D sigmoid blend (DESIGN.md §2.2) —
    one FMA per merge instead of FA2's rescale/divide. The same merge
    combines *cross-device* partials under context-parallel sharding.
    n_splits=None asks repro.kernels.tuning for a split count; the cache
    is zero-padded up to a multiple of it (padded slots are masked), the
    same convention as the pallas kernel.

    When the active ShardingCtx seq-shards this cache (context parallel —
    see `sharding.cp_axis_for_cache`), the call routes to
    `repro.distributed.context.cp_decode`: per-shard partials + a log-depth
    cross-device butterfly of the same blend, so the wire carries (O, Λ)
    messages instead of a gathered cache.
    """
    b, _, hq, d = q.shape
    s_max = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))

    from repro.distributed.context import maybe_cp_decode  # lazy: no cycle

    o_cp = maybe_cp_decode(
        q, k_cache, v_cache, cache_len, scale=scale, window=window,
        chunk=chunk, n_splits=n_splits,
        # kernel-free per-shard partials, like the rest of this function
        # (dry-runs, any backend)
        use_kernel=False,
    )
    if o_cp is not None:
        return o_cp
    if n_splits is None:
        from repro.kernels.tuning import choose_decode_split  # lazy: no cycle

        n_splits = choose_decode_split(
            s_max, d, v_cache.shape[-1], group=g, window=window, chunk=chunk
        ).n_splits
    n_splits = max(1, min(n_splits, s_max))
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, (b,))

    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    pos = jnp.arange(s_max)
    valid = pos[None, :] < cache_len[:, None]  # [B, S]
    if window > 0:
        valid &= pos[None, :] >= (cache_len[:, None] - window)
    if chunk > 0:
        cur_chunk = (cache_len[:, None] - 1) // chunk
        valid &= (pos[None, :] // chunk) == cur_chunk

    # scores: [B, Hkv, G, S]
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    if n_splits <= 1:
        lam = jax.nn.logsumexp(s, axis=-1)
        p = jnp.exp(s - lam[..., None])
        # rows with no visible key are ZERO (the kernels' dead-partial
        # convention), not the uniform-softmax artifact exp(NEG_INF−NEG_INF)
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        o = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    else:
        dv = v_cache.shape[-1]
        pad = (-s_max) % n_splits  # padded slots score NEG_INF ⇒ dead
        if pad:
            s = jnp.pad(s, ((0, 0), (0, 0), (0, 0), (0, pad)), constant_values=NEG_INF)
            vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        split = (s_max + pad) // n_splits
        sp = s.reshape(b, hkv, g, n_splits, split).transpose(3, 0, 1, 2, 4)
        vp = vf.reshape(b, n_splits, split, hkv, dv).transpose(1, 0, 2, 3, 4)
        m_p = jnp.max(sp, axis=-1)
        m_safe = jnp.maximum(m_p, NEG_INF / 2)
        p = jnp.exp(sp - m_safe[..., None])
        l_p = jnp.sum(p, axis=-1)
        lam_p = jnp.where(
            l_p > 0, m_safe + jnp.log(jnp.maximum(l_p, jnp.finfo(jnp.float32).tiny)), NEG_INF
        )
        o_p = jnp.einsum("pbhgs,pbshd->pbhgd", p, vp)
        o_p = o_p / jnp.maximum(l_p, jnp.finfo(jnp.float32).tiny)[..., None]
        o, lam = merge_partials(o_p, lam_p)  # FLASH-D split-K merge

    return o.reshape(b, 1, hq, -1).astype(q.dtype)


def varlen_attention(
    q: jax.Array,  # [T, Hq, d] — packed query rows from many sequences
    k_pages: jax.Array,  # [P, page, Hkv, d] — global page pool
    v_pages: jax.Array,  # [P, page, Hkv, dv]
    block_tbl: jax.Array,  # [B, N] i32 per-sequence block tables
    seq_ids: jax.Array,  # [T] i32 owning sequence per row (−1 = padding)
    q_pos: jax.Array,  # [T] i32 absolute KV position per row (−1 = padding)
    kv_len: jax.Array,  # [B] i32 visible KV length per sequence
    *,
    scale: Optional[float] = None,
    window: int = 0,
    chunk: int = 0,
    impl: str = "flashd",
    block_q: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [P, Hkv] f32 — quantized pool
    v_scale: Optional[jax.Array] = None,  # [P, Hkv] f32
) -> jax.Array:
    """Packed varlen attention over a paged KV cache → o [T, Hq, dv].

    THE unified serving entry (DESIGN.md §3.5): prefill chunks, whole
    prompts and single decode tokens ride in one flat batch — a decode
    token is just a 1-row segment. Every row attends its own sequence's
    pages under a causal (× window/chunk) mask at its absolute position;
    padding rows (seq_ids < 0) return zeros.

    `impl` ∈ {*_pallas → the fused Pallas kernel (block-table gather in
    the DMA descriptors, in-VMEM sigmoid carry); anything else → this jnp
    mirror}. The mirror gathers each row's pages to a contiguous view, so
    its working set is O(T · N·page) — fine for serving packs, not meant
    for training-sized T. The Pallas path requires the packing contract
    (block_q-aligned segments, see kernels/flashd_varlen.py); rows are
    padded to a block multiple here, but segment ALIGNMENT is the
    caller's job (the scheduler's packer provides it).

    `k_scale`/`v_scale` ([P, Hkv] f32) mark a quantized page pool
    (DESIGN.md §3.8): the kernel dequantizes tiles in VMEM after the DMA
    gather; this mirror dequantizes during its page gather — identical
    arithmetic, so it stays the differential oracle.
    """
    t, hq, d = q.shape
    _, page, hkv, dv = v_pages.shape
    g = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    seq_ids = jnp.asarray(seq_ids, jnp.int32)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(-1)

    if impl.endswith("_pallas"):
        from repro.kernels import ops as kernel_ops  # lazy: avoid import cycle

        if block_q is None:
            from repro.kernels.tuning import choose_varlen_blocks

            block_q = choose_varlen_blocks(
                t, d, dv, group=g, page=page,
                kv_itemsize=jnp.dtype(k_pages.dtype).itemsize
                if k_scale is not None else 4,
            ).block_q
        pad = (-t) % block_q
        if pad:
            q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
            seq_ids = jnp.pad(seq_ids, (0, pad), constant_values=-1)
            q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
        o = kernel_ops.pallas_varlen(
            q, k_pages, v_pages, block_tbl, seq_ids, q_pos, kv_len,
            scale=scale, window=window, chunk=chunk, block_q=block_q,
            k_scale=k_scale, v_scale=v_scale,
        )
        return o[:t]

    # jnp mirror: gather each row's sequence cache, one einsum per pack.
    sid = jnp.maximum(seq_ids, 0)
    k_cache = gather_pages(k_pages, block_tbl, scales=k_scale)  # [B, S, Hkv, d]
    v_cache = gather_pages(v_pages, block_tbl, scales=v_scale)
    s_tot = k_cache.shape[1]
    kt = k_cache[sid].astype(jnp.float32)  # [T, S, Hkv, d]
    vt = v_cache[sid].astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(t, hkv, g, d)

    pos = jnp.arange(s_tot)
    keep = pos[None, :] < kv_len[sid][:, None]  # sequence boundary
    keep &= pos[None, :] <= q_pos[:, None]  # causal at the row's position
    if window > 0:
        keep &= q_pos[:, None] - pos[None, :] < window
    if chunk > 0:
        keep &= q_pos[:, None] // chunk == pos[None, :] // chunk

    s = jnp.einsum("thgd,tshd->thgs", qf, kt, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    lam = jax.nn.logsumexp(s, axis=-1)
    p = jnp.exp(s - lam[..., None])
    # rows with no visible key (padding, empty segments) are ZERO — the
    # kernels' dead-partial convention, not the uniform-softmax artifact
    p = jnp.where(keep[:, None, None, :], p, 0.0)
    o = jnp.einsum("thgs,tshd->thgd", p, vt)
    return o.reshape(t, hq, dv).astype(q.dtype)


def gather_pages(
    pages: jax.Array,
    block_tbl: jax.Array,
    scales: Optional[jax.Array] = None,
) -> jax.Array:
    """[P, page, Hkv, ·] pool + [B, N] table → contiguous [B, N·page, Hkv, ·].

    The jnp materialization of the block-table indirection the paged Pallas
    kernel performs in its DMA descriptors — the oracle for that kernel,
    and the bridge that lets every contiguous-cache consumer (the split-K
    jnp path, cross-device cp_decode) run against a paged cache.

    With `scales` ([P, Hkv] f32, a quantized pool's per-(page, head)
    side-band) the gathered view is dequantized to f32 — the mirror of the
    kernels' in-tile dequant (DESIGN.md §3.8)."""
    b, n = block_tbl.shape
    _, page, hkv = pages.shape[:3]
    out = pages[block_tbl]  # [B, N, page, Hkv, ·]
    if scales is not None:
        out = out.astype(jnp.float32) * scales[block_tbl][:, :, None, :, None]
    return out.reshape(b, n * page, hkv, pages.shape[-1])


def decode_attention_paged(
    q: jax.Array,  # [B, 1, Hq, d]
    k_pages: jax.Array,  # [P, page, Hkv, d] — global page pool
    v_pages: jax.Array,  # [P, page, Hkv, dv]
    block_tbl: jax.Array,  # [B, N] i32 per-sequence block tables
    cache_len: jax.Array,  # [B]
    *,
    scale: Optional[float] = None,
    window: int = 0,
    chunk: int = 0,
    n_splits: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [P, Hkv] f32 — quantized pool
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-step decode against a paged KV cache (DESIGN.md §3.4).

    This is the backend-agnostic path: gather the sequence's pages into
    contiguous [B, S, Hkv, ·] form and run `decode_attention`, which keeps
    all of its routing — context-parallel `cp_decode` when the active
    ShardingCtx seq-shards the (gathered) cache, tuned split-K with the
    FLASH-D sigmoid merge otherwise. The Pallas hot path
    (`kernels.ops.pallas_decode_paged`) skips the gather entirely: the
    block table becomes a scalar-prefetch operand and the DMA engine
    fetches physical pages directly. Quantized pools (k_scale/v_scale,
    DESIGN.md §3.8) are dequantized during the gather.
    """
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    k_cache = gather_pages(k_pages, block_tbl, scales=k_scale)
    v_cache = gather_pages(v_pages, block_tbl, scales=v_scale)
    return decode_attention(
        q, k_cache, v_cache, cache_len, scale=scale, window=window,
        chunk=chunk, n_splits=n_splits,
    )
