"""Paper-faithful sequential FlashAttention variants (Algs. 1-3 of FLASH-D).

These are the *reference* forms: one key/value pair consumed per scan step,
exactly as written in the paper. They exist to (a) validate the paper's
mathematical-equivalence claim, (b) serve as oracles for the tiled/blocked
implementations, and (c) instrument element-level skip statistics (Table I).

All functions take
    q : [d]            a single query vector
    k : [N, d]         key vectors
    v : [N, dv]        value vectors
and return the attention output [dv] (and auxiliary state where noted).
Batched wrappers live in `repro.core.attention`.

The recurrences are carried with `jax.lax.scan` so they stay `jit`- and
`vmap`-compatible (no Python loops over sequence length).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "naive_attention",
    "flash_attention_alg1",
    "flash_attention2_alg2",
    "flashd_alg3",
    "flashd_alg3_skipstats",
    "SKIP_LO",
    "SKIP_HI",
]

# Paper §III-C: outside [-6, 11] the sigmoid saturates; w_i is set to 0/1
# by default and the exponential (and the output update) is skipped.
SKIP_LO = -6.0
SKIP_HI = 11.0


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Textbook softmax attention for one query (the ground-truth oracle)."""
    s = k @ q  # [N]
    f = jax.nn.softmax(s)
    return f @ v


def flash_attention_alg1(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Alg. 1 — baseline FlashAttention: incremental softmax division."""
    d = q.shape[-1]
    dv = v.shape[-1]

    def step(carry, kv):
        m_prev, l_prev, o_prev = carry
        k_i, v_i = kv
        s_i = jnp.dot(q, k_i)
        m_i = jnp.maximum(m_prev, s_i)
        alpha = jnp.exp(m_prev - m_i)
        p_i = jnp.exp(s_i - m_i)
        l_i = l_prev * alpha + p_i
        o_i = o_prev * (l_prev * alpha / l_i) + v_i * (p_i / l_i)
        return (m_i, l_i, o_i), None

    init = (jnp.float32(-jnp.inf), jnp.float32(0.0), jnp.zeros((dv,), jnp.float32))
    (_, _, o), _ = jax.lax.scan(step, init, (k.astype(jnp.float32), v.astype(jnp.float32)))
    return o


def flash_attention2_alg2(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Alg. 2 — FlashAttention2: lazy softmax division (one final divide)."""
    dv = v.shape[-1]

    def step(carry, kv):
        m_prev, l_prev, o_prev = carry
        k_i, v_i = kv
        s_i = jnp.dot(q, k_i)
        m_i = jnp.maximum(m_prev, s_i)
        alpha = jnp.exp(m_prev - m_i)
        p_i = jnp.exp(s_i - m_i)
        l_i = l_prev * alpha + p_i
        o_i = o_prev * alpha + v_i * p_i
        return (m_i, l_i, o_i), None

    init = (jnp.float32(-jnp.inf), jnp.float32(0.0), jnp.zeros((dv,), jnp.float32))
    (_, l_n, o), _ = jax.lax.scan(step, init, (k.astype(jnp.float32), v.astype(jnp.float32)))
    return o / l_n


class _FlashDCarry(NamedTuple):
    s_prev: jax.Array  # previous attention score s_{i-1}
    ln_w_prev: jax.Array  # ln w_{i-1}  (w_1 = 1 -> ln w_1 = 0)
    o: jax.Array  # running output vector


def _flashd_step_weight(s_i, s_prev, ln_w_prev, *, saturate: bool):
    """w_i = sigmoid(s_i - s_{i-1} + ln w_{i-1}), with the paper's
    saturation rule applied when `saturate` (skip the exponential outside
    the active region [-6, 11] and return the default 0/1 weight).

    Also returns ln w_i computed EXACTLY in log space (log_sigmoid =
    −softplus(−δ)): the carried (s, ln w) pair encodes the running LSE as
    Λ = s − ln w, and round-tripping through w itself (ln(σ(δ)) after σ
    saturates to 0 in f32) silently clamps Λ at ~87 — the hardware analogue
    is the format-floor of the stored weight (§III-C). The fused log-space
    form keeps Alg. 3 exact over the full f32 range."""
    delta = s_i - s_prev + ln_w_prev
    w = jax.nn.sigmoid(delta)
    ln_w = jax.nn.log_sigmoid(delta)
    if saturate:
        w = jnp.where(
            delta <= SKIP_LO,
            0.0,
            jnp.where(delta >= SKIP_HI, 1.0, w),
        )
        ln_w = jnp.where(delta >= SKIP_HI, 0.0, ln_w)
    return w, ln_w, delta


def flashd_alg3(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    saturate: bool = False,
) -> jax.Array:
    """Alg. 3 — FLASH-D: softmax division hidden in the sigmoid.

    Carries (s_{i-1}, ln w_{i-1}, o) — note: *no running max, no running
    sum-of-exponents*. With `saturate=True` the paper's [-6, 11] static
    saturation/skip criterion is applied (still exact to ~sigmoid(-6)≈2e-3
    of weight mass; the paper reports no application-level effect).
    """
    dv = v.shape[-1]

    def step(carry: _FlashDCarry, xs):
        k_i, v_i, is_first = xs
        s_i = jnp.dot(q, k_i)
        w_i, ln_w, delta = _flashd_step_weight(
            s_i, carry.s_prev, carry.ln_w_prev, saturate=saturate
        )
        w_i = jnp.where(is_first, 1.0, w_i)  # Alg.3 line 7: w_1 = 1
        ln_w = jnp.where(is_first, 0.0, ln_w)
        # Eq. 12: o_i = o_{i-1} + (v_i - o_{i-1}) w_i  -- one FMA, no division
        o_i = carry.o + (v_i - carry.o) * w_i
        new = _FlashDCarry(s_i, ln_w, o_i)
        if saturate:
            # Skip semantics (§III-C): when w_i defaults to 0 nothing is
            # computed or written — o AND the carried (s_prev, ln w_prev)
            # registers stay put, so the next sigmoid argument is
            # s_{i+1} − s_{i-1} + ln w_{i-1} = s_{i+1} − Λ, still exact.
            skip = jnp.logical_and(~is_first, delta <= SKIP_LO)
            new = jax.tree.map(lambda a, b: jnp.where(skip, a, b), carry, new)
        return new, None

    n = k.shape[0]
    init = _FlashDCarry(jnp.float32(0.0), jnp.float32(0.0), jnp.zeros((dv,), jnp.float32))
    is_first = jnp.arange(n) == 0
    (carry), _ = jax.lax.scan(
        step, init, (k.astype(jnp.float32), v.astype(jnp.float32), is_first)
    )
    return carry.o


def flashd_alg3_skipstats(
    q: jax.Array, k: jax.Array, v: jax.Array, n_valid=None
):
    """FLASH-D forward that also returns Table-I skip statistics.

    Returns (o, n_skip_low, n_skip_high): `n_skip_low` counts steps with
    sigmoid argument <= -6 (output update skipped entirely: no v_i load, no
    FMA); `n_skip_high` counts >= 11 (output replaced by v_i: FMA skipped).
    `n_valid` limits the scan to a key prefix (causal evaluation: query i
    processes keys [0..i] exactly as an incremental decoder would).
    """
    dv = v.shape[-1]
    n = k.shape[0]
    if n_valid is None:
        n_valid = n

    def step(carry, xs):
        (s_prev, ln_w_prev, o_prev, nlo, nhi) = carry
        k_i, v_i, idx = xs
        is_first = idx == 0
        in_prefix = idx < n_valid
        s_i = jnp.dot(q, k_i)
        w_i, ln_w, delta = _flashd_step_weight(s_i, s_prev, ln_w_prev, saturate=True)
        w_i = jnp.where(is_first, 1.0, w_i)
        ln_w = jnp.where(is_first, 0.0, ln_w)
        live = jnp.logical_and(~is_first, in_prefix)
        skip_lo = jnp.logical_and(live, delta <= SKIP_LO)
        skip_hi = jnp.logical_and(live, delta >= SKIP_HI)
        o_i = o_prev + (v_i - o_prev) * w_i
        # on skip (or past the prefix), registers stay put (see flashd_alg3)
        hold = jnp.logical_or(skip_lo, ~in_prefix)
        s_i = jnp.where(hold, s_prev, s_i)
        ln_w = jnp.where(hold, ln_w_prev, ln_w)
        o_i = jnp.where(hold, o_prev, o_i)
        return (s_i, ln_w, o_i, nlo + skip_lo, nhi + skip_hi), None

    init = (
        jnp.float32(0.0),
        jnp.float32(0.0),
        jnp.zeros((dv,), jnp.float32),
        jnp.int32(0),
        jnp.int32(0),
    )
    (_, _, o, nlo, nhi), _ = jax.lax.scan(
        step, init, (k.astype(jnp.float32), v.astype(jnp.float32), jnp.arange(n))
    )
    return o, nlo, nhi


# Convenience batched forms (over heads/batch) used by tests and Table I.
flashd_alg3_batched = jax.vmap(
    jax.vmap(functools.partial(flashd_alg3), in_axes=(0, None, None)),
    in_axes=(0, 0, 0),
)
naive_attention_batched = jax.vmap(
    jax.vmap(naive_attention, in_axes=(0, None, None)), in_axes=(0, 0, 0)
)
