"""8-segment piecewise-linear sigmoid / ln — the paper's §IV-B datapath.

The ASIC implements σ(x) on the active region [-6, 11] and ln(w) on (0, 1)
with 8-segment PWL function units (coefficients fitted with `pwlf` in the
paper; here with deterministic endpoint-interpolation + one least-squares
refinement pass, no external dependency). Outside the active region the
hardware returns the saturated default — exactly the paper's skip rule.

These are provided to (a) mirror the paper's hardware datapath bit-for-bit
in the `flashd_pwl` attention variant and (b) let the Table-I/Fig-4 style
benchmarks quantify the accuracy cost (none at application level, per the
paper). The default TPU path uses exact transcendentals (DESIGN.md §2.3).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["pwl_sigmoid", "pwl_ln", "SIGMOID_RANGE", "pwl_coeffs"]

SIGMOID_RANGE = (-6.0, 11.0)  # paper Fig. 2 active region
_N_SEG = 8


def _fit_pwl(fn, lo: float, hi: float, n_seg: int, log_space: bool = False):
    """Continuous PWL fit: segment endpoints on the curve, then a least-squares
    slope/intercept refinement per segment (keeps continuity to ~1e-3)."""
    if log_space:
        breaks = np.exp(np.linspace(np.log(lo), np.log(hi), n_seg + 1))
    else:
        breaks = np.linspace(lo, hi, n_seg + 1)
    slopes, intercepts = [], []
    for a, b in zip(breaks[:-1], breaks[1:]):
        xs = np.linspace(a, b, 64)
        ys = fn(xs)
        A = np.stack([xs, np.ones_like(xs)], axis=1)
        (m, c), *_ = np.linalg.lstsq(A, ys, rcond=None)
        slopes.append(m)
        intercepts.append(c)
    return (
        jnp.asarray(breaks, jnp.float32),
        jnp.asarray(slopes, jnp.float32),
        jnp.asarray(intercepts, jnp.float32),
    )


_SIG_BREAKS, _SIG_M, _SIG_C = _fit_pwl(
    lambda x: 1.0 / (1.0 + np.exp(-x)), SIGMOID_RANGE[0], SIGMOID_RANGE[1], _N_SEG
)
# ln over (0,1): geometric breakpoints resolve the singularity near 0 the way
# a hardware LUT with exponent-indexed segments would.
_LN_BREAKS, _LN_M, _LN_C = _fit_pwl(np.log, 2.0 ** -6, 1.0, _N_SEG, log_space=True)


def pwl_coeffs():
    """Expose fitted coefficients (benchmarks report them per paper §IV-B)."""
    return {
        "sigmoid": (_SIG_BREAKS, _SIG_M, _SIG_C),
        "ln": (_LN_BREAKS, _LN_M, _LN_C),
    }


def _pwl_eval(x, breaks, m, c):
    idx = jnp.clip(jnp.searchsorted(breaks, x) - 1, 0, m.shape[0] - 1)
    return m[idx] * x + c[idx]


def pwl_sigmoid(x: jax.Array) -> jax.Array:
    """PWL σ(x): saturates to 0 / 1 outside [-6, 11] (paper skip rule)."""
    y = _pwl_eval(x, _SIG_BREAKS, _SIG_M, _SIG_C)
    y = jnp.where(x <= SIGMOID_RANGE[0], 0.0, y)
    y = jnp.where(x >= SIGMOID_RANGE[1], 1.0, y)
    return jnp.clip(y, 0.0, 1.0)


def pwl_ln(w: jax.Array) -> jax.Array:
    """PWL ln(w) on (0,1): always ≤ 0, clamped at the smallest segment."""
    w = jnp.clip(w, float(_LN_BREAKS[0]), 1.0)
    return jnp.minimum(_pwl_eval(w, _LN_BREAKS, _LN_M, _LN_C), 0.0)
