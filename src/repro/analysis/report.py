"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun_results.json.

    PYTHONPATH=src python -m repro.analysis.report dryrun_results.json
"""

from __future__ import annotations

import json
import sys
from typing import List


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def render(results: List[dict]) -> str:
    lines = []
    ok = [r for r in results if r["status"] == "ok"]
    skipped = [r for r in results if r["status"] == "skipped"]
    errors = [r for r in results if r["status"] == "error"]

    lines.append(f"Cells: {len(ok)} compiled, {len(skipped)} skipped (documented), "
                 f"{len(errors)} errors.\n")

    lines.append("| arch | shape | mesh | compile s | mem/dev GiB | fits 16G | "
                 "t_compute ms | t_memory ms | t_coll ms | dominant | useful |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r["roofline"]
        mem = (r.get("memory") or {}).get("total_bytes_per_device", 0)
        fits = "yes" if mem <= 16 * 2**30 else "**NO**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.0f} "
            f"| {_fmt_bytes(mem)} | {fits} "
            f"| {rl['t_compute']*1e3:.1f} | {rl['t_memory']*1e3:.1f} "
            f"| {rl['t_collective']*1e3:.1f} | {rl['dominant']} "
            f"| {rl['useful_flops_ratio']:.2f} |"
        )
    if skipped:
        lines.append("\nSkipped cells:\n")
        for r in sorted(skipped, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            lines.append(f"* {r['arch']} × {r['shape']} × {r['mesh']} — {r['reason']}")
    if errors:
        lines.append("\nErrored cells:\n")
        for r in errors:
            lines.append(f"* {r['arch']} × {r['shape']} × {r['mesh']} — {r['error']}")
    return "\n".join(lines)


def render_collectives(results: List[dict], arch: str, shape: str, mesh: str) -> str:
    for r in results:
        if (r["arch"], r["shape"], r["mesh"]) == (arch, shape, mesh) and r["status"] == "ok":
            rows = ["| collective | count | result GiB | wire GiB |", "|---|---|---|---|"]
            for k, v in sorted(r["roofline"]["collectives"].items()):
                rows.append(
                    f"| {k} | {v['count']:.0f} | {v['bytes']/2**30:.2f} "
                    f"| {v.get('wire_bytes', 0)/2**30:.2f} |"
                )
            return "\n".join(rows)
    return "(cell not found)"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print(render(results))


if __name__ == "__main__":
    main()
