"""Roofline extraction from a compiled dry-run artifact (no hardware).

Three terms, in seconds, per the assignment:
    compute    = HLO_FLOPs      / (chips × peak_FLOP/s)
    memory     = HLO_bytes      / (chips × HBM_bw)
    collective = collective_B   / (chips × link_bw)

`compiled.cost_analysis()` yields the PER-DEVICE SPMD program's flops/bytes
(XLA compiles one per-device module), so we divide by per-chip peaks
directly; collective bytes are parsed from the post-partitioning HLO text
(`compiled.as_text()`) by summing operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops (also per-device).

Hardware constants (TPU v5e, assignment-specified):
    197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "collective_bytes", "roofline", "RooflineReport", "parse_hlo_collectives"]

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit {{0,1,2,...},{...}} form: first group's member count
        return max(len(m.group(1).split(",")), 1)
    return 1


def parse_hlo_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: {count, bytes (result sizes), wire_bytes}.

    The CPU HLO printer omits operand types, so sizes come from the result
    shape on the LHS; per-device wire bytes follow the standard ring-
    algorithm volumes over the op's replica group of size g:
        all-gather       out·(g−1)/g      (out is the gathered size)
        all-reduce       2·size·(g−1)/g
        reduce-scatter   out·(g−1)        (input = out·g)
        all-to-all       size·(g−1)/g
        collective-permute  size
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0} for k in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.*?)\s+([\w-]+)\(", line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        size = _shape_bytes(result_type)
        g = _group_size(line)
        if base == "all-gather":
            wire = size * (g - 1) / max(g, 1)
        elif base == "all-reduce":
            wire = 2.0 * size * (g - 1) / max(g, 1)
        elif base == "reduce-scatter":
            wire = size * (g - 1)
        elif base == "all-to-all":
            wire = size * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = size
        out[base]["count"] += 1
        out[base]["bytes"] += size
        out[base]["wire_bytes"] += wire
    return out


def collective_bytes(hlo_text: str) -> float:
    return sum(v["wire_bytes"] for v in parse_hlo_collectives(hlo_text).values())


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, Dict[str, float]]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float  # 6·N·D (or 6·N_active·D) global
    useful_flops_ratio: float  # model_flops / (flops_per_device × chips)
    chips: int
    memory_per_device: Optional[dict] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    hw: HW = HW(),
    memory_per_device: Optional[dict] = None,
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    colls = parse_hlo_collectives(hlo_text)
    cbytes = sum(v["wire_bytes"] for v in colls.values())
    t_c = flops / hw.peak_flops
    t_m = bytes_ / hw.hbm_bw
    t_x = cbytes / hw.ici_bw
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)), key=lambda kv: kv[1])[0]
    total_flops = flops * chips
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=bytes_,
        collective_bytes_per_device=cbytes,
        collectives={k: v for k, v in colls.items() if v["count"]},
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dom,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_flops) if total_flops else 0.0,
        chips=chips,
        memory_per_device=memory_per_device,
    )
