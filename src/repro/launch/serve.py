"""Serving launcher: load (or init) weights, run the batched engine.

    PYTHONPATH=src python -m repro.launch.serve --arch paper-llama \
        --requests 6 --max-new-tokens 16

Per-request time-to-first-token is printed for EVERY step mode (the
scheduler tracks it per request id from enqueue to first token, so a
priority-swapped or preempted request reports the waiting time it really
accrued), and the paged engines print the prefix-cache / preemption
counters from `Engine.stats()` (DESIGN.md §3.6).

Fault tolerance (DESIGN.md §3.7): `--fault-rate`/`--fault-seed` turn on
deterministic chaos injection, `--deadline-ms`/`--max-retries` set the
per-request lifecycle budgets, and every request's terminal status
(done / failed / expired) is printed with the retry/downgrade counters.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import get_model
from repro.runtime import checkpoint as ckpt
from repro.serve import Engine, ServeConfig


def _parse_priorities(spec, n_requests):
    """--priorities "2,0,1,..." (1:1 with requests) or "mixed" (alternate
    two classes — a quick way to see preemptive scheduling act)."""
    if spec is None:
        return None
    if spec == "mixed":
        return [i % 2 for i in range(n_requests)]
    prios = [int(x) for x in spec.split(",")]
    if len(prios) != n_requests:
        raise SystemExit(
            f"--priorities lists {len(prios)} values for {n_requests} requests"
        )
    return prios


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-llama")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--ckpt-dir", default=None, help="restore trained weights")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--max-new-tokens", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kv-layout", choices=("contiguous", "paged"),
                   default="contiguous",
                   help="paged: page-pool KV, admission by free pages "
                        "(DESIGN.md §3.4)")
    p.add_argument("--page-size", type=int, default=0,
                   help="tokens per KV page (0 → tuned)")
    p.add_argument("--kv-pool-tokens", type=int, default=0,
                   help="paged pool size in tokens (0 → max_batch·max_len)")
    p.add_argument("--kv-dtype", default="",
                   help='quantize the paged KV pool: "int8" (or "fp8" '
                        'where the host jax supports it) stores pages at '
                        '1 B/elem with a per-page scale side-band — ~4x '
                        'the tokens per byte of HBM (DESIGN.md §3.8); '
                        'requires --kv-layout paged; "" → native dtype')
    p.add_argument("--step-mode", choices=("sequential", "mixed"),
                   default="sequential",
                   help="mixed: chunked-prefill continuous batching — one "
                        "packed varlen step per iteration (DESIGN.md §3.5)")
    p.add_argument("--token-budget", type=int, default=0,
                   help="packed tokens per mixed step (0 → heuristic)")
    p.add_argument("--prefill-chunk", type=int, default=16,
                   help="max prompt tokens one sequence feeds per mixed step")
    p.add_argument("--shared-prefix-len", type=int, default=0,
                   help="prepend a shared system prompt of this many tokens "
                        "to every request (exercises the radix prefix "
                        "cache, DESIGN.md §3.6)")
    p.add_argument("--priorities", default=None,
                   help='comma-separated ints (1:1 with requests) or '
                        '"mixed" — higher value is served first and may '
                        'preempt lower (DESIGN.md §3.6)')
    p.add_argument("--no-prefix-cache", action="store_true",
                   help="disable the radix prefix cache")
    p.add_argument("--no-preemption", action="store_true",
                   help="worst-case reservation admission instead of "
                        "optimistic allocation + preemption")
    p.add_argument("--deadline-ms", type=float, default=0.0,
                   help="per-request deadline in milliseconds (0 → none); "
                        "overdue requests are cancelled like EOS with "
                        "status 'expired' (DESIGN.md §3.7)")
    p.add_argument("--max-retries", type=int, default=3,
                   help="fault-retry budget per request before it goes "
                        "terminal-FAILED (DESIGN.md §3.7)")
    p.add_argument("--fault-rate", type=float, default=0.0,
                   help="chaos injection: probability each fault-site "
                        "check fires (0 → no injection)")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="seed for the deterministic fault injector")
    p.add_argument("--spec-tokens", type=int, default=0,
                   help="speculative decoding: draft tokens verified per "
                        "round through one packed varlen dispatch "
                        "(DESIGN.md §3.9); 0 → off. Greedy only; needs "
                        "--kv-layout paged or --step-mode mixed")
    p.add_argument("--draft-config", default="qwen3-0.6b",
                   help="architecture of the draft model proposing spec "
                        "tokens (smoke config under --smoke; randomly "
                        "initialized unless the checkpoint provides it)")
    p.add_argument("--no-spec", action="store_true",
                   help="force speculation off even if --spec-tokens is "
                        "set (quick A/B against the same command line)")
    args = p.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir:
        from repro.train.train_step import TrainConfig, init_train_state

        template = init_train_state(jax.random.PRNGKey(args.seed), cfg, TrainConfig())
        state, _ = ckpt.restore(args.ckpt_dir, template)
        params = state.params
        print(f"restored weights from {args.ckpt_dir}")

    spec_tokens = 0 if args.no_spec else args.spec_tokens
    draft = None
    if spec_tokens > 0:
        # a randomly initialized draft still exercises the whole verify /
        # rollback path (its proposals mostly get rejected — output stays
        # token-identical by construction); real deployments restore
        # trained draft weights here
        dcfg = (configs.get_smoke_config(args.draft_config) if args.smoke
                else configs.get_config(args.draft_config))
        dparams = get_model(dcfg).init(jax.random.PRNGKey(args.seed + 1), dcfg)
        draft = (dparams, dcfg)
        print(f"speculative decoding: draft={args.draft_config} "
              f"k={spec_tokens}")

    eng = Engine(params, cfg, ServeConfig(
        max_batch=args.max_batch,
        max_len=args.shared_prefix_len + args.prompt_len + args.max_new_tokens + 8,
        temperature=args.temperature,
        seed=args.seed,
        kv_layout=args.kv_layout,
        page_size=args.page_size,
        kv_pool_tokens=args.kv_pool_tokens,
        kv_dtype=args.kv_dtype,
        step_mode=args.step_mode,
        token_budget=args.token_budget,
        prefill_chunk=args.prefill_chunk,
        prefix_cache=not args.no_prefix_cache,
        preemption=not args.no_preemption,
        max_retries=args.max_retries,
        deadline_s=args.deadline_ms / 1e3,
        fault_rate=args.fault_rate,
        fault_seed=args.fault_seed,
        spec_tokens=spec_tokens,
    ), draft=draft)
    rng = np.random.default_rng(args.seed)
    shared = rng.integers(
        0, cfg.vocab_size, (args.shared_prefix_len,)
    ).astype(np.int32)
    reqs = [
        np.concatenate([
            shared,
            rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32),
        ])
        for _ in range(args.requests)
    ]
    priorities = _parse_priorities(args.priorities, len(reqs))
    t0 = time.time()
    outs = eng.serve(reqs, max_new_tokens=args.max_new_tokens,
                     priorities=priorities)
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in outs)
    status = eng.stats()["request_status"]
    for i, o in enumerate(outs):
        print(f"request {i} [{status.get(i, '?'):>7}]: {o.tolist()}")
    layout = "paged pool" if eng._page_layout is not None else "contiguous slots"
    mode = "mixed varlen steps" if eng._mixed_ok else "sequential chunks"
    print(f"{total_tokens} tokens in {dt:.2f}s → {total_tokens/dt:.1f} tok/s "
          f"(batched decode over {args.max_batch} slots, {layout}, {mode}, "
          f"peak {eng.peak_active} concurrent)")
    if eng.ttft:  # every step mode reports per-request TTFT
        print("time-to-first-token (enqueue → first token, per request):")
        for rid in sorted(eng.ttft):
            prio = f" prio={priorities[rid]}" if priorities is not None else ""
            print(f"  request {rid}:{prio} {eng.ttft[rid]*1e3:8.1f} ms")
        ttft = [eng.ttft[r] for r in sorted(eng.ttft)]
        print(f"  mean {np.mean(ttft)*1e3:.1f} ms, max {np.max(ttft)*1e3:.1f} ms")
    st = eng.stats()
    if "kv_pool_bytes" in st:
        print(f"kv pool: {st['kv_dtype']}, "
              f"{st['kv_pool_bytes'] / 1024:.1f} KiB "
              f"({st['kv_bytes_per_token']:.0f} B/token)")
    if st["prefix_cache_enabled"] or st["preemption_enabled"]:
        print(f"serving core: prefix-cache hit rate "
              f"{100 * st['hit_rate']:.1f}% "
              f"({st['hit_tokens']}/{st['prompt_tokens']} prompt tokens, "
              f"{st.get('cached_pages', 0)} pages retained), "
              f"{st['preemptions']} preemptions, "
              f"{st.get('evictions', 0)} evictions")
    if args.fault_rate > 0 or args.deadline_ms > 0 or st["retried"]:
        n_done = sum(s == "done" for s in status.values())
        print(f"fault tolerance: {n_done}/{len(outs)} done, "
              f"{st['failed']} failed, {st['expired']} expired, "
              f"{st['retried']} retries, {st['downgrades']} downgrades "
              f"(impl now {st['attn_impl']}), "
              f"faults fired {st.get('injected_faults', {})}")
    if st.get("spec_enabled"):
        print(f"speculation: acceptance {100 * st['spec_acceptance_rate']:.1f}% "
              f"({st['spec_accepted']}/{st['spec_drafted']} drafts, "
              f"{st['spec_rejected']} rejected), "
              f"{st['spec_mean_accepted']:.2f} accepted/round "
              f"over {st['spec_rounds']} rounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
