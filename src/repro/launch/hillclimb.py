import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""§Perf hillclimb driver: hypothesis → change → re-lower → validate.

Runs a named list of variants for one (arch × shape × mesh) cell and
records the three roofline terms per variant into hillclimb_results.json.
Each variant is a combination of the framework's perf levers:

  attn=fa2|flashd         kernel family (fa2 = the paper's baseline)
  skip                    FLASH-D tile-skip predication
  remat=dots|full|none    activation-checkpoint policy
  nosp                    disable sequence-parallel residual sharding
  cast1                   bf16 cast-before-FSDP-gather (halves gather bytes)
  int8grad                error-feedback int8 gradient compression
  accum=N                 microbatch count
  bq=N / bk=N             attention tile sizes
  cf=X                    MoE capacity factor

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch yi-34b \
      --shape train_4k --variants baseline,cast1,cast1+int8grad
"""

import argparse
import json
import sys

from repro.launch import dryrun as dr
from repro.optim import CompressionConfig


def parse_variant(spec: str):
    """'cast1+int8grad+remat=dots' → kwargs for dr.run_cell."""
    kw = dict(attn_impl=None, remat=None, extra_cfg={}, train_overrides={},
              use_sp=True, use_tp=True)
    if spec in ("baseline", ""):
        return kw
    for part in spec.split("+"):
        if part.startswith("attn="):
            kw["attn_impl"] = part.split("=", 1)[1]
        elif part == "skip":
            kw["extra_cfg"]["attn_skip"] = True
        elif part.startswith("remat="):
            kw["remat"] = part.split("=", 1)[1]
        elif part == "nosp":
            kw["use_sp"] = False
        elif part == "notp":
            kw["use_tp"] = False
        elif part == "cast1":
            kw["train_overrides"]["cast_params_once"] = True
        elif part == "gradbf16":
            kw["train_overrides"]["grad_dtype"] = "bfloat16"
            kw["train_overrides"]["cast_params_once"] = True
        elif part == "int8grad":
            kw["train_overrides"]["compression"] = CompressionConfig(kind="int8")
        elif part.startswith("accum="):
            kw["train_overrides"]["accum_steps"] = int(part.split("=", 1)[1])
        elif part.startswith("bq="):
            kw["extra_cfg"]["attn_block_q"] = int(part.split("=", 1)[1])
        elif part.startswith("bk="):
            kw["extra_cfg"]["attn_block_k"] = int(part.split("=", 1)[1])
        elif part.startswith("cf="):
            kw["extra_cfg"]["capacity_factor"] = float(part.split("=", 1)[1])
        else:
            raise ValueError(f"unknown variant token {part!r}")
    return kw


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--variants", required=True, help="comma-separated specs")
    p.add_argument("--out", default="hillclimb_results.json")
    args = p.parse_args(argv)

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for spec in args.variants.split(","):
        spec = spec.strip()
        key = (args.arch, args.shape, args.mesh, spec)
        if any((r["arch"], r["shape"], r["mesh_flag"], r["variant"]) == key
               for r in results):
            print(f"[skip existing] {spec}")
            continue
        kw = parse_variant(spec)
        try:
            rec = dr.run_cell(
                args.arch, args.shape, args.mesh == "multi",
                attn_impl=kw["attn_impl"], remat=kw["remat"],
                extra_cfg=kw["extra_cfg"] or None,
                train_overrides=kw["train_overrides"] or None,
                use_sp=kw["use_sp"], use_tp=kw["use_tp"], verbose=False,
            )
            rl = rec["roofline"]
            print(
                f"[{spec:40s}] tc={rl['t_compute']*1e3:9.1f}ms "
                f"tm={rl['t_memory']*1e3:9.1f}ms tx={rl['t_collective']*1e3:9.1f}ms "
                f"dom={rl['dominant']:10s} useful={rl['useful_flops_ratio']:.2f} "
                f"mem={rec['memory'].get('total_bytes_per_device',0)/2**30:.1f}GiB",
                flush=True,
            )
        except Exception as e:
            rec = {"status": "error", "error": str(e), "roofline": None, "memory": {}}
            print(f"[{spec}] ERROR {e}", flush=True)
        rec["variant"] = spec
        rec["arch"], rec["shape"], rec["mesh_flag"] = args.arch, args.shape, args.mesh
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
