"""Production mesh construction (assignment-specified shapes).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init; everything else
sees the host's single device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly forced-host) devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))
