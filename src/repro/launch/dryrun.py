import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY jax import (jax pins device count at
first init) and exist ONLY here — tests/benches see the real single device.

Per cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        memory_analysis() / cost_analysis() / HLO-collective parse → JSON

Results append incrementally to --out (default dryrun_results.json);
existing (arch, shape, mesh) entries are skipped unless --force.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi   # 2×16×16 only
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.analysis import roofline as rf
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.train.train_step import TrainConfig, TrainState, make_train_step
from repro.optim import OptState


# Per-arch memory-fit knobs for the 256-chip/16GB-HBM pod, recorded in the
# cell records: microbatch accumulation bounds live activations; bf16 Adam
# m/v halves optimizer state (math stays f32). Serve cells load bf16 weights
# (standard inference practice).
TRAIN_KNOBS = {
    "yi-34b": dict(accum_steps=2, opt_state_dtype="bfloat16"),
    "qwen3-moe-235b-a22b": dict(accum_steps=4, opt_state_dtype="bfloat16"),
    "llama4-scout-17b-a16e": dict(accum_steps=2, opt_state_dtype="bfloat16"),
    "recurrentgemma-9b": dict(opt_state_dtype="bfloat16"),
}
# archs whose optimizer state must ZeRO-shard across pods too (DESIGN.md §4)
FSDP_OVER_POD = {"qwen3-moe-235b-a22b", "llama4-scout-17b-a16e", "yi-34b"}


def _train_cfg_for(arch: str) -> TrainConfig:
    return TrainConfig(**TRAIN_KNOBS.get(arch, {}))


def _state_struct(cfg, train_cfg: TrainConfig):
    """Abstract TrainState via eval_shape (no allocation)."""
    api = get_model(cfg)
    opt_dt = jnp.dtype(train_cfg.opt_state_dtype)

    def mk():
        params = api.init(jax.random.PRNGKey(0), cfg)
        zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, opt_dt), p)
        return TrainState(
            params=params,
            opt=OptState(m=zeros(params), v=zeros(params), step=jnp.int32(0)),
            residual=None,
            step=jnp.int32(0),
            loss_scale=jnp.float32(1.0),
            good_steps=jnp.int32(0),
            skipped=jnp.int32(0),
        )

    return jax.eval_shape(mk)


def _state_specs(state_struct):
    pspec = shd.param_specs(state_struct.params)
    from jax.sharding import PartitionSpec as P

    return TrainState(
        params=pspec,
        opt=OptState(m=pspec, v=pspec, step=P()),
        residual=None,
        step=P(),
        loss_scale=P(),
        good_steps=P(),
        skipped=P(),
    )


def build_cell(arch: str, shape_name: str, *, attn_impl=None, remat=None,
               use_sp=None, extra_cfg=None, train_overrides=None):
    """Returns (step_fn, arg_structs, in_specs, model_flops, cfg)."""
    from jax.sharding import PartitionSpec as P

    cfg = configs.get_config(arch)
    overrides = dict(extra_cfg or {})
    if attn_impl:
        overrides["attn_impl"] = attn_impl
    if remat:
        overrides["remat"] = remat
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = configs.SHAPES[shape_name]
    api = get_model(cfg)
    batch_struct = configs.input_specs(cfg, shape)
    tokens_global = shape.global_batch * shape.seq_len

    if shape.kind == "train":
        train_cfg = dataclasses.replace(_train_cfg_for(arch), **(train_overrides or {}))
        train_step = make_train_step(cfg, train_cfg)
        state_struct = _state_struct(cfg, train_cfg)
        state_specs = _state_specs(state_struct)
        batch_specs = shd.batch_specs(batch_struct)
        step = train_step
        args = (state_struct, batch_struct)
        in_specs = (state_specs, batch_specs)
        donate = (0,)  # TrainState buffers reused in place (params/opt/grads)
        # metrics are replicated scalars; new state keeps the input sharding
        metrics_struct = jax.eval_shape(step, state_struct, batch_struct)[1]
        out_specs = (state_specs, jax.tree.map(lambda _: P(), metrics_struct))
        model_flops = 6.0 * cfg.active_param_count() * tokens_global
    elif shape.kind == "prefill":
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")  # inference weights
        api = get_model(cfg)
        params_struct = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
        pspecs = shd.param_specs(params_struct)
        batch_specs = shd.batch_specs(batch_struct)
        step = lambda params, batch: api.apply(params, batch, cfg, last_only=True)[0]
        args = (params_struct, batch_struct)
        in_specs = (pspecs, batch_specs)
        donate = ()
        logits_struct = jax.eval_shape(step, params_struct, batch_struct)
        out_specs = shd.batch_specs(logits_struct)
        model_flops = 2.0 * cfg.active_param_count() * tokens_global
    else:  # decode
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")  # inference weights
        api = get_model(cfg)
        params_struct = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg))
        pspecs = shd.param_specs(params_struct)
        cache_struct = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len, cfg)
        )
        cache_specs = shd.cache_specs_tree(cache_struct)
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        step = lambda params, cache, token, p: api.decode_step(params, cache, token, p, cfg)
        args = (params_struct, cache_struct, tok, pos)
        in_specs = (pspecs, cache_specs, P(), P())
        donate = (1,)  # KV cache updated in place
        logits_struct = jax.eval_shape(step, *args)[0]
        out_specs = (shd.batch_specs(logits_struct), cache_specs)
        model_flops = 2.0 * cfg.active_param_count() * shape.global_batch
    return step, args, in_specs, out_specs, donate, model_flops, cfg


def _compile_once(arch, shape_name, mesh, *, attn_impl=None, remat=None,
                  extra_cfg=None, train_overrides=None, use_sp=True, use_tp=True):
    """Lower + compile one cell variant. Returns (cost, hlo, mem, secs, cfg)."""
    t0 = time.time()
    fsdp = ("pod", "data") if arch in FSDP_OVER_POD else "data"
    ctx = shd.ShardingCtx(mesh, fsdp_axis=fsdp, use_sp=use_sp)
    ctx.tp_activations = use_tp
    with shd.activate(ctx):
        with shd.mesh_ctx(mesh):
            step, args, in_specs, out_specs, donate, model_flops, cfg = build_cell(
                arch, shape_name, attn_impl=attn_impl, remat=remat,
                extra_cfg=extra_cfg, train_overrides=train_overrides,
            )
            jitted = shd.sharded_jit(step, in_shardings=in_specs,
                                     out_shardings=out_specs,
                                     donate_argnums=donate)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()

            mem = {}
            try:
                ma = compiled.memory_analysis()
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                    v = getattr(ma, k, None)
                    if v is not None:
                        mem[k] = int(v)
                mem["total_bytes_per_device"] = (
                    mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0)
                )
            except Exception as e:  # pragma: no cover
                mem["error"] = str(e)

            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                cost = {k: float(v) for k, v in ca.items()
                        if isinstance(v, (int, float))}
            except Exception as e:  # pragma: no cover
                cost = {"error": str(e)}
            hlo = compiled.as_text()
    return cost, hlo, mem, time.time() - t0, model_flops, cfg


def _corrected_cost(arch, shape_name, mesh, cfg, *, attn_impl=None, remat=None,
                    extra_cfg=None, train_overrides=None, use_sp=True, use_tp=True):
    """Trip-count-corrected (flops, bytes, collectives) via unrolled probes.

    XLA cost_analysis counts a while-loop (lax.scan) body ONCE, so the
    full-depth compile undercounts scanned layers. We compile python-unrolled
    1-block and 2-block variants at the SAME global shape; the difference is
    exactly one pattern-block's cost and
        total = c(p) + (L/p − 1) · (c(2p) − c(p))
    (embed/head/frontend costs cancel in the difference). Collective bytes
    get the same correction from the probes' HLO.
    """
    p = len(cfg.pattern)
    shape = configs.SHAPES[shape_name]
    # the microbatch-accumulation scan body is ALSO counted once by XLA's
    # cost analysis; everything inside it (the whole model) repeats
    # accum_steps times per step (optimizer runs once — negligible flops)
    tcfg = dataclasses.replace(_train_cfg_for(arch), **(train_overrides or {}))
    accum = tcfg.accum_steps if shape.kind == "train" else 1
    probes = []
    for k in (1, 2):
        ov = dict(extra_cfg or {})
        ov.update(n_layers=p * k, scan_layers=False)
        if cfg.is_encdec:
            ov["n_encoder_layers"] = k
        cost, hlo, _, secs, _, _ = _compile_once(
            arch, shape_name, mesh, attn_impl=attn_impl, remat=remat, extra_cfg=ov,
            train_overrides=train_overrides, use_sp=use_sp, use_tp=use_tp,
        )
        colls = rf.parse_hlo_collectives(hlo)
        probes.append((cost, colls, secs))
    (c1, x1, s1), (c2, x2, s2) = probes
    blocks = cfg.n_layers / p  # fractional when a remainder stack exists

    def corr(a, b):
        return (a + (blocks - 1.0) * (b - a)) * accum

    cost = {
        "flops": corr(c1.get("flops", 0.0), c2.get("flops", 0.0)),
        "bytes accessed": corr(c1.get("bytes accessed", 0.0), c2.get("bytes accessed", 0.0)),
        "transcendentals": corr(c1.get("transcendentals", 0.0), c2.get("transcendentals", 0.0)),
    }
    coll = {}
    zero = {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
    for kind in set(x1) | set(x2):
        a, b = x1.get(kind, zero), x2.get(kind, zero)
        coll[kind] = {k: corr(a[k], b[k]) for k in zero}
    return cost, coll, s1 + s2


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, attn_impl=None,
             remat=None, extra_cfg=None, verbose=True, probe_cost=True,
             train_overrides=None, use_sp=True, use_tp=True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    cfg0 = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    ok, reason = configs.cell_status(cfg0, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    # full-depth compile: proves the real config lowers/compiles + memory
    cost_raw, hlo, mem, t_compile, model_flops, cfg = _compile_once(
        arch, shape_name, mesh, attn_impl=attn_impl, remat=remat,
        extra_cfg=extra_cfg, train_overrides=train_overrides, use_sp=use_sp,
        use_tp=use_tp,
    )

    probe_s = 0.0
    if probe_cost:
        cost, coll, probe_s = _corrected_cost(
            arch, shape_name, mesh, cfg, attn_impl=attn_impl, remat=remat,
            extra_cfg=extra_cfg, train_overrides=train_overrides, use_sp=use_sp,
            use_tp=use_tp,
        )
        cbytes = sum(v["wire_bytes"] for v in coll.values())
        report = rf.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name,
            flops_per_device=cost["flops"],
            bytes_per_device=cost["bytes accessed"],
            collective_bytes_per_device=cbytes,
            collectives={k: v for k, v in coll.items() if v["count"]},
            t_compute=cost["flops"] / rf.PEAK_FLOPS,
            t_memory=cost["bytes accessed"] / rf.HBM_BW,
            t_collective=cbytes / rf.ICI_BW,
            dominant="",
            model_flops=model_flops,
            useful_flops_ratio=0.0,
            chips=chips,
            memory_per_device=mem,
        )
        report.dominant = max(
            (("compute", report.t_compute), ("memory", report.t_memory),
             ("collective", report.t_collective)), key=lambda kv: kv[1])[0]
        total = report.flops_per_device * chips
        report.useful_flops_ratio = model_flops / total if total else 0.0
    else:
        report = rf.roofline(
            arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
            cost=cost_raw, hlo_text=hlo, model_flops=model_flops,
            memory_per_device=mem,
        )

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "compile_s": round(t_compile, 1), "probe_s": round(probe_s, 1),
        "cost_raw": {k: v for k, v in cost_raw.items()
                     if k in ("flops", "bytes accessed", "transcendentals")},
        "memory": mem,
        "roofline": report.as_dict(),
    }
    if verbose:
        dom = report.dominant
        print(
            f"[{arch} × {shape_name} × {mesh_name}] compile={t_compile:.0f}s "
            f"flops/dev={report.flops_per_device:.3e} "
            f"bytes/dev={report.bytes_per_device:.3e} "
            f"coll/dev={report.collective_bytes_per_device:.3e} "
            f"t=(c {report.t_compute*1e3:.2f} | m {report.t_memory*1e3:.2f} "
            f"| x {report.t_collective*1e3:.2f}) ms → {dom}; "
            f"useful={report.useful_flops_ratio:.2f} "
            f"mem/dev={mem.get('total_bytes_per_device', 0)/2**30:.2f}GiB",
            flush=True,
        )
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="one arch (default: all)")
    p.add_argument("--shape", default=None, help="one shape (default: all)")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--out", default="dryrun_results.json")
    p.add_argument("--force", action="store_true")
    p.add_argument("--attn-impl", default=None)
    p.add_argument("--remat", default=None)
    args = p.parse_args(argv)

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(configs.SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                if not args.force and (arch, shape, mesh_name) in done:
                    continue
                try:
                    rec = run_cell(arch, shape, multi,
                                   attn_impl=args.attn_impl, remat=args.remat)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    print(f"[{arch} × {shape} × {mesh_name}] ERROR {e}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != (arch, shape, mesh_name)]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"dry-run complete: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
