"""Training launcher: config → mesh → resilient jitted loop → checkpoints.

Single-host it runs for real (the end-to-end example trains paper-llama on
this container); on a TPU slice the same entry point picks up all devices
(`plan_mesh`) and shards via the rules engine. The loop runs under the
`train_resilient` supervisor (DESIGN.md §6): verified checkpoints with
newest-good fallback, non-finite-grad skip + dynamic loss scaling inside
the jitted step, loss-spike rollback, and — with `--fault-rate` — the same
deterministic chaos injection the serve launcher exposes, here at the five
train sites. Restarting after a crash with `--resume` replays to a
bitwise-identical loss curve.

    PYTHONPATH=src python -m repro.launch.train --arch paper-llama \
        --steps 200 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt

    # chaos soak + resume
    PYTHONPATH=src python -m repro.launch.train --steps 200 \
        --ckpt-dir /tmp/ckpt --fault-rate 0.1 --fault-seed 7
    PYTHONPATH=src python -m repro.launch.train --steps 400 \
        --ckpt-dir /tmp/ckpt --resume
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.optim import AdamWConfig, CompressionConfig, OptState
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import FaultInjector, StragglerMonitor, plan_mesh
from repro.train import ResilienceConfig, train_resilient
from repro.train.train_step import TrainConfig, TrainState, init_train_state, make_train_step


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-llama")
    p.add_argument("--smoke", action="store_true", help="use the reduced config")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--compression", default="none", choices=["none", "int8", "topk"])
    p.add_argument("--attn-impl", default=None)
    p.add_argument("--ckpt-dir", default=None,
                   help="checkpoint directory (default: fresh temp dir)")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--keep-checkpoints", type=int, default=3,
                   help="garbage-collect all but the newest N (0 → keep all)")
    p.add_argument("--resume", action="store_true",
                   help="continue from the newest verified checkpoint in "
                        "--ckpt-dir (without this flag a non-empty dir is "
                        "an error, so nothing resumes silently)")
    p.add_argument("--fault-rate", type=float, default=0.0,
                   help="chaos injection: probability each train-site "
                        "check fires (0 → no injection)")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="seed for the deterministic fault injector")
    p.add_argument("--spike-threshold", type=float, default=0.0,
                   help="loss-spike rollback: loss > T × trailing median "
                        "restores the last good checkpoint (0 → off)")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        compression=CompressionConfig(kind=args.compression),
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        accum_steps=args.accum,
    )

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_ckpt_")
    existing = ckpt.valid_steps(ckpt_dir)
    if existing and not args.resume:
        raise SystemExit(
            f"{ckpt_dir} already holds checkpoints (steps {existing}); "
            f"pass --resume to continue or point --ckpt-dir elsewhere"
        )
    if args.resume and existing:
        print(f"resuming from step {existing[-1]} in {ckpt_dir}")

    n_dev = len(jax.devices())
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
    ))

    if n_dev > 1:
        plan = plan_mesh(n_dev)
        mesh = jax.make_mesh(plan.mesh_shape, plan.axis_names)
        ctx = shd.ShardingCtx(mesh)
    else:
        mesh = ctx = None

    def init_state_fn():
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tc)
        if ctx is not None:
            with shd.activate(ctx), shd.mesh_ctx(mesh):
                state = jax.device_put(state, shd.to_named(_sspec(state)))
        return state

    def _sspec(state):
        pspecs = shd.param_specs(state.params)
        return TrainState(params=pspecs,
                          opt=OptState(m=pspecs, v=pspecs, step=P()),
                          residual=(pspecs if state.residual is not None else None),
                          step=P(), loss_scale=P(), good_steps=P(), skipped=P())

    def build_step_fn():
        step_raw = make_train_step(cfg, tc)
        if ctx is None:
            return jax.jit(step_raw)
        with shd.activate(ctx), shd.mesh_ctx(mesh):
            sspec = _sspec(init_state_fn())
            inner = shd.sharded_jit(step_raw, in_shardings=(sspec, None))

        def step(state, batch):
            with shd.activate(ctx), shd.mesh_ctx(mesh):
                return inner(state, batch)

        return step

    injector = None
    if args.fault_rate > 0:
        injector = FaultInjector(args.fault_rate, args.fault_seed,
                                 sites=FaultInjector.TRAIN_SITES)

    monitor = StragglerMonitor(
        on_straggler=lambda s, dt, mu: print(
            f"[straggler] step {s}: {dt*1e3:.0f}ms vs EWMA {mu*1e3:.0f}ms "
            f"— would flag this pod for exclusion at re-mesh"
        )
    )
    last_t = [time.monotonic()]

    def on_step(step, metrics, counters):
        now = time.monotonic()
        monitor.observe(step, now - last_t[0])
        last_t[0] = now
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss {metrics['loss']:.4f} "
                f"gnorm {metrics['grad_norm']:.3f} "
                f"lr {metrics['lr']:.2e} "
                f"scale {metrics['loss_scale']:.3g} | "
                f"skipped={int(metrics['skipped'])} "
                f"rollbacks={counters['rollbacks']} "
                f"restarts={counters['restarts']} "
                f"faults={counters['faults']}",
                flush=True,
            )

    res = ResilienceConfig(
        ckpt_every=args.ckpt_every,
        keep_checkpoints=args.keep_checkpoints or None,
        spike_threshold=args.spike_threshold,
    )
    t0 = time.time()
    state, history, counters = train_resilient(
        ckpt_dir=ckpt_dir, model_cfg=cfg, train_cfg=tc, data=data,
        total_steps=args.steps, seed=args.seed, res=res, injector=injector,
        init_state_fn=init_state_fn, step_fn=build_step_fn(),
        on_step=on_step,
    )
    print(
        f"done: {len(history)} committed steps in {time.time() - t0:.1f}s "
        f"(skipped={counters['skipped']} rollbacks={counters['rollbacks']} "
        f"restarts={counters['restarts']} faults={counters['faults']} "
        f"stragglers={len(monitor.flagged)}) — checkpoints in {ckpt_dir}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
