"""Training launcher: config → mesh → resilient jitted loop → checkpoints.

Single-host it runs for real (the end-to-end example trains paper-llama on
this container); on a TPU slice the same entry point picks up all devices
(`plan_mesh`) and shards via the rules engine. Fault tolerance: async
checkpoints + restart-from-latest + straggler monitor, all on by default.

    PYTHONPATH=src python -m repro.launch.train --arch paper-llama \
        --steps 200 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.optim import AdamWConfig, CompressionConfig, OptState
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import StragglerMonitor, plan_mesh
from repro.train.train_step import TrainConfig, TrainState, init_train_state, make_train_step


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-llama")
    p.add_argument("--smoke", action="store_true", help="use the reduced config")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--compression", default="none", choices=["none", "int8", "topk"])
    p.add_argument("--attn-impl", default=None)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch) if args.smoke else configs.get_config(args.arch)
    if args.attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        compression=CompressionConfig(kind=args.compression),
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        accum_steps=args.accum,
    )

    n_dev = len(jax.devices())
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
    ))

    if n_dev > 1:
        plan = plan_mesh(n_dev)
        mesh = jax.make_mesh(plan.mesh_shape, plan.axis_names)
        ctx = shd.ShardingCtx(mesh)
    else:
        mesh = ctx = None

    def build():
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tc)
        step_raw = make_train_step(cfg, tc)
        if ctx is None:
            return state, jax.jit(step_raw, donate_argnums=(0,))
        with shd.activate(ctx), shd.mesh_ctx(mesh):
            pspecs = shd.param_specs(state.params)
            sspec = TrainState(params=pspecs,
                               opt=OptState(m=pspecs, v=pspecs, step=P()),
                               residual=(pspecs if state.residual is not None else None),
                               step=P())
            state = jax.device_put(state, shd.to_named(sspec))
            step = shd.sharded_jit(step_raw, in_shardings=(sspec, None),
                                   donate_argnums=(0,))
            return state, step

    state, step_fn = build()
    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = ckpt.CheckpointManager(args.ckpt_dir)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, extra = ckpt.restore(args.ckpt_dir, state, step=last)
            start = int(extra["data_step"])
            print(f"resumed from step {start}")

    monitor = StragglerMonitor(
        on_straggler=lambda s, dt, mu: print(
            f"[straggler] step {s}: {dt*1e3:.0f}ms vs EWMA {mu*1e3:.0f}ms "
            f"— would flag this pod for exclusion at re-mesh"
        )
    )

    def run_steps(state):
        for i in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch(i))
            monitor.start_step()
            with (shd.activate(ctx) if ctx else _null()), \
                 (shd.mesh_ctx(mesh) if mesh else _null()):
                state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            monitor.end_step(i)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(
                    f"step {i:5d} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e}",
                    flush=True,
                )
            if mgr and ((i + 1) % args.ckpt_every == 0 or i == args.steps - 1):
                mgr.save_async(i + 1, state, extra={"data_step": i + 1})
        if mgr:
            mgr.wait()
        return state

    import contextlib

    def _null():
        return contextlib.nullcontext()

    t0 = time.time()
    state = run_steps(state)
    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s "
          f"({len(monitor.flagged)} straggler events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
