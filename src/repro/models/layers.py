"""Shared neural-net layers: norms, rotary embeddings, initializers, embed.

Pure-functional: params are nested dicts of jnp arrays; every `init_*`
returns a pytree and the matching `apply` consumes it. Master weights live
in `param_dtype` (f32); compute casts to `dtype` (bf16) at use sites.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "rms_norm",
    "apply_rope",
    "embed_lookup",
    "logits_from_hidden",
    "conv1d_causal",
]


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LLaMA-style 0.02 default cap)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else min(0.02, 1.0 / math.sqrt(fan_in))
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in f32 (norm statistics never in bf16), output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float):
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x [B, S, H, hd]; positions [B, S] or [S]."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)  # [S, half] or [B, S, half]
    if cos.ndim == 2:  # positions [S] → align to [1, S, 1, half]
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # positions [B, S] → [B, S, 1, half]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(table: jax.Array, ids: jax.Array, dtype) -> jax.Array:
    """Token embedding with sqrt(d) scaling left to the caller's convention
    (we follow LLaMA: no scaling)."""
    return jnp.take(table, ids, axis=0).astype(dtype)


def logits_from_hidden(
    h: jax.Array, head: jax.Array, true_vocab: int
) -> jax.Array:
    """LM head on padded vocab; padded slots masked to a large negative so
    softmax/CE ignore them. Computed in bf16 matmul, f32 logits."""
    logits = jnp.einsum(
        "bsd,dv->bsv", h, head.astype(h.dtype), preferred_element_type=jnp.float32
    )
    v_pad = head.shape[-1]
    if v_pad > true_vocab:
        mask = jnp.arange(v_pad) < true_vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def conv1d_causal(x: jax.Array, w: jax.Array, cache: Optional[jax.Array] = None):
    """Depthwise causal 1-D conv. x [B, S, C], w [K, C].

    Training/prefill: full-sequence (left-padded). Decode: pass `cache`
    [B, K-1, C] of trailing inputs; returns (y [B, 1, C], new_cache).
    """
    k = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        y = sum(
            xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
        )
        return y.astype(x.dtype), None
    window = jnp.concatenate([cache, x], axis=1)  # [B, K, C]
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    return y[:, None, :].astype(x.dtype), window[:, 1:, :]
