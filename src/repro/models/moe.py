"""Mixture-of-Experts FFN: top-k routing, group-local capacity dispatch.

GShard-style grouped dispatch: tokens are reshaped to [G, t, d] groups
(G aligned with the data-parallel shards) and each group routes/dispatches
INDEPENDENTLY with a group-local capacity C = ceil(t·k/E · cf). Everything
before the expert einsum is group-local (no communication); the expert
einsum over the E-sharded stacked weights is where GSPMD inserts the
all-to-all (tokens→experts) — the canonical EP pattern. A global-capacity
formulation would make the dispatch buffer [E, T·k/E·cf, d] with T the
GLOBAL token count, which is both a memory blow-up per shard and a
compile-time collective disaster (measured: 69 GiB/device on
qwen3-moe-235b train_4k before this rewrite).

Position-in-expert comes from a cumsum over the one-hot assignment (no
[T, E, C] one-hot dispatch tensor); tokens past capacity drop (GShard
semantics); combine weights renormalize over surviving choices.

Aux outputs: switch load-balance loss + router z-loss + dropped fraction.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import active_ctx, shard
from repro.models.layers import dense_init

__all__ = ["init_moe", "apply_moe"]


def init_moe(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dt = cfg.master_dtype
    return {
        "router": dense_init(ks[0], (d, e), dtype=dt),
        "experts_wg": dense_init(ks[1], (e, d, f), dtype=dt),
        "experts_wu": dense_init(ks[2], (e, d, f), dtype=dt),
        "experts_wd": dense_init(ks[3], (e, f, d), dtype=dt),
    }


def _n_groups(t: int) -> int:
    """Groups ≈ data-parallel shards (so dispatch is shard-local); falls
    back gracefully on small inputs and single-device runs."""
    ctx = active_ctx()
    want = 1
    if ctx is not None:
        want = ctx.axis_size(ctx.batch_axes)
    while want > 1 and t % want:
        want //= 2
    return max(want, 1)


def apply_moe(params: dict, x: jax.Array, cfg) -> Tuple[jax.Array, dict]:
    """x [B, S, D] → (y [B, S, D], aux losses dict)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    t_total = b * s
    g = _n_groups(t_total)
    t = t_total // g  # tokens per group
    cdt = cfg.compute_dtype
    xt = x.reshape(g, t, d)
    xt = shard(xt, "moe_groups")

    logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [G, t, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- group-local capacity assignment over (token, choice) pairs ----
    # Rank-within-expert via a stable sort over expert ids — O(t·k) memory.
    # (A one-hot cumsum [t·k, E] would cost T·k·E ints: ~17 GiB/device on
    # qwen3-moe-235b train_4k. Measured; hence the sort.)
    cap = int((t * k / e) * cfg.capacity_factor) + 1
    # choice-major flattening: all 1st choices outrank all 2nd choices, etc.
    # (GShard priority semantics)
    flat_e = top_e.transpose(0, 2, 1).reshape(g, k * t)

    def rank_in_expert(fe):
        order = jnp.argsort(fe, stable=True)
        sorted_e = fe[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e))  # [E]
        pos_sorted = jnp.arange(k * t) - starts[sorted_e]
        return jnp.zeros((k * t,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))

    flat_pos = jax.vmap(rank_in_expert)(flat_e)
    keep = flat_pos < cap  # [G, k·t]
    slot = flat_e * cap + jnp.where(keep, flat_pos, 0)

    # scatter tokens into group-local capacity buffers [G, E·C, d] — one
    # scatter per routing choice, so no [G, k·t, d] token replication
    xt_c = xt.astype(cdt)
    buf = jnp.zeros((g, e * cap, d), cdt)
    for j in range(k):
        slot_j = slot[:, j * t:(j + 1) * t]
        keep_j = keep[:, j * t:(j + 1) * t]
        buf = jax.vmap(lambda b_, sl, sr: b_.at[sl].add(sr))(
            buf, slot_j, jnp.where(keep_j[..., None], xt_c, 0)
        )
    buf = shard(buf.reshape(g, e, cap, d), "moe_dispatch")

    # ---- expert FFN (SwiGLU) — the all-to-all happens around this einsum
    # Re-assert expert-only sharding on the (bf16-cast) weights before the
    # einsum: the FSDP shard on d would otherwise make XLA all-reduce the
    # [G,E,C,F] einsum output over the data axis every layer — gathering the
    # E-local weight slices (≤200 MB) is strictly cheaper (§Perf lever).
    def _expert_shard(w):
        ctx = active_ctx()
        if ctx is None:
            return w.astype(cdt)
        from jax.sharding import PartitionSpec as P

        e_fit = e % ctx.axis_size("model") == 0
        spec = P("model" if e_fit else None, None, None)
        return jax.lax.with_sharding_constraint(w.astype(cdt), spec)

    wg_ = _expert_shard(params["experts_wg"])
    wu_ = _expert_shard(params["experts_wu"])
    wd_ = _expert_shard(params["experts_wd"])
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, wg_)) * jnp.einsum(
        "gecd,edf->gecf", buf, wu_
    )
    out = jnp.einsum("gecf,efd->gecd", h, wd_)
    out = shard(out, "moe_dispatch").reshape(g, e * cap, d)

    # ---- combine: per-choice gather of expert outputs, weighted sum ----
    w_choice = top_p.transpose(0, 2, 1)  # [G, k, t]
    y = jnp.zeros((g, t, d), cdt)
    for j in range(k):
        slot_j = slot[:, j * t:(j + 1) * t]
        keep_j = keep[:, j * t:(j + 1) * t]
        gathered = jax.vmap(lambda o, sl: jnp.take(o, sl, axis=0))(out, slot_j)
        wj = (w_choice[:, j] * keep_j).astype(cdt)
        y = y + gathered * wj[..., None]

    # ---- aux losses (Switch §2.2 + router z-loss) ----
    density = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux_loss = e * jnp.sum(density * density_proxy) * cfg.aux_loss_weight
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_loss
    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss, "moe_dropped": frac_dropped}
    return y.reshape(b, s, d), aux
