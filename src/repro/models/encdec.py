"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional attention blocks over precomputed audio-frame
embeddings (the modality frontend is a stub per the assignment — frames
enter as [B, T, d_model]). Decoder: causal self-attention + cross-attention
to the encoder output + SwiGLU, all through the FLASH-D kernels (cross
attention uses the 'full' mask — no causal structure over memory).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.attention import decode_attention
from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import embed_lookup, logits_from_hidden, rms_norm, dense_init
from repro.models.transformer import (
    _apply_attn,
    _apply_swiglu,
    _init_attn,
    _init_swiglu,
    _qkv,
    _AUX_KEYS,
    _remat,
)



def _maybe_scan(body, carry, xs, cfg, with_out=False):
    """lax.scan or python unroll (dry-run cost probes; see ModelConfig)."""
    import jax as _jax, jax.numpy as _jnp
    if cfg.scan_layers:
        return _jax.lax.scan(body, carry, xs)
    nb = _jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(nb):
        carry, y = body(carry, _jax.tree.map(lambda x: x[i], xs))
        outs.append(y)
    if with_out and outs[0] is not None:
        outs = _jax.tree.map(lambda *ys: _jnp.stack(ys), *outs)
    else:
        outs = None
    return carry, outs

__all__ = ["init_encdec", "apply_encdec", "encdec_loss", "init_encdec_cache", "decode_step_encdec"]


def _init_enc_block(key, cfg):
    return {
        "norm1": jnp.zeros((cfg.d_model,), cfg.master_dtype),
        "mixer": _init_attn(jax.random.fold_in(key, 1), cfg),
        "norm2": jnp.zeros((cfg.d_model,), cfg.master_dtype),
        "ffn": _init_swiglu(jax.random.fold_in(key, 2), cfg),
    }


def _init_dec_block(key, cfg):
    p = _init_enc_block(key, cfg)
    p["norm_cross"] = jnp.zeros((cfg.d_model,), cfg.master_dtype)
    p["cross"] = _init_attn(jax.random.fold_in(key, 3), cfg)
    return p


def init_encdec(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    dt = cfg.master_dtype

    def stack(base, n, mk):
        blocks = [mk(jax.random.fold_in(base, i), cfg) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    return {
        "embed": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), scale=0.02, dtype=dt),
        "frame_proj": dense_init(ks[1], (cfg.d_model, cfg.d_model), dtype=dt),
        "enc_blocks": stack(ks[2], cfg.n_encoder_layers, _init_enc_block),
        "dec_blocks": stack(ks[3], cfg.n_layers, _init_dec_block),
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": dense_init(ks[4], (cfg.d_model, cfg.padded_vocab), dtype=dt),
    }


def encode(params, frame_embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frame_embeds [B, T, D] (stub frontend output) → memory [B, T, D]."""
    cdt = cfg.compute_dtype
    h = jnp.einsum("btd,de->bte", frame_embeds.astype(cdt), params["frame_proj"].astype(cdt))
    h = shard(h, "residual")
    positions = jnp.arange(h.shape[1])

    def body(h, bp):
        x = rms_norm(h, bp["norm1"], cfg.norm_eps)
        h = shard(h + _apply_attn(bp["mixer"], x, cfg, "attn_bidir", positions), "residual")
        x = rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = shard(h + _apply_swiglu(bp["ffn"], x, cfg), "residual")
        return h, None

    h, _ = _maybe_scan(_remat(body, cfg), h, params["enc_blocks"], cfg)
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _dec_body(cfg, memory, positions):
    def body(carry, bp):
        h, aux = carry
        x = rms_norm(h, bp["norm1"], cfg.norm_eps)
        h = shard(h + _apply_attn(bp["mixer"], x, cfg, "attn", positions), "residual")
        x = rms_norm(h, bp["norm_cross"], cfg.norm_eps)
        h = shard(h + _apply_attn(bp["cross"], x, cfg, "cross", positions, kv_x=memory), "residual")
        x = rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = shard(h + _apply_swiglu(bp["ffn"], x, cfg), "residual")
        return (h, aux), None

    return body


def apply_encdec(params: dict, batch: Dict, cfg: ModelConfig, *, last_only: bool = False):
    """batch: frame_embeds [B,T,D], tokens [B,S] → (logits, aux)."""
    memory = encode(params, batch["frame_embeds"], cfg)
    h = embed_lookup(params["embed"], batch["tokens"], cfg.compute_dtype)
    h = shard(h, "residual")
    positions = jnp.arange(h.shape[1])
    aux = {k: jnp.float32(0.0) for k in _AUX_KEYS}
    (h, aux), _ = _maybe_scan(
        _remat(_dec_body(cfg, memory, positions), cfg), (h, aux), params["dec_blocks"], cfg
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    logits = logits_from_hidden(h, params["lm_head"], cfg.vocab_size)
    return shard(logits, "logits"), aux


def encdec_loss(params: dict, batch: Dict, cfg: ModelConfig):
    logits, aux = apply_encdec(params, batch, cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# decode (serve): cached self-attn KV + cached cross-attn KV
# ---------------------------------------------------------------------------

def init_encdec_cache(batch: int, max_len: int, mem_len: int, cfg: ModelConfig) -> dict:
    hd = cfg.head_dim_
    n = cfg.n_layers
    kv = lambda s: jnp.zeros((n, batch, s, cfg.n_kv_heads, hd), cfg.compute_dtype)
    return {
        "self_k": kv(max_len), "self_v": kv(max_len),
        "cross_k": kv(mem_len), "cross_v": kv(mem_len),
    }


def fill_cross_cache(params: dict, memory: jax.Array, cache: dict, cfg: ModelConfig):
    """Project encoder memory through every decoder layer's cross K/V once."""
    cdt = cfg.compute_dtype
    b, t, _ = memory.shape
    hd = cfg.head_dim_

    def per_layer(bp):
        k = jnp.einsum("btd,dh->bth", memory, bp["cross"]["wk"].astype(cdt))
        v = jnp.einsum("btd,dh->bth", memory, bp["cross"]["wv"].astype(cdt))
        if cfg.qkv_bias:
            k, v = k + bp["cross"]["bk"].astype(cdt), v + bp["cross"]["bv"].astype(cdt)
        return (
            k.reshape(b, t, cfg.n_kv_heads, hd),
            v.reshape(b, t, cfg.n_kv_heads, hd),
        )

    ks, vs = jax.vmap(per_layer)(params["dec_blocks"])
    return {**cache, "cross_k": ks, "cross_v": vs}


def decode_step_encdec(params: dict, cache: dict, token: jax.Array, pos: jax.Array, cfg: ModelConfig):
    """One decoder step against cached cross-attention memory."""
    b = token.shape[0]
    hd = cfg.head_dim_
    cdt = cfg.compute_dtype
    h = embed_lookup(params["embed"], token[:, None], cdt)
    mem_len = cache["cross_k"].shape[2]
    bidx = jnp.arange(b)

    def body(h, xs):
        bp, sk, sv, ck, cv = xs
        # self attention
        x = rms_norm(h, bp["norm1"], cfg.norm_eps)
        q, k, v = _qkv(bp["mixer"], x, cfg, "attn", pos[:, None])
        sk = sk.at[bidx, pos].set(k[:, 0])
        sv = sv.at[bidx, pos].set(v[:, 0])
        o = decode_attention(q, sk, sv, pos + 1)
        h = h + jnp.einsum(
            "bsh,hd->bsd", o.reshape(b, 1, -1), bp["mixer"]["wo"].astype(cdt)
        )
        # cross attention against cached memory K/V
        x = rms_norm(h, bp["norm_cross"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dh->bsh", x, bp["cross"]["wq"].astype(cdt))
        if cfg.qkv_bias:
            qc = qc + bp["cross"]["bq"].astype(cdt)
        qc = qc.reshape(b, 1, cfg.n_heads, hd)
        oc = decode_attention(qc, ck, cv, jnp.full((b,), mem_len))
        h = h + jnp.einsum(
            "bsh,hd->bsd", oc.reshape(b, 1, -1), bp["cross"]["wo"].astype(cdt)
        )
        # ffn
        x = rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = h + _apply_swiglu(bp["ffn"], x, cfg)
        return h, (sk, sv)

    if cfg.scan_layers:
        # fori_loop carrying the stacked self-cache, sliced/updated in place
        # (same rationale as decode_step_lm: one cache buffer, donatable)
        def loop_body(i, carry):
            h, sk_all, sv_all = carry
            xs = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
                (params["dec_blocks"], sk_all, sv_all,
                 cache["cross_k"], cache["cross_v"]),
            )
            h, (sk_i, sv_i) = body(h, xs)
            sk_all = jax.lax.dynamic_update_index_in_dim(sk_all, sk_i, i, 0)
            sv_all = jax.lax.dynamic_update_index_in_dim(sv_all, sv_i, i, 0)
            return (h, sk_all, sv_all)

        n = cfg.n_layers
        h, sk, sv = jax.lax.fori_loop(
            0, n, loop_body, (h, cache["self_k"], cache["self_v"])
        )
    else:
        h, out = _maybe_scan(
            body,
            h,
            (params["dec_blocks"], cache["self_k"], cache["self_v"],
             cache["cross_k"], cache["cross_v"]),
            cfg,
            with_out=True,
        )
        sk, sv = out
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(h, params["lm_head"], cfg.vocab_size)
    return logits[:, 0], {**cache, "self_k": sk, "self_v": sv}
