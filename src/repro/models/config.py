"""Model configuration — one dataclass drives every assigned architecture.

A model is a repeating *pattern* of (mixer, ffn) layer specs scanned over
`n_layers` (pattern remainder handled as an epilogue stack), which lets
heterogeneous stacks (recurrentgemma 1:2, llama4 3:1 chunked:global) compile
as compact `lax.scan`s with stacked parameters instead of 38–94 unrolled
layers. Mixers:

  attn          causal softmax attention (FLASH-D kernel)
  attn_bidir    bidirectional (encoder / cross)
  attn_local    causal sliding window (recurrentgemma)
  attn_chunked  causal within chunks (llama4 iRoPE local layers)
  attn_nope     causal, NO rotary (llama4 global layers)
  ssm           Mamba-2 SSD block (attention-free)
  rglru         Griffin RG-LRU recurrent block

FFNs: swiglu | moe | none (mamba blocks carry no separate FFN).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

LayerSpec = Tuple[str, str]  # (mixer, ffn)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (("attn", "swiglu"),)
    head_dim: int = 0  # 0 → d_model // n_heads
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_window: int = 0  # attn_local sliding window
    attn_chunk: int = 0  # attn_chunked chunk length
    rope_theta: float = 10000.0
    attn_impl: str = "flashd"  # flashd | fa2 | naive | xla | flashd_pallas | fa2_pallas
    attn_block_q: Optional[int] = None  # None → repro.kernels.tuning picks
    attn_block_k: Optional[int] = None
    attn_skip: bool = False  # FLASH-D tile-skip predication
    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    lru_width: int = 0  # 0 → d_model
    # enc-dec
    n_encoder_layers: int = 0  # >0 → encoder-decoder model
    # modality frontend (stub: precomputed embeddings enter input_specs)
    frontend: str = "none"  # none | vision | audio
    frontend_tokens: int = 0  # patches / frames prepended (vision) or encoder input length factor (audio)
    # numerics / embedding
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"  # master weights
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # remat: none | dots | full
    remat: str = "full"
    # scan over layer blocks (compile-compact) vs python-unrolled (used by
    # the dry-run cost probes: XLA cost_analysis counts a while body once,
    # so trip-count-corrected totals come from 1- vs 2-block unrolled probes)
    scan_layers: bool = True

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> Tuple[LayerSpec, ...]:
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def master_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def uses_attention(self) -> bool:
        return any(m.startswith("attn") for m, _ in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no layer does full-context softmax attention over long
        sequences (SSM / local / chunked only) — gates the long_500k shape."""
        return all(
            m in ("ssm", "rglru", "attn_local", "attn_chunked") or not m.startswith("attn")
            for m, _ in self.pattern
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, hd = self.d_model, self.head_dim_
        total = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d  # lm head
        total += d  # final norm

        def attn_params():
            p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            if self.qkv_bias:
                p += (self.n_heads + 2 * self.n_kv_heads) * hd
            if self.qk_norm:
                p += 2 * hd
            return p + d  # pre-norm

        def swiglu_params():
            return 3 * d * self.d_ff + d

        def moe_params():
            return self.n_experts * 3 * d * self.d_ff + d * self.n_experts + d

        def ssm_params():
            di, hs = self.d_inner, self.ssm_heads
            p = d * (2 * di + 2 * self.ssm_state + hs)  # in_proj (z,x,B,C,dt)
            p += self.conv_width * (di + 2 * self.ssm_state)  # conv
            p += hs + hs  # A_log, D
            p += di * d  # out_proj
            return p + d

        def rglru_params():
            w = self.lru_width_
            p = 2 * d * w  # input + gate branch
            p += self.conv_width * w  # temporal conv
            p += 2 * w * w // 1  # RG-LRU gates (input gate + recurrence gate, diagonalish per-channel: use w params each)
            p += w * d  # out proj
            return p + d

        mixer_cost = {
            "attn": attn_params, "attn_bidir": attn_params, "attn_local": attn_params,
            "attn_chunked": attn_params, "attn_nope": attn_params,
            "ssm": ssm_params, "rglru": rglru_params, "none": lambda: 0,
        }
        ffn_cost = {"swiglu": swiglu_params, "moe": moe_params, "none": lambda: 0}

        layers = [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]
        for mixer, ffn in layers:
            total += mixer_cost[mixer]() + ffn_cost[ffn]()
        if self.is_encdec:
            # encoder layers (bidir attn + swiglu) + decoder cross-attn adds
            total += self.n_encoder_layers * (attn_params() + swiglu_params())
            total += self.n_layers * attn_params()  # cross-attention per decoder layer
        if self.frontend == "vision":
            total += self.d_model * self.d_model  # patch projection stub
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for _, f in (
            self.pattern[i % len(self.pattern)] for i in range(self.n_layers)
        ) if f == "moe")
        inactive = moe_layers * (self.n_experts - self.n_experts_active) * 3 * self.d_model * self.d_ff
        return full - inactive
