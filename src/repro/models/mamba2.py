"""Mamba-2 (SSD — state-space duality) mixer, chunked matmul formulation.

The SSD recurrence  h_t = exp(a·dt_t)·h_{t−1} + dt_t·B_t x_tᵀ,
y_t = C_tᵀ h_t + D·x_t  is evaluated with the chunked algorithm of
arXiv:2405.21060 §6: intra-chunk terms are a masked quadratic form (MXU
matmuls), inter-chunk state is carried by a short `lax.scan` over chunks —
TPU-native (no per-step scan over 4k..512k tokens).

Decode: O(1) per token via the explicit recurrence on the carried state
[B, H, P, N]. The attention-free path for the long_500k shape.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import conv1d_causal, dense_init, rms_norm

__all__ = ["init_mamba2", "apply_mamba2", "init_mamba2_cache", "decode_mamba2"]


def init_mamba2(key, cfg) -> dict:
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    dt = cfg.master_dtype
    # in_proj emits [z (di), x (di), B (n), C (n), dt (h)]
    return {
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype=dt),
        "conv_w": dense_init(ks[1], (cfg.conv_width, di + 2 * n), scale=0.1, dtype=dt),
        "A_log": jnp.zeros((h,), dt) + jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dt),
        "D": jnp.ones((h,), dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(dt),  # softplus⁻¹
        "gate_norm": jnp.zeros((di,), dt),
        "w_out": dense_init(ks[2], (di, d), dtype=dt),
    }


def _split_in(proj, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    return z, xin, bmat, cmat, dt


def _segsum(x):
    """Stable 'segment sum' producing the lower-triangular decay matrix:
    L[i, j] = sum_{j < m <= i} x[m]  (i ≥ j), −inf above the diagonal."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a_log, bmat, cmat, d_skip, chunk: int):
    """SSD forward. x [B,S,H,P], dt [B,S,H], bmat/cmat [B,S,N]; returns y.

    Single B/C group shared across heads (Mamba-2 default, G=1).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))  # [H], negative
    da = (dt * a[None, None, :]).astype(jnp.float32)  # [B,S,H]
    xdt = x * dt[..., None]

    # chunked views: c = chunk index, l = position within chunk
    xr = xdt.reshape(b, nc, chunk, h, p)
    br = bmat.reshape(b, nc, chunk, n)
    cr = cmat.reshape(b, nc, chunk, n)
    dar = da.reshape(b, nc, chunk, h)

    # 1) intra-chunk (quadratic, MXU): y_intra[l] = Σ_{m≤l} C_l·B_m decay(l,m) xdt_m
    lmat = jnp.exp(_segsum(dar.transpose(0, 1, 3, 2)))  # [B,nc,H,L,L]
    cb = jnp.einsum("bcln,bcmn->bclm", cr, br)  # [B,nc,L,L]
    y_intra = jnp.einsum("bclm,bchlm,bcmhp->bclhp", cb, lmat, xr)

    # 2) chunk-final states: states[c] = Σ_m decay(end,m) B_m xdt_mᵀ
    decay_end = jnp.exp(jnp.cumsum(dar, axis=2)[:, :, -1:, :] - jnp.cumsum(dar, axis=2))
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", br, decay_end, xr)

    # 3) inter-chunk recurrence over nc chunks (short scan)
    chunk_decay = jnp.exp(jnp.sum(dar, axis=2))  # [B,nc,H]

    def scan_fn(h_prev, inp):
        st, dec = inp
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N] state entering chunk

    # 4) state→output within chunk: y_inter[l] = C_l · (decay(l,0⁻) h_prev)
    decay_in = jnp.exp(jnp.cumsum(dar, axis=2))  # [B,nc,L,H]
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", cr, decay_in, h_prevs)

    y = (y_intra + y_inter).reshape(b, s, h, p) + x * d_skip[None, None, :, None]
    return y.astype(x.dtype)


def apply_mamba2(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence (training / prefill) Mamba-2 block. x [B,S,D]."""
    b, s, d = x.shape
    cdt = cfg.compute_dtype
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cdt))
    z, xin, bmat, cmat, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, _ = conv1d_causal(conv_in, params["conv_w"].astype(cdt))
    conv_out = jax.nn.silu(conv_out)
    di, n = cfg.d_inner, cfg.ssm_state
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))

    pad = (-s) % cfg.ssm_chunk
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    h = cfg.ssm_heads
    y = ssd_chunked(
        xin.reshape(b, s + pad, h, cfg.ssm_head_dim).astype(jnp.float32),
        dt,
        params["A_log"],
        bmat.astype(jnp.float32),
        cmat.astype(jnp.float32),
        params["D"].astype(jnp.float32),
        cfg.ssm_chunk,
    )[:, :s]
    y = y.reshape(b, s, di).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cdt))


def init_mamba2_cache(batch: int, cfg, dtype=jnp.float32) -> dict:
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "ssm_state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
        "conv_cache": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
    }


def decode_mamba2(params: dict, x: jax.Array, cache: dict, cfg):
    """One-token decode. x [B, 1, D] → (y [B, 1, D], new cache). O(1)/token."""
    b = x.shape[0]
    cdt = cfg.compute_dtype
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(cdt))
    z, xin, bmat, cmat, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_cache = conv1d_causal(
        conv_in, params["conv_w"].astype(cdt), cache["conv_cache"]
    )
    conv_out = jax.nn.silu(conv_out)
    di, n = cfg.d_inner, cfg.ssm_state
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    h, p = cfg.ssm_heads, cfg.ssm_head_dim

    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    lam = jnp.exp(dt[:, 0, :] * a[None, :])  # [B, H]
    xh = xin[:, 0].reshape(b, h, p).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0, :], xh, bmat[:, 0].astype(jnp.float32))
    state = cache["ssm_state"] * lam[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), state)
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(cdt))
    return y, {"ssm_state": state, "conv_cache": conv_cache}
