"""Griffin / RecurrentGemma recurrent block: RG-LRU + temporal conv + GLU.

Structure (arXiv:2402.19427 Fig. 2): two branches from the residual —
  (a) linear → GeLU                                  (gate branch)
  (b) linear → causal conv1d(w=4) → RG-LRU           (recurrent branch)
merged multiplicatively, then projected out.

RG-LRU per channel:  r_t = σ(W_a u_t + b_a)   i_t = σ(W_x u_t + b_x)
  a_t = exp(−c·softplus(Λ)·r_t)     (c = 8)
  h_t = a_t·h_{t−1} + sqrt(1 − a_t²)·(i_t ⊙ u_t)

Training/prefill uses `jax.lax.associative_scan` over the linear recurrence
(log-depth on TPU, no per-token serial chain); decode is the explicit O(1)
update on a carried state. Deviation note: the paper uses block-diagonal
gate weights; we use dense [W, W] gates (documented in DESIGN.md).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import conv1d_causal, dense_init

__all__ = ["init_rglru", "apply_rglru", "init_rglru_cache", "decode_rglru"]

_C = 8.0  # Griffin's fixed recurrence sharpness


def init_rglru(key, cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width_
    ks = jax.random.split(key, 6)
    dt = cfg.master_dtype
    # Λ init so a ∈ (0.9, 0.999) at r = 1 (paper's init)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * _C)))  # softplus⁻¹
    return {
        "w_gate": dense_init(ks[1], (d, w), dtype=dt),  # gate branch (GeLU)
        "w_x": dense_init(ks[2], (d, w), dtype=dt),  # recurrent branch in
        "conv_w": dense_init(ks[3], (cfg.conv_width, w), scale=0.1, dtype=dt),
        "wa_gate": dense_init(ks[4], (w, w), dtype=dt),  # recurrence gate
        "wi_gate": dense_init(ks[5], (w, w), dtype=dt),  # input gate
        "lam": lam.astype(dt),
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d), dtype=dt),
    }


def _gates(params, u, cfg):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["wa_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ params["wi_gate"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def apply_rglru(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence Griffin block. x [B, S, D] → [B, S, D]."""
    cdt = cfg.compute_dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"].astype(cdt)))
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(cdt))
    u, _ = conv1d_causal(u, params["conv_w"].astype(cdt))
    a, b = _gates(params, u, cfg)

    # h_t = a_t h_{t−1} + b_t  — associative: (a2,b2)∘(a1,b1) = (a1a2, a2b1+b2)
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(cdt) * gate)
    return jnp.einsum("bsw,wd->bsd", y, params["w_out"].astype(cdt))


def init_rglru_cache(batch: int, cfg, dtype=jnp.float32) -> dict:
    w = cfg.lru_width_
    return {
        "lru_state": jnp.zeros((batch, w), jnp.float32),
        "conv_cache": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def decode_rglru(params: dict, x: jax.Array, cache: dict, cfg):
    """One-token decode. x [B, 1, D] → (y [B, 1, D], new cache)."""
    cdt = cfg.compute_dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"].astype(cdt)))
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(cdt))
    u, conv_cache = conv1d_causal(u, params["conv_w"].astype(cdt), cache["conv_cache"])
    a, b = _gates(params, u[:, 0], cfg)
    h = a * cache["lru_state"] + b
    y = (h[:, None, :].astype(cdt) * gate)
    y = jnp.einsum("bsw,wd->bsd", y, params["w_out"].astype(cdt))
    return y, {"lru_state": h, "conv_cache": conv_cache}
