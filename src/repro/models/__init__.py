"""Model zoo facade: uniform init/loss/decode API over all architectures."""

from __future__ import annotations

from typing import Callable, NamedTuple

from repro.models import encdec as _encdec
from repro.models import transformer as _tf
from repro.models.config import ModelConfig


class ModelApi(NamedTuple):
    init: Callable  # (key, cfg) -> params
    apply: Callable  # (params, batch, cfg) -> (logits, aux)
    loss: Callable  # (params, batch, cfg) -> (loss, metrics)
    init_cache: Callable  # (batch, max_len, cfg) -> cache
    decode_step: Callable  # (params, cache, token, pos, cfg) -> (logits, cache)


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.is_encdec:
        return ModelApi(
            init=_encdec.init_encdec,
            apply=_encdec.apply_encdec,
            loss=_encdec.encdec_loss,
            # self-attn cache sized to the sequence; cross-attn memory is the
            # encoder frame count — capped at 4096 (audio frontends emit
            # ~O(1k) frames; a 32k cross memory would be modality-impossible)
            # paged-layout kwargs are accepted but ignored: the engine falls
            # back to the contiguous layout for enc-dec (DESIGN.md §3.4)
            init_cache=lambda b, s, c, **kw: _encdec.init_encdec_cache(b, s, min(s, 4096), c),
            decode_step=_encdec.decode_step_encdec,
        )
    return ModelApi(
        init=_tf.init_lm,
        apply=_tf.apply_lm,
        loss=_tf.lm_loss,
        init_cache=_tf.init_decode_cache,
        decode_step=_tf.decode_step_lm,
    )


__all__ = ["ModelConfig", "ModelApi", "get_model"]
